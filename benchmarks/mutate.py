"""Streaming-mutation benchmark: serve throughput + achieved recall
across an insert/delete burst, before and after recalibration and
compaction (the repro.mutate subsystem's end-to-end cost story).

Phases (all served through the slot-pool DarthServer at mixed declared
targets):
  pre-burst         frozen index, freshly fit predictor
  post-burst        +20% inserts (30% drifted/OOD), -10% deletes; the
                    predictor is still the frozen-index fit
  post-recalibrate  drift monitor refit + hot-swap
  post-compact      delta folded into the base, empty ring

Each phase reports host-side qps, mean achieved recall per declared
target against FRESH ground truth over the live base+delta set, and the
mean distance count.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro import mutate
from repro.core import api, engines
from repro.data import vectors
from repro.index import flat, ivf
from repro.serve import DarthServer

K = 10
TARGETS = (0.8, 0.9, 0.95)


def mutate_burst(n: int = 20_000, d: int = 32, queries: int = 384):
    ds = vectors.make_dataset(n=n, d=d, num_learn=2_000,
                              num_queries=queries, clusters=128,
                              cluster_std=1.3, seed=0)
    index = ivf.build(ds.base, nlist=128, seed=0)
    mut = mutate.MutableIndex(
        index, capacity=-(-int(0.2 * n) // 128) * 128)

    def make_engine(**kw):
        return engines.mutable_engine(
            engines.ivf_engine(mut.base, **kw), mut.delta)

    darth = api.Darth(make_engine=make_engine,
                      engine=make_engine(k=K, nprobe=128))
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base))

    rng = np.random.default_rng(0)
    r_targets = rng.choice(TARGETS, size=queries).astype(np.float32)
    server = DarthServer(darth.engine, darth.trained.predictor,
                         darth.interval_for_target, num_slots=64)
    monitor = mutate.RecalibrationMonitor(mut, darth, targets=TARGETS,
                                          threshold=0.01)

    rows = []

    def live_gt():
        """Exact live ground truth — memoized on the mutation epoch by
        MutableIndex itself (post-burst and post-recalibrate share one
        live set, so they share one scan)."""
        return mut.live_ground_truth(ds.queries, K)

    def phase(label):
        t0 = time.time()
        results, stats = server.serve(ds.queries, r_targets)
        dt = time.time() - t0
        done = np.array([i for i, r in enumerate(results)
                         if r is not None])
        if done.size == 0:
            rows.append({"phase": label, "qps": 0.0,
                         "seconds": round(dt, 2), "error": "no results"})
            return rows[-1]
        ids = np.stack([results[i][1] for i in done])
        gt = live_gt()[done]
        rec = np.asarray(flat.recall_at_k(jnp.asarray(ids),
                                          jnp.asarray(gt)))
        monitor.observe(ds.queries[done], r_targets[done], ids)
        row = {"phase": label, "qps": round(len(done) / dt, 1),
               "seconds": round(dt, 2),
               "slot_steps": stats.slot_steps}
        for t in TARGETS:
            sel = r_targets[done] == np.float32(t)
            # null (not NaN) when a target drew no completed queries —
            # results/benchmarks.json must stay standard JSON
            row[f"recall@{t}"] = (round(float(rec[sel].mean()), 4)
                                  if sel.any() else None)
        rows.append(row)
        return row

    phase("pre-burst")

    events = vectors.mutation_stream(ds, insert_pct=0.2, delete_pct=0.1,
                                     drift=0.3, steps=6, seed=1)
    t0 = time.time()
    mut.apply(events)
    mutate_s = time.time() - t0
    server.set_engine(make_engine(k=K, nprobe=128),
                      contents_only=True)
    darth.engine = server.engine
    burst = phase("post-burst")

    rep = monitor.drift()
    t0 = time.time()
    monitor.recalibrate(ds.learn, server=server)
    recal_s = time.time() - t0
    phase("post-recalibrate")

    t0 = time.time()
    mut.compact()
    compact_s = time.time() - t0
    server.set_engine(make_engine(k=K, nprobe=128),
                      contents_only=True)
    darth.engine = server.engine
    final = phase("post-compact")

    rows.append({"phase": "costs", "mutate_seconds": round(mutate_s, 2),
                 "recalibrate_seconds": round(recal_s, 2),
                 "compact_seconds": round(compact_s, 2),
                 "drift_worst_gap": round(rep.worst_gap, 4),
                 "num_live": mut.num_live})
    if "recall@0.9" in burst and "recall@0.9" in final:
        headline = (f"post-burst r@.9 {burst['recall@0.9']:.3f} -> "
                    f"post-compact {final['recall@0.9']:.3f}; "
                    f"compact {compact_s:.1f}s")
    else:
        headline = f"phase returned no results; compact {compact_s:.1f}s"
    return rows, headline


if __name__ == "__main__":
    rows, headline = mutate_burst()
    for r in rows:
        print(r)
    print(headline)
