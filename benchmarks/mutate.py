"""Streaming-mutation benchmark: serve throughput + achieved recall
across an insert/delete burst, before and after recalibration and
compaction (the repro.mutate subsystem's end-to-end cost story).

Phases (all served through the slot-pool DarthServer at mixed declared
targets):
  pre-burst         frozen index, freshly fit predictor
  post-burst        +20% inserts (30% drifted/OOD), -10% deletes; the
                    predictor is still the frozen-index fit
  post-recalibrate  drift monitor refit + hot-swap
  post-compact      delta folded into the base, empty ring

Each phase reports host-side qps, mean achieved recall per declared
target against FRESH ground truth over the live base+delta set, and the
mean distance count.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro import mutate
from repro.core import api, engines
from repro.data import vectors
from repro.index import flat, ivf
from repro.serve import DarthServer

K = 10
TARGETS = (0.8, 0.9, 0.95)


def mutate_burst(n: int = 20_000, d: int = 32, queries: int = 384):
    ds = vectors.make_dataset(n=n, d=d, num_learn=2_000,
                              num_queries=queries, clusters=128,
                              cluster_std=1.3, seed=0)
    index = ivf.build(ds.base, nlist=128, seed=0)
    mut = mutate.MutableIndex(
        index, capacity=-(-int(0.2 * n) // 128) * 128)

    def make_engine(**kw):
        return engines.mutable_engine(
            engines.ivf_engine(mut.base, **kw), mut.delta)

    darth = api.Darth(make_engine=make_engine,
                      engine=make_engine(k=K, nprobe=128))
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base))

    rng = np.random.default_rng(0)
    r_targets = rng.choice(TARGETS, size=queries).astype(np.float32)
    server = DarthServer(darth.engine, darth.trained.predictor,
                         darth.interval_for_target, num_slots=64)
    monitor = mutate.RecalibrationMonitor(mut, darth, targets=TARGETS,
                                          threshold=0.01)

    rows = []

    def live_gt():
        """Exact live ground truth — memoized on the mutation epoch by
        MutableIndex itself (post-burst and post-recalibrate share one
        live set, so they share one scan)."""
        return mut.live_ground_truth(ds.queries, K)

    def phase(label):
        t0 = time.time()
        results, stats = server.serve(ds.queries, r_targets)
        dt = time.time() - t0
        done = np.array([i for i, r in enumerate(results)
                         if r is not None])
        if done.size == 0:
            rows.append({"phase": label, "qps": 0.0,
                         "seconds": round(dt, 2), "error": "no results"})
            return rows[-1]
        ids = np.stack([results[i][1] for i in done])
        gt = live_gt()[done]
        rec = np.asarray(flat.recall_at_k(jnp.asarray(ids),
                                          jnp.asarray(gt)))
        monitor.observe(ds.queries[done], r_targets[done], ids)
        row = {"phase": label, "qps": round(len(done) / dt, 1),
               "seconds": round(dt, 2),
               "slot_steps": stats.slot_steps}
        for t in TARGETS:
            sel = r_targets[done] == np.float32(t)
            # null (not NaN) when a target drew no completed queries —
            # results/benchmarks.json must stay standard JSON
            row[f"recall@{t}"] = (round(float(rec[sel].mean()), 4)
                                  if sel.any() else None)
        rows.append(row)
        return row

    phase("pre-burst")

    events = vectors.mutation_stream(ds, insert_pct=0.2, delete_pct=0.1,
                                     drift=0.3, steps=6, seed=1)
    t0 = time.time()
    mut.apply(events)
    mutate_s = time.time() - t0
    server.set_engine(make_engine(k=K, nprobe=128),
                      contents_only=True)
    darth.engine = server.engine
    burst = phase("post-burst")

    rep = monitor.drift()
    t0 = time.time()
    monitor.recalibrate(ds.learn, server=server)
    recal_s = time.time() - t0
    phase("post-recalibrate")

    t0 = time.time()
    mut.compact()
    compact_s = time.time() - t0
    server.set_engine(make_engine(k=K, nprobe=128),
                      contents_only=True)
    darth.engine = server.engine
    final = phase("post-compact")

    rows.append({"phase": "costs", "mutate_seconds": round(mutate_s, 2),
                 "recalibrate_seconds": round(recal_s, 2),
                 "compact_seconds": round(compact_s, 2),
                 "drift_worst_gap": round(rep.worst_gap, 4),
                 "num_live": mut.num_live})
    if "recall@0.9" in burst and "recall@0.9" in final:
        headline = (f"post-burst r@.9 {burst['recall@0.9']:.3f} -> "
                    f"post-compact {final['recall@0.9']:.3f}; "
                    f"compact {compact_s:.1f}s")
    else:
        headline = f"phase returned no results; compact {compact_s:.1f}s"
    return rows, headline


def mutate_online_compaction(n: int = 8_000, d: int = 24,
                             queries: int = 320, slots: int = 8,
                             ticks_per_boundary: int = 1):
    """p99-during-compaction: serve a query stream while a mutation
    stream lands one event per chunk boundary, then fold the delta —
    three ways:

      baseline    mutations only, never compacts (the latency floor)
      background  incremental rebuild ticked at boundaries, atomic
                  hot-swap at a drained boundary (the tentpole path)
      sync        stop-the-world compact() inside one boundary (the
                  old behavior, kept as the spike to beat)

    Boundary-to-boundary wall times come from an on_boundary timestamp
    hook, so the host-side tick work IS inside the measured latency.
    Each interval is tagged with the action taken at its opening
    boundary; the gate compares the p99 of the DURING-COMPACTION window
    (begin + tick intervals) against the baseline's overall p99 — the
    swap boundary itself is reported separately as `swap_stall_ms`
    (its cost is the one-time XLA recompile for the grown base shapes,
    paid once at cutover, not per-chunk while rebuilding).

    Gates: background during-compaction p99 <= 1.5x baseline p99, all
    queries complete, and the post-swap base must be EXACTLY equal
    (arrays + served topk_d/topk_i/ndis at hosts {1, 2}) to a
    from-scratch synchronous rebuild."""
    import jax

    from repro import dist
    from repro.launch import mesh as mesh_lib

    ds = vectors.make_dataset(n=n, d=d, num_learn=1_000,
                              num_queries=queries, clusters=64,
                              cluster_std=1.3, seed=0)
    index = ivf.build(ds.base, nlist=64, seed=0)
    cap = -(-int(0.15 * n) // 128) * 128
    events = vectors.mutation_stream(ds, insert_pct=0.15, delete_pct=0.05,
                                     drift=0.3, steps=6, seed=1)

    mut0 = mutate.MutableIndex(index, capacity=cap)

    def make_engine(mut, **kw):
        return engines.mutable_engine(
            engines.ivf_engine(mut.base, **kw), mut.delta)

    darth = api.Darth(
        make_engine=lambda **kw: make_engine(mut0, **kw),
        engine=make_engine(mut0, k=K, nprobe=64))
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base))
    rng = np.random.default_rng(0)
    r_targets = rng.choice(TARGETS, size=queries).astype(np.float32)

    # Reference from-scratch rebuild FIRST: it is both the equality
    # oracle and the jit warmup for the compaction shapes, so the timed
    # background run measures tick work, not compile time.
    ref = mutate.MutableIndex(index, capacity=cap)
    ref.apply(events)
    ref.compact()

    rows = []

    def run_mode(mode: str):
        mut = mutate.MutableIndex(index, capacity=cap)
        server = DarthServer(make_engine(mut, k=K, nprobe=64),
                             darth.trained.predictor,
                             darth.interval_for_target, num_slots=slots)
        ev = list(events)
        stamps = []
        tags = []
        state = {"swapped": False}

        def on_boundary(srv):
            stamps.append(time.perf_counter())
            if srv.swap_pending or state["swapped"]:
                tags.append("drain" if srv.swap_pending else "idle")
                return
            if ev:
                tags.append("event")
                e = ev.pop(0)
                mut.apply([e])
                srv.set_engine(mutate.refresh_view(
                    srv.engine,
                    base=mut.base if e.kind == "delete" else None,
                    delta=mut.delta), contents_only=True)
            elif mode == "baseline":
                tags.append("idle")
            elif mode == "sync":
                tags.append("sync_compact")
                mut.compact()          # stop-the-world, inside a boundary
                srv.request_swap(make_engine(mut, k=K, nprobe=64),
                                 contents_only=True)
                state["swapped"] = True
            elif not mut.compacting:
                tags.append("begin")
                mut.begin_compaction()
            else:
                done = False
                for _ in range(ticks_per_boundary):
                    done = mut.compact_tick()
                    if done:
                        break
                if done:
                    tags.append("swap_req")
                    mut.swap_compaction()
                    srv.request_swap(make_engine(mut, k=K, nprobe=64),
                                     contents_only=True)
                    state["swapped"] = True
                else:
                    tags.append("tick")

        results, stats = server.serve(ds.queries, r_targets,
                                      on_boundary=on_boundary)
        # leftovers (short serve phase) drain off-clock — same
        # generator code path, so the folded base is identical
        if ev:
            mut.apply(ev)
            ev.clear()
        if mode != "baseline" and not state["swapped"]:
            if mut.compacting:
                while not mut.compact_tick():
                    pass
                mut.swap_compaction()
            else:
                mut.compact()
        deltas = np.diff(np.asarray(stamps)) * 1e3
        # interval i (stamps[i] -> stamps[i+1]) carries the cost of the
        # action taken at its OPENING boundary plus one chunk step
        by_tag = {}
        for t, ms in zip(tags[:-1], deltas):
            by_tag.setdefault(t, []).append(float(ms))
        window = by_tag.get("begin", []) + by_tag.get("tick", [])
        # the swap boundary: request + drain + the apply's one-time
        # recompile for the grown base shapes
        stall = (by_tag.get("swap_req", []) + by_tag.get("drain", [])
                 + by_tag.get("sync_compact", []))
        ndone = sum(1 for r in results if r is not None)
        rows.append({"mode": mode,
                     "boundaries": len(stamps),
                     "p50_ms": round(float(np.percentile(deltas, 50)), 2),
                     "p99_ms": round(float(np.percentile(deltas, 99)), 2),
                     "compaction_window_p99_ms":
                         (round(float(np.percentile(window, 99)), 2)
                          if window else None),
                     "swap_stall_ms": (round(max(stall), 2)
                                       if stall else None),
                     "swaps": stats.swaps,
                     "swapped_mid_serve": state["swapped"],
                     "completed": ndone})
        return mut, rows[-1]

    _, base_row = run_mode("baseline")
    mut_bg, bg_row = run_mode("background")
    _, sync_row = run_mode("sync")

    # -- gate 1: no stop-the-world pause in the background path --------
    p99_base = base_row["p99_ms"]
    p99_bg = bg_row["compaction_window_p99_ms"]
    if bg_row["completed"] != queries:
        raise RuntimeError(
            f"background mode completed {bg_row['completed']}/{queries}")
    if p99_bg is None:
        raise RuntimeError("background compaction never overlapped the "
                           "serve phase — no window to measure")
    if p99_bg > 1.5 * p99_base:
        raise RuntimeError(
            f"background compaction p99 {p99_bg:.1f}ms exceeds 1.5x "
            f"no-compaction baseline {p99_base:.1f}ms")

    # -- gate 2: post-swap base EXACTLY equals a from-scratch rebuild --
    for field in ("centroids", "bucket_vecs", "bucket_ids",
                  "bucket_sqnorm"):
        a = np.asarray(getattr(mut_bg.base, field))
        b = np.asarray(getattr(ref.base, field))
        if not np.array_equal(a, b):
            raise RuntimeError(f"post-swap base.{field} differs from "
                               f"the from-scratch rebuild")

    # -- gate 3: served results identical at hosts {1, 2}, through the
    # sharded multi-host mesh when the device pool allows it ----------
    def parity_serve(mut, hosts):
        mesh = (mesh_lib.make_serve_mesh(hosts, 2)
                if jax.device_count() >= hosts * 2 else None)
        if mesh is not None:
            view = dist.place_index(mut.view(), mesh)
            eng = engines.mutable_engine(
                engines.sharded_ivf_engine(view.base, mesh,
                                           k=K, nprobe=64), view.delta)
        else:
            eng = make_engine(mut, k=K, nprobe=64)
        srv = DarthServer(eng, darth.trained.predictor,
                          darth.interval_for_target, num_slots=slots,
                          mesh=mesh, hosts=hosts)
        results, stats = srv.serve(ds.queries, r_targets)
        return results, stats, mesh is not None

    parity = {}
    for h in (1, 2):
        res_bg, st_bg, meshed = parity_serve(mut_bg, h)
        res_ref, st_ref, _ = parity_serve(ref, h)
        for qi, (a, b) in enumerate(zip(res_bg, res_ref)):
            if (a is None) != (b is None):
                raise RuntimeError(f"hosts={h} q{qi}: completion differs")
            if a is not None and not (np.array_equal(a[0], b[0])
                                      and np.array_equal(a[1], b[1])):
                raise RuntimeError(f"hosts={h} q{qi}: topk differs "
                                   f"between swapped and rebuilt index")
        if st_bg.ndis_harvested != st_ref.ndis_harvested:
            raise RuntimeError(
                f"hosts={h}: ndis {st_bg.ndis_harvested} != "
                f"{st_ref.ndis_harvested}")
        parity[h] = {"ndis": st_bg.ndis_harvested, "sharded": meshed}
    rows.append({"mode": "parity", "hosts": {str(h): v for h, v
                                             in parity.items()},
                 "base_fields_equal": True})

    stall_bg = bg_row["swap_stall_ms"] or 0.0
    stall_sync = sync_row["swap_stall_ms"] or 0.0
    headline = (f"compacting p99 {p99_bg:.0f}ms vs base {p99_base:.0f}ms"
                f"; cutover stall bg {stall_bg:.0f}ms vs sync "
                f"{stall_sync:.0f}ms; {bg_row['swaps']} swap(s); "
                f"parity@hosts{{1,2}} ok")
    return rows, headline


if __name__ == "__main__":
    from benchmarks.artifact import write_bench_artifact
    out = {}
    for fn in (mutate_burst, mutate_online_compaction):
        rows, headline = fn()
        for r in rows:
            print(r)
        print(headline)
        out[fn.__name__] = {"headline": headline, "rows": rows}
    print("wrote", write_bench_artifact(out))
