"""Benchmark harness (deliverable d): one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes results/benchmarks.json
plus a per-commit results/BENCH_<utc-timestamp>.json artifact (same
payload + git metadata) so nightly runs accumulate a comparable series.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import time
import traceback


def _git_meta() -> dict:
    """Best-effort commit metadata for the per-commit artifact."""
    meta = {}
    for key, cmd in (("commit", ["git", "rev-parse", "HEAD"]),
                     ("branch", ["git", "rev-parse", "--abbrev-ref",
                                 "HEAD"])):
        try:
            meta[key] = subprocess.run(
                cmd, capture_output=True, text=True, timeout=10,
                check=True).stdout.strip()
        except Exception:
            meta[key] = "unknown"
    return meta


def main() -> None:
    from benchmarks import dist_search, mutate, obs, paper_tables as pt

    benches = [
        ("obs_tracing_overhead", obs.obs_tracing_overhead),
        ("dist_sharded_search", dist_search.dist_sharded_search),
        ("dist_sharded_ivf_probe", dist_search.dist_sharded_ivf_probe),
        ("dist_sharded_hnsw_beam", dist_search.dist_sharded_hnsw_beam),
        ("dist_multi_host_serve", dist_search.dist_multi_host_serve),
        ("dist_difficulty_serve", dist_search.dist_difficulty_serve),
        ("mutate_burst", mutate.mutate_burst),
        ("mutate_online_compaction", mutate.mutate_online_compaction),
        ("table5_predictor_quality", pt.table5_predictor_quality),
        ("table4_training_cost", pt.table4_training_cost),
        ("fig5_interval_ablation", pt.fig5_interval_ablation),
        ("fig6_speedups_hnsw", lambda: pt.fig6_darth_speedups("hnsw")),
        ("fig19_speedups_ivf", lambda: pt.fig6_darth_speedups("ivf")),
        ("fig8_optimality_ivf", lambda: pt.fig8_optimality("ivf")),
        ("fig10_competitors", pt.fig10_competitors),
        ("fig11_hardness", pt.fig11_hardness),
        ("fig18_ood", pt.fig18_ood),
        ("feature_ablation", pt.feature_ablation),
        ("model_selection", pt.model_selection),
        ("serving_compaction", pt.serving_compaction),
    ]

    all_out = {}
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.time()
        try:
            rows, headline = fn()
            status = "ok"
        except Exception as e:  # pragma: no cover
            rows, headline = [], f"ERROR {type(e).__name__}: {e}"
            status = "error"
            traceback.print_exc()
        dt = time.time() - t0
        us = dt * 1e6
        all_out[name] = {"status": status, "seconds": round(dt, 1),
                         "headline": headline, "rows": rows}
        print(f"{name},{us:.0f},{headline}", flush=True)

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_out, f, indent=1, default=str)
    # per-commit artifact: same payload stamped with git metadata and a
    # UTC timestamp in the filename, so CI uploads keep one comparable
    # file per run instead of overwriting the series
    stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
        "%Y%m%dT%H%M%SZ")
    artifact = {"meta": {**_git_meta(), "timestamp_utc": stamp},
                "benchmarks": all_out}
    with open(f"results/BENCH_{stamp}.json", "w") as f:
        json.dump(artifact, f, indent=1, default=str)
    print(f"wrote results/benchmarks.json + results/BENCH_{stamp}.json")
    n_err = sum(1 for v in all_out.values() if v["status"] != "ok")
    if n_err:
        raise SystemExit(f"{n_err} benchmarks failed")


if __name__ == "__main__":
    main()
