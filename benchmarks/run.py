"""Benchmark harness (deliverable d): one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes results/benchmarks.json
plus a per-commit results/BENCH_<utc-timestamp>.json artifact (same
payload + git metadata incl. a dirty flag, via benchmarks.artifact) on
EVERY invocation — nightly and local runs alike — so the perf series
accumulates one comparable point per run.
"""
from __future__ import annotations

import json
import os
import time
import traceback

from benchmarks.artifact import write_bench_artifact


def main() -> None:
    from benchmarks import dist_search, mutate, obs, paper_tables as pt

    benches = [
        ("obs_tracing_overhead", obs.obs_tracing_overhead),
        ("dist_sharded_search", dist_search.dist_sharded_search),
        ("dist_sharded_ivf_probe", dist_search.dist_sharded_ivf_probe),
        ("dist_sharded_hnsw_beam", dist_search.dist_sharded_hnsw_beam),
        ("dist_residency", dist_search.dist_residency),
        ("dist_multi_host_serve", dist_search.dist_multi_host_serve),
        ("dist_difficulty_serve", dist_search.dist_difficulty_serve),
        ("mutate_burst", mutate.mutate_burst),
        ("mutate_online_compaction", mutate.mutate_online_compaction),
        ("table5_predictor_quality", pt.table5_predictor_quality),
        ("table4_training_cost", pt.table4_training_cost),
        ("fig5_interval_ablation", pt.fig5_interval_ablation),
        ("fig6_speedups_hnsw", lambda: pt.fig6_darth_speedups("hnsw")),
        ("fig19_speedups_ivf", lambda: pt.fig6_darth_speedups("ivf")),
        ("fig8_optimality_ivf", lambda: pt.fig8_optimality("ivf")),
        ("fig10_competitors", pt.fig10_competitors),
        ("fig11_hardness", pt.fig11_hardness),
        ("fig18_ood", pt.fig18_ood),
        ("feature_ablation", pt.feature_ablation),
        ("model_selection", pt.model_selection),
        ("serving_compaction", pt.serving_compaction),
    ]

    all_out = {}
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.time()
        try:
            rows, headline = fn()
            status = "ok"
        except Exception as e:  # pragma: no cover
            rows, headline = [], f"ERROR {type(e).__name__}: {e}"
            status = "error"
            traceback.print_exc()
        dt = time.time() - t0
        us = dt * 1e6
        all_out[name] = {"status": status, "seconds": round(dt, 1),
                         "headline": headline, "rows": rows}
        print(f"{name},{us:.0f},{headline}", flush=True)

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_out, f, indent=1, default=str)
    path = write_bench_artifact(all_out)
    print(f"wrote results/benchmarks.json + {path}")
    n_err = sum(1 for v in all_out.values() if v["status"] != "ok")
    if n_err:
        raise SystemExit(f"{n_err} benchmarks failed")


if __name__ == "__main__":
    main()
