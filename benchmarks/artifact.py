"""Per-commit benchmark artifact writer shared by every invocation.

The per-commit perf trajectory only works if EVERY benchmark run —
nightly lane, local `python -m benchmarks.run`, or a single module's
`__main__` — leaves a `results/BENCH_<utc>.json` behind with enough
metadata (commit hash + git-clean flag) to place it on the series.
CI uploads whatever matches `results/BENCH_*.json`.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
from typing import Dict


def git_meta() -> dict:
    """Best-effort commit metadata: hash, branch, and a `dirty` flag so
    artifacts from uncommitted working trees are never mistaken for the
    commit's true numbers."""
    meta: Dict[str, object] = {}
    for key, cmd in (("commit", ["git", "rev-parse", "HEAD"]),
                     ("branch", ["git", "rev-parse", "--abbrev-ref",
                                 "HEAD"])):
        try:
            meta[key] = subprocess.run(
                cmd, capture_output=True, text=True, timeout=10,
                check=True).stdout.strip()
        except Exception:
            meta[key] = "unknown"
    try:
        status = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        meta["dirty"] = bool(status)
    except Exception:
        meta["dirty"] = None     # unknown: not a git checkout
    return meta


def write_bench_artifact(all_out: dict, *,
                         results_dir: str = "results") -> str:
    """Write `<results_dir>/BENCH_<utc>.json` stamping `all_out` (a
    {bench_name: payload} dict) with git metadata. Returns the path."""
    os.makedirs(results_dir, exist_ok=True)
    stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
        "%Y%m%dT%H%M%SZ")
    path = os.path.join(results_dir, f"BENCH_{stamp}.json")
    payload = {"meta": {**git_meta(), "timestamp_utc": stamp},
               "benchmarks": all_out}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path
