"""Sharded flat-search benchmark: wall time + HLO collective-traffic
accounting (utils/hlo.collective_bytes) for the cross-shard top-k merge.

Run standalone with forced placeholder devices to see real shard counts:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.dist_search
"""
from __future__ import annotations

import time

import numpy as np


def dist_sharded_search(n: int = 20_000, d: int = 32, b: int = 256,
                        k: int = 10):
    import jax.numpy as jnp

    from repro import dist
    from repro.index import flat
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)

    fn = dist.make_sharded_flat_search(mesh, k)
    compiled = fn.lower(q, x).compile()  # single compile serves run + HLO
    coll = hlo_lib.collective_bytes(compiled.as_text())

    d_sh, i_sh = compiled(q, x)
    d_sh.block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        d_sh, i_sh = compiled(q, x)
    d_sh.block_until_ready()
    us_per_batch = (time.time() - t0) / reps * 1e6

    d_ref, i_ref = flat.search(q, x, k)
    err = float(np.max(np.abs(np.asarray(d_sh) - np.asarray(d_ref))))
    recall = float(np.mean(np.asarray(
        flat.recall_at_k(i_sh, i_ref))))

    rows = [{
        "shards": shards, "n": n, "batch": b, "k": k,
        "collective_bytes_per_batch": coll["total"],
        "collective_ops": coll["num_ops"],
        "us_per_batch": round(us_per_batch),
        "max_abs_err_vs_flat": err, "recall_vs_flat": recall,
    }]
    headline = (f"{shards} shard(s): {coll['total']/1e3:.1f} kB "
                f"collectives/batch, err {err:.2e}, recall {recall:.4f}")
    return rows, headline


def dist_sharded_ivf_probe(n: int = 20_000, d: int = 32, b: int = 64,
                           k: int = 10, nlist: int = 64, nprobe: int = 8):
    """Sharded IVF probe: collective traffic of the shard_map fast path
    (per-shard bucket_topk + [B, k] all-gather merge) vs driving the
    plain probe_step over the same cap-sharded index through GSPMD
    gathers, plus numeric parity against single-device ivf.search."""
    import jax
    import jax.numpy as jnp

    from repro import dist
    from repro.index import flat, ivf
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)

    index = ivf.build(x, nlist=nlist, seed=0)
    placed = dist.place_index(index, mesh)

    # Both steps are jitted taking the index as an explicit argument:
    # closure-captured consts lose their committed shardings, which
    # would hide the GSPMD traffic (and replicate the bucket store).
    step = dist.collectives.make_sharded_probe_step(mesh)
    s0 = ivf.init_state(placed, q, k=k, nprobe=nprobe)
    fast_c = step.lower(placed, s0).compile()
    coll_fast = hlo_lib.collective_bytes(fast_c.as_text())
    coll_gspmd = hlo_lib.collective_bytes(
        ivf.probe_step.lower(placed, s0).compile().as_text())

    s = fast_c(placed, s0)
    s.topk_d.block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        s = fast_c(placed, s0)
    s.topk_d.block_until_ready()
    us_per_step = (time.time() - t0) / reps * 1e6

    d_sh, i_sh, _ = ivf.search_sharded(placed, q, k=k, nprobe=nprobe,
                                       mesh=mesh)
    d_ref, i_ref, _ = ivf.search(index, q, k=k, nprobe=nprobe)
    ids_eq = bool(np.array_equal(np.asarray(i_sh), np.asarray(i_ref)))
    recall = float(np.mean(np.asarray(flat.recall_at_k(i_sh, i_ref))))

    rows = [{
        "shards": shards, "n": n, "batch": b, "k": k,
        "nlist": nlist, "nprobe": nprobe, "cap": placed.cap,
        "collective_bytes_fast_path": coll_fast["total"],
        "collective_bytes_gspmd_gather": coll_gspmd["total"],
        "us_per_probe_step": round(us_per_step),
        "ids_match_single_device": ids_eq, "recall_vs_single": recall,
    }]
    headline = (f"{shards} shard(s): {coll_fast['total']/1e3:.1f} kB/probe "
                f"shard_map vs {coll_gspmd['total']/1e3:.1f} kB GSPMD, "
                f"ids_eq {ids_eq}")
    return rows, headline


def dist_sharded_hnsw_beam(b: int = 32, k: int = 10, m: int = 8,
                           ef: int = 48):
    """Sharded HNSW beam step: collective traffic of the shard_map fast
    path (per-shard neighbor resolution + [B, M] psum/all-gather
    frontier merge) vs driving the plain beam_step over the same
    row-sharded graph through GSPMD gathers, plus numeric parity against
    single-device hnsw.search. Two (N, D) sizes pin the fast path's
    per-step bytes as independent of N and D (O(B*M*shards)), while the
    GSPMD gather baseline scales with D."""
    import jax.numpy as jnp

    from repro import dist
    from repro.index import hnsw
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    step = dist.collectives.make_sharded_beam_step(mesh)
    rng = np.random.default_rng(0)

    rows = []
    for n, d in ((4000, 16), (8000, 32)):
        x = rng.normal(size=(n, d)).astype(np.float32)
        q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
        index = hnsw.build(x, m=m, passes=1, ef_construction=32, seed=0)
        placed = dist.place_index(index, mesh)

        s0 = hnsw.init_state(placed, q, ef=ef)
        fast_c = step.lower(placed, s0, k=k).compile()
        coll_fast = hlo_lib.collective_bytes(fast_c.as_text())
        coll_gspmd = hlo_lib.collective_bytes(
            hnsw.beam_step.lower(placed, s0, k=k).compile().as_text())

        s = fast_c(placed, s0)
        s.cand_d.block_until_ready()
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            s = fast_c(placed, s0)
        s.cand_d.block_until_ready()
        us_per_step = (time.time() - t0) / reps * 1e6

        d_sh, i_sh, s_sh = hnsw.search_sharded(placed, q, k=k, ef=ef,
                                               mesh=mesh)
        d_ref, i_ref, s_ref = hnsw.search(index, q, k=k, ef=ef)
        rows.append({
            "shards": shards, "n": n, "d": d, "batch": b, "k": k, "m": m,
            "ef": ef, "n_padded": placed.num_vectors,
            "collective_bytes_fast_path": coll_fast["total"],
            "collective_bytes_gspmd_gather": coll_gspmd["total"],
            "us_per_beam_step": round(us_per_step),
            "ids_match_single_device": bool(np.array_equal(
                np.asarray(i_sh), np.asarray(i_ref))),
            "ndis_match_single_device": bool(np.array_equal(
                np.asarray(s_sh.ndis), np.asarray(s_ref.ndis))),
        })

    size_free = rows[0]["collective_bytes_fast_path"] == \
        rows[-1]["collective_bytes_fast_path"]
    headline = (f"{shards} shard(s): "
                f"{rows[-1]['collective_bytes_fast_path']/1e3:.1f} kB/step "
                f"shard_map (N/D-independent: {size_free}) vs "
                f"{rows[-1]['collective_bytes_gspmd_gather']/1e3:.1f} kB "
                f"GSPMD, ids_eq "
                f"{all(r['ids_match_single_device'] for r in rows)}")
    return rows, headline


def dist_multi_host_serve(n: int = 20_000, d: int = 32, k: int = 10,
                          nlist: int = 64, nprobe: int = 16,
                          slots: int = 64, steps_per_sync: int = 4,
                          stream: int = 128):
    """Multi-host slot-pool serve traffic: per-chunk collective bytes of
    the jitted run_chunk on a ("hosts", "model") serve mesh (slot dim
    split over host groups, index global per group) vs the
    single-controller server on a ("model",)-only mesh. The slot split
    halves the probe shard_map's all-gather operands ([B, ..] ->
    [B/hosts, ..] per group) but adds cross-host reshards of the
    replicated frontier bookkeeping (merge_topk inputs, the due.any()
    predicate) — the nightly entry tracks that balance so a regression
    in either direction is visible; a short serve stream sanity-checks
    that the per-host loops actually drain their stripes."""
    import jax
    import jax.numpy as jnp

    from repro import dist
    from repro.core import engines
    from repro.core.intervals import IntervalParams
    from repro.index import ivf
    from repro.launch import mesh as mesh_lib
    from repro.serve import DarthServer
    from repro.utils import hlo as hlo_lib

    ndev = jax.device_count()
    hosts = 2 if ndev >= 8 else 1
    shards = 4 if ndev >= 8 else max(ndev // max(hosts, 1), 1)

    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    index = ivf.build(x, nlist=nlist, seed=0)

    # Predictor/interval stubs: the chunk's collective traffic does not
    # depend on trained values, only on shapes and the engine step.
    def predictor(feats):
        return jnp.full((feats.shape[0],), 0.5, jnp.float32)

    def interval_for_target(rt):
        rt = np.atleast_1d(rt)
        return IntervalParams(ipi=np.full(rt.shape, 64.0, np.float32),
                              mpi=np.full(rt.shape, 8.0, np.float32))

    def measure(mesh, host_loops, label):
        placed = dist.place_index(index, mesh)
        eng = engines.sharded_ivf_engine(placed, mesh, k=k, nprobe=nprobe)
        server = DarthServer(eng, predictor, interval_for_target,
                             num_slots=slots,
                             steps_per_sync=steps_per_sync,
                             mesh=mesh, hosts=host_loops)
        qb = rng.normal(size=(slots, d)).astype(np.float32)
        rt = np.full((slots,), 0.9, np.float32)
        ipi = np.full((slots,), 64.0, np.float32)
        mpi = np.full((slots,), 8.0, np.float32)
        st = server._init_chunk(eng.index, server._put(qb),
                                server._put(ipi), server._put(mpi))
        compiled = server._run_chunk.lower(
            eng.index, st, server._put(rt), server._put(ipi),
            server._put(mpi)).compile()
        coll = hlo_lib.collective_bytes(compiled.as_text())

        q = rng.normal(size=(stream, d)).astype(np.float32)
        t0 = time.time()
        results, stats = server.serve(q, np.full((stream,), 0.9,
                                                 np.float32))
        dt = time.time() - t0
        assert stats.completed == stream
        return {
            "topology": label, "hosts": host_loops,
            "shards": int(mesh.shape["model"]), "slots": slots,
            "steps_per_sync": steps_per_sync,
            "collective_bytes_per_chunk": coll["total"],
            "collective_ops_per_chunk": coll["num_ops"],
            "stream_qps": round(stream / max(dt, 1e-9), 1),
            "per_host_completed": [h.completed for h in stats.hosts],
        }

    rows = [
        measure(mesh_lib.make_search_mesh(shards), 1,
                "single-controller"),
        measure(mesh_lib.make_serve_mesh(hosts, shards), hosts,
                "multi-host"),
    ]
    sc, mh = rows[0], rows[1]
    ratio = (mh["collective_bytes_per_chunk"]
             / max(sc["collective_bytes_per_chunk"], 1))
    headline = (f"{hosts} host(s) x {shards} shard(s): "
                f"{mh['collective_bytes_per_chunk']/1e3:.1f} kB/chunk "
                f"multi-host vs "
                f"{sc['collective_bytes_per_chunk']/1e3:.1f} kB "
                f"single-controller ({ratio:.2f}x)")
    return rows, headline


if __name__ == "__main__":
    for fn in (dist_sharded_search, dist_sharded_ivf_probe,
               dist_sharded_hnsw_beam, dist_multi_host_serve):
        rows, headline = fn()
        print(headline)
        for r in rows:
            print(r)
