"""Sharded flat-search benchmark: wall time + HLO collective-traffic
accounting (utils/hlo.collective_bytes) for the cross-shard top-k merge.

Run standalone with forced placeholder devices to see real shard counts:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.dist_search
"""
from __future__ import annotations

import time

import numpy as np


def dist_sharded_search(n: int = 20_000, d: int = 32, b: int = 256,
                        k: int = 10):
    import jax.numpy as jnp

    from repro import dist
    from repro.index import flat
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)

    fn = dist.make_sharded_flat_search(mesh, k)
    compiled = fn.lower(q, x).compile()  # single compile serves run + HLO
    coll = hlo_lib.collective_bytes(compiled.as_text())

    d_sh, i_sh = compiled(q, x)
    d_sh.block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        d_sh, i_sh = compiled(q, x)
    d_sh.block_until_ready()
    us_per_batch = (time.time() - t0) / reps * 1e6

    d_ref, i_ref = flat.search(q, x, k)
    err = float(np.max(np.abs(np.asarray(d_sh) - np.asarray(d_ref))))
    recall = float(np.mean(np.asarray(
        flat.recall_at_k(i_sh, i_ref))))

    rows = [{
        "shards": shards, "n": n, "batch": b, "k": k,
        "collective_bytes_per_batch": coll["total"],
        "collective_ops": coll["num_ops"],
        "us_per_batch": round(us_per_batch),
        "max_abs_err_vs_flat": err, "recall_vs_flat": recall,
    }]
    headline = (f"{shards} shard(s): {coll['total']/1e3:.1f} kB "
                f"collectives/batch, err {err:.2e}, recall {recall:.4f}")
    return rows, headline


if __name__ == "__main__":
    rows, headline = dist_sharded_search()
    print(headline)
    for r in rows:
        print(r)
