"""Sharded flat-search benchmark: wall time + HLO collective-traffic
accounting (utils/hlo.collective_bytes) for the cross-shard top-k merge.

Run standalone with forced placeholder devices to see real shard counts:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.dist_search
"""
from __future__ import annotations

import time

import numpy as np


def dist_sharded_search(n: int = 20_000, d: int = 32, b: int = 256,
                        k: int = 10):
    import jax.numpy as jnp

    from repro import dist
    from repro.index import flat
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)

    fn = dist.make_sharded_flat_search(mesh, k)
    compiled = fn.lower(q, x).compile()  # single compile serves run + HLO
    coll = hlo_lib.collective_bytes(compiled.as_text())

    d_sh, i_sh = compiled(q, x)
    d_sh.block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        d_sh, i_sh = compiled(q, x)
    d_sh.block_until_ready()
    us_per_batch = (time.time() - t0) / reps * 1e6

    d_ref, i_ref = flat.search(q, x, k)
    err = float(np.max(np.abs(np.asarray(d_sh) - np.asarray(d_ref))))
    recall = float(np.mean(np.asarray(
        flat.recall_at_k(i_sh, i_ref))))

    rows = [{
        "shards": shards, "n": n, "batch": b, "k": k,
        "collective_bytes_per_batch": coll["total"],
        "collective_ops": coll["num_ops"],
        "us_per_batch": round(us_per_batch),
        "max_abs_err_vs_flat": err, "recall_vs_flat": recall,
    }]
    headline = (f"{shards} shard(s): {coll['total']/1e3:.1f} kB "
                f"collectives/batch, err {err:.2e}, recall {recall:.4f}")
    return rows, headline


def dist_sharded_ivf_probe(n: int = 20_000, d: int = 32, b: int = 64,
                           k: int = 10, nlist: int = 64, nprobe: int = 8):
    """Sharded IVF probe: collective traffic of the shard_map fast path
    (per-shard bucket_topk + [B, k] all-gather merge) vs driving the
    plain probe_step over the same cap-sharded index through GSPMD
    gathers, plus numeric parity against single-device ivf.search."""
    import jax
    import jax.numpy as jnp

    from repro import dist
    from repro.index import flat, ivf
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)

    index = ivf.build(x, nlist=nlist, seed=0)
    placed = dist.place_index(index, mesh)

    # Both steps are jitted taking the index as an explicit argument:
    # closure-captured consts lose their committed shardings, which
    # would hide the GSPMD traffic (and replicate the bucket store).
    step = dist.collectives.make_sharded_probe_step(mesh)
    s0 = ivf.init_state(placed, q, k=k, nprobe=nprobe)
    fast_c = step.lower(placed, s0).compile()
    coll_fast = hlo_lib.collective_bytes(fast_c.as_text())
    coll_gspmd = hlo_lib.collective_bytes(
        ivf.probe_step.lower(placed, s0).compile().as_text())

    s = fast_c(placed, s0)
    s.topk_d.block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        s = fast_c(placed, s0)
    s.topk_d.block_until_ready()
    us_per_step = (time.time() - t0) / reps * 1e6

    d_sh, i_sh, _ = ivf.search_sharded(placed, q, k=k, nprobe=nprobe,
                                       mesh=mesh)
    d_ref, i_ref, _ = ivf.search(index, q, k=k, nprobe=nprobe)
    ids_eq = bool(np.array_equal(np.asarray(i_sh), np.asarray(i_ref)))
    recall = float(np.mean(np.asarray(flat.recall_at_k(i_sh, i_ref))))

    rows = [{
        "shards": shards, "n": n, "batch": b, "k": k,
        "nlist": nlist, "nprobe": nprobe, "cap": placed.cap,
        "collective_bytes_fast_path": coll_fast["total"],
        "collective_bytes_gspmd_gather": coll_gspmd["total"],
        "us_per_probe_step": round(us_per_step),
        "ids_match_single_device": ids_eq, "recall_vs_single": recall,
    }]
    headline = (f"{shards} shard(s): {coll_fast['total']/1e3:.1f} kB/probe "
                f"shard_map vs {coll_gspmd['total']/1e3:.1f} kB GSPMD, "
                f"ids_eq {ids_eq}")
    return rows, headline


def dist_sharded_hnsw_beam(b: int = 32, k: int = 10, m: int = 8,
                           ef: int = 48):
    """Sharded HNSW beam step: collective traffic of the shard_map fast
    path (per-shard neighbor resolution + [B, M] psum/all-gather
    frontier merge) vs driving the plain beam_step over the same
    row-sharded graph through GSPMD gathers, plus numeric parity against
    single-device hnsw.search. Two (N, D) sizes pin the fast path's
    per-step bytes as independent of N and D (O(B*M*shards)), while the
    GSPMD gather baseline scales with D."""
    import jax.numpy as jnp

    from repro import dist
    from repro.index import hnsw
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    step = dist.collectives.make_sharded_beam_step(mesh)
    rng = np.random.default_rng(0)

    rows = []
    for n, d in ((4000, 16), (8000, 32)):
        x = rng.normal(size=(n, d)).astype(np.float32)
        q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
        index = hnsw.build(x, m=m, passes=1, ef_construction=32, seed=0)
        placed = dist.place_index(index, mesh)

        s0 = hnsw.init_state(placed, q, ef=ef)
        fast_c = step.lower(placed, s0, k=k).compile()
        coll_fast = hlo_lib.collective_bytes(fast_c.as_text())
        coll_gspmd = hlo_lib.collective_bytes(
            hnsw.beam_step.lower(placed, s0, k=k).compile().as_text())

        s = fast_c(placed, s0)
        s.cand_d.block_until_ready()
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            s = fast_c(placed, s0)
        s.cand_d.block_until_ready()
        us_per_step = (time.time() - t0) / reps * 1e6

        d_sh, i_sh, s_sh = hnsw.search_sharded(placed, q, k=k, ef=ef,
                                               mesh=mesh)
        d_ref, i_ref, s_ref = hnsw.search(index, q, k=k, ef=ef)
        rows.append({
            "shards": shards, "n": n, "d": d, "batch": b, "k": k, "m": m,
            "ef": ef, "n_padded": placed.num_vectors,
            "collective_bytes_fast_path": coll_fast["total"],
            "collective_bytes_gspmd_gather": coll_gspmd["total"],
            "us_per_beam_step": round(us_per_step),
            "ids_match_single_device": bool(np.array_equal(
                np.asarray(i_sh), np.asarray(i_ref))),
            "ndis_match_single_device": bool(np.array_equal(
                np.asarray(s_sh.ndis), np.asarray(s_ref.ndis))),
        })

    size_free = rows[0]["collective_bytes_fast_path"] == \
        rows[-1]["collective_bytes_fast_path"]
    headline = (f"{shards} shard(s): "
                f"{rows[-1]['collective_bytes_fast_path']/1e3:.1f} kB/step "
                f"shard_map (N/D-independent: {size_free}) vs "
                f"{rows[-1]['collective_bytes_gspmd_gather']/1e3:.1f} kB "
                f"GSPMD, ids_eq "
                f"{all(r['ids_match_single_device'] for r in rows)}")
    return rows, headline


def dist_residency(b: int = 8, k: int = 10, nlist: int = 32,
                   nprobe: int = 8, m: int = 8, ef: int = 48,
                   visited_width: int = 512):
    """Compact-residency gates (PR 10): the SQ8-resident sharded step
    programs (IVF probe over int8 codes, HNSW beam over int8 codes +
    the fixed-width hashed visited filter) must move the SAME per-step
    collective bytes at N=2048 and N=8192 — candidates, never index
    rows — and the device-resident index bytes must drop >= 3.5x vs
    f32 for the IVF layout at D=64 (the serving dim class the budget
    is written for; the HNSW ratio is reported ungated because its
    f32 row carries the adjacency list both formats keep). Recall at
    the large size shows the quantization + hashed-filter cost the
    conformance tests bound."""
    import jax.numpy as jnp

    from repro import dist
    from repro.index import flat, hnsw, ivf, residency
    from repro.launch import mesh as mesh_lib
    from repro.utils import hlo as hlo_lib

    mesh = mesh_lib.make_search_mesh()
    shards = dist.collectives.shard_count(mesh)
    d = 64
    rng = np.random.default_rng(0)
    rows = []
    coll = {"ivf": {}, "hnsw": {}}
    ratios = {}
    for n in (2048, 8192):
        x = rng.normal(size=(n, d)).astype(np.float32)
        q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)

        index_f = ivf.build(x, nlist=nlist, seed=0)
        index_q = residency.quantize_ivf(index_f)
        placed = dist.place_index(index_q, mesh)
        step = dist.collectives.make_sharded_probe_step(mesh)
        s0 = ivf.init_state(placed, q, k=k, nprobe=nprobe)
        coll["ivf"][n] = hlo_lib.collective_bytes(
            step.lower(placed, s0).compile().as_text())["total"]

        graph_f = hnsw.build(x, m=m, passes=1, ef_construction=32,
                             seed=0)
        graph_q = residency.quantize_hnsw(graph_f)
        gplaced = dist.place_index(graph_q, mesh)
        bstep = dist.collectives.make_sharded_beam_step(mesh)
        gs0 = hnsw.init_state(gplaced, q, ef=ef,
                              visited_width=visited_width)
        coll["hnsw"][n] = hlo_lib.collective_bytes(
            bstep.lower(gplaced, gs0, k=k).compile().as_text())["total"]

        ratios[n] = {
            "ivf": (residency.resident_bytes(index_f)["total"]
                    / residency.resident_bytes(index_q)["total"]),
            "hnsw": (residency.resident_bytes(graph_f)["total"]
                     / residency.resident_bytes(graph_q)["total"]),
        }

        _, gt_i = flat.search(q, jnp.asarray(x), k)

        def recall(i_pred):
            return float(np.mean(np.asarray(
                flat.recall_at_k(i_pred, gt_i))))

        _, i_f32, _ = ivf.search(index_f, q, k=k, nprobe=nprobe)
        _, i_sq8, _ = ivf.search(index_q, q, k=k, nprobe=nprobe)
        _, gi_f32, _ = hnsw.search(graph_f, q, k=k, ef=ef)
        _, gi_sq8, _ = hnsw.search(graph_q, q, k=k, ef=ef,
                                   visited_width=visited_width)
        rows.append({
            "shards": shards, "n": n, "d": d, "k": k,
            "nlist": nlist, "nprobe": nprobe, "m": m, "ef": ef,
            "visited_width": visited_width,
            "ivf_collective_bytes_per_step": coll["ivf"][n],
            "hnsw_collective_bytes_per_step": coll["hnsw"][n],
            "ivf_resident_ratio_f32_over_sq8": round(ratios[n]["ivf"], 3),
            "hnsw_resident_ratio_f32_over_sq8": round(
                ratios[n]["hnsw"], 3),
            "ivf_recall_f32": round(recall(i_f32), 4),
            "ivf_recall_sq8": round(recall(i_sq8), 4),
            "hnsw_recall_f32": round(recall(gi_f32), 4),
            "hnsw_recall_sq8_hashed": round(recall(gi_sq8), 4),
        })

    n_indep = (coll["ivf"][2048] == coll["ivf"][8192]
               and coll["hnsw"][2048] == coll["hnsw"][8192])
    ratio_ok = ratios[8192]["ivf"] >= 3.5
    rows[-1]["gate_collective_bytes_n_independent"] = n_indep
    rows[-1]["gate_ivf_resident_ratio_ge_3_5"] = ratio_ok
    headline = (f"{shards} shard(s): SQ8 steps "
                f"{coll['ivf'][8192]/1e3:.1f} kB ivf / "
                f"{coll['hnsw'][8192]/1e3:.1f} kB hnsw per step, "
                f"N-independent {'PASS' if n_indep else 'FAIL'}; "
                f"resident f32/sq8 {ratios[8192]['ivf']:.2f}x ivf "
                f"(gate>=3.5x {'PASS' if ratio_ok else 'FAIL'}), "
                f"{ratios[8192]['hnsw']:.2f}x hnsw")
    return rows, headline


def dist_multi_host_serve(n: int = 20_000, d: int = 32, k: int = 10,
                          nlist: int = 64, nprobe: int = 16,
                          slots: int = 64, steps_per_sync: int = 4,
                          stream: int = 128):
    """Multi-host slot-pool serve traffic: per-chunk collective bytes of
    the jitted run_chunk on a ("hosts", "model") serve mesh (slot dim
    split over host groups, index global per group) vs the
    single-controller server on a ("model",)-only mesh. With the
    candidate merges pinned inside the shard_map (pin_merge — the TopK
    custom-call cannot be partitioned, so an outside merge forced a
    cross-host all-gather of its operands) the slot split makes every
    per-chunk collective host-group-local, so multi-host bytes must
    come in BELOW single-controller: the nightly gate asserts the ratio
    < 1.05x (gate_pass). A short serve stream sanity-checks that the
    per-host loops actually drain their stripes."""
    import jax
    import jax.numpy as jnp

    from repro import dist
    from repro.core import engines
    from repro.core.intervals import IntervalParams
    from repro.index import ivf
    from repro.launch import mesh as mesh_lib
    from repro.serve import DarthServer
    from repro.utils import hlo as hlo_lib

    ndev = jax.device_count()
    hosts = 2 if ndev >= 8 else 1
    shards = 4 if ndev >= 8 else max(ndev // max(hosts, 1), 1)

    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    index = ivf.build(x, nlist=nlist, seed=0)

    # Predictor/interval stubs: the chunk's collective traffic does not
    # depend on trained values, only on shapes and the engine step.
    def predictor(feats):
        return jnp.full((feats.shape[0],), 0.5, jnp.float32)

    def interval_for_target(rt):
        rt = np.atleast_1d(rt)
        return IntervalParams(ipi=np.full(rt.shape, 64.0, np.float32),
                              mpi=np.full(rt.shape, 8.0, np.float32))

    def measure(mesh, host_loops, label):
        placed = dist.place_index(index, mesh)
        eng = engines.sharded_ivf_engine(placed, mesh, k=k, nprobe=nprobe)
        server = DarthServer(eng, predictor, interval_for_target,
                             num_slots=slots,
                             steps_per_sync=steps_per_sync,
                             mesh=mesh, hosts=host_loops)
        qb = rng.normal(size=(slots, d)).astype(np.float32)
        rt = np.full((slots,), 0.9, np.float32)
        ipi = np.full((slots,), 64.0, np.float32)
        mpi = np.full((slots,), 8.0, np.float32)
        st = server._init_chunk(eng.index, server._put(qb),
                                server._put(ipi), server._put(mpi))
        compiled = server._run_chunk.lower(
            eng.index, st, server._put(rt), server._put(ipi),
            server._put(mpi)).compile()
        coll = hlo_lib.collective_bytes(compiled.as_text())

        q = rng.normal(size=(stream, d)).astype(np.float32)
        t0 = time.time()
        results, stats = server.serve(q, np.full((stream,), 0.9,
                                                 np.float32))
        dt = time.time() - t0
        assert stats.completed == stream
        return {
            "topology": label, "hosts": host_loops,
            "shards": int(mesh.shape["model"]), "slots": slots,
            "steps_per_sync": steps_per_sync,
            "collective_bytes_per_chunk": coll["total"],
            "collective_ops_per_chunk": coll["num_ops"],
            "stream_qps": round(stream / max(dt, 1e-9), 1),
            "per_host_completed": [h.completed for h in stats.hosts],
        }

    rows = [
        measure(mesh_lib.make_search_mesh(shards), 1,
                "single-controller"),
        measure(mesh_lib.make_serve_mesh(hosts, shards), hosts,
                "multi-host"),
    ]
    sc, mh = rows[0], rows[1]
    ratio = (mh["collective_bytes_per_chunk"]
             / max(sc["collective_bytes_per_chunk"], 1))
    # the gate only means something on a genuinely multi-host mesh
    gate_pass = ratio < 1.05 if hosts > 1 else None
    mh["collective_bytes_ratio_vs_single"] = round(ratio, 4)
    mh["gate_ratio_below_1_05"] = gate_pass
    headline = (f"{hosts} host(s) x {shards} shard(s): "
                f"{mh['collective_bytes_per_chunk']/1e3:.1f} kB/chunk "
                f"multi-host vs "
                f"{sc['collective_bytes_per_chunk']/1e3:.1f} kB "
                f"single-controller ({ratio:.2f}x"
                + (f", gate<1.05x {'PASS' if gate_pass else 'FAIL'}"
                   if gate_pass is not None else "")
                + ")")
    return rows, headline


def dist_difficulty_serve(n: int = 20_000, d: int = 32, k: int = 10,
                          nlist: int = 64, nprobe: int = 16,
                          slots: int = 64, steps_per_sync: int = 4,
                          stream: int = 192):
    """Difficulty-aware multi-host serving: per-tier p99 recall/latency
    SLOs (serve.difficulty) through the slot-pool server on the serve
    mesh, plus per-chunk collective bytes with the merge-pinning fix on
    vs off (pin_merge True/False — the pre-fix chunk all-gathered merge
    operands across hosts because the TopK custom-call cannot be
    partitioned). A real DARTH fit drives termination so the reported
    recall percentiles are the predictor's actual harvest estimates."""
    import jax
    import jax.numpy as jnp

    from repro import dist
    from repro.core import api, engines
    from repro.index import flat, ivf
    from repro.launch import mesh as mesh_lib
    from repro.serve import DarthServer, TierConfig
    from repro.utils import hlo as hlo_lib

    ndev = jax.device_count()
    hosts = 2 if ndev >= 8 else 1
    shards = 4 if ndev >= 8 else max(ndev // max(hosts, 1), 1)
    mesh = (mesh_lib.make_serve_mesh(hosts, shards) if hosts > 1
            else mesh_lib.make_search_mesh(shards))

    from repro.data import vectors
    ds = vectors.make_dataset(n=n, d=d, num_learn=1024, num_queries=stream,
                              clusters=nlist, seed=0)
    index = ivf.build(ds.base, nlist=nlist, seed=0)
    placed = dist.place_index(index, mesh)

    def build_engine(**kw):
        return engines.sharded_ivf_engine(placed, mesh, **kw)

    darth = api.Darth(make_engine=build_engine,
                      engine=build_engine(k=k, nprobe=nprobe))
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), mesh=mesh)

    rng = np.random.default_rng(1)
    r_targets = rng.choice([0.8, 0.9, 0.95],
                           size=stream).astype(np.float32)
    tiers = TierConfig(hard_quantile=0.75, hard_slot_fraction=0.25,
                       boost=0.05, hedge=True, rebalance=True)
    server = DarthServer(darth.engine, darth.trained.predictor,
                         darth.interval_for_target, num_slots=slots,
                         steps_per_sync=steps_per_sync, mesh=mesh,
                         hosts=hosts, tiers=tiers)
    t0 = time.time()
    results, stats = server.serve(ds.queries, r_targets)
    dt = time.time() - t0
    assert all(r is not None for r in results)

    # ground-truth recall per tier (the TierStats percentiles are the
    # predictor's estimates; this is the real thing)
    _, gt_i = flat.search(jnp.asarray(ds.queries), jnp.asarray(ds.base), k)
    ids = np.stack([r[1] for r in results])
    rec = np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i))
    from repro.serve import difficulty as difficulty_lib
    is_hard = difficulty_lib.assign_tiers(
        difficulty_lib.difficulty_scores(darth.engine.index, ds.queries),
        tiers)

    # before/after collective bytes of the chunk program
    def chunk_bytes(pin):
        eng = build_engine(k=k, nprobe=nprobe, pin_merge=pin)
        srv = DarthServer(eng, darth.trained.predictor,
                          darth.interval_for_target, num_slots=slots,
                          steps_per_sync=steps_per_sync, mesh=mesh,
                          hosts=hosts)
        qb = rng.normal(size=(slots, d)).astype(np.float32)
        ipi = np.full((slots,), 64.0, np.float32)
        mpi = np.full((slots,), 8.0, np.float32)
        st = srv._init_chunk(eng.index, srv._put(qb), srv._put(ipi),
                             srv._put(mpi))
        rt = np.full((slots,), 0.9, np.float32)
        compiled = srv._run_chunk.lower(
            eng.index, st, srv._put(rt), srv._put(ipi),
            srv._put(mpi)).compile()
        return hlo_lib.collective_bytes(compiled.as_text())["total"]

    bytes_fixed = chunk_bytes(True)
    bytes_prefix = chunk_bytes(False)

    rows = []
    for tier, hard in (("easy", False), ("hard", True)):
        ts = stats.tiers[tier]
        sel = is_hard == hard
        rows.append({
            "topology": f"{hosts}x{shards}", "tier": tier,
            "queries": ts.count,
            "recall_p50_pred": round(ts.recall_p50, 4),
            "recall_p99_pred": round(ts.recall_p99, 4),
            "recall_p50_true": round(float(np.percentile(rec[sel], 50)), 4),
            "recall_p99_true": round(float(np.percentile(rec[sel], 1)), 4),
            "latency_p50_steps": ts.latency_p50,
            "latency_p99_steps": ts.latency_p99,
            "hedged": ts.hedged, "hedge_upgrades": ts.hedge_upgrades,
            "chunk_bytes_pinned_merge": bytes_fixed,
            "chunk_bytes_unpinned_merge": bytes_prefix,
            "chunk_ms_p50": round(stats.chunk_ms_p50, 2),
            "chunk_ms_p99": round(stats.chunk_ms_p99, 2),
            "stream_qps": round(stream / max(dt, 1e-9), 1),
        })
    hard_row = rows[1]
    headline = (f"{hosts} host(s) x {shards} shard(s): hard-tier p99 "
                f"recall {hard_row['recall_p99_true']:.3f} (true) / "
                f"{hard_row['recall_p99_pred']:.3f} (pred), latency p99 "
                f"{hard_row['latency_p99_steps']:.0f} steps; chunk "
                f"{bytes_fixed/1e3:.1f} kB pinned vs "
                f"{bytes_prefix/1e3:.1f} kB unpinned merge")
    return rows, headline


if __name__ == "__main__":
    from benchmarks.artifact import write_bench_artifact
    out = {}
    for fn in (dist_sharded_search, dist_sharded_ivf_probe,
               dist_sharded_hnsw_beam, dist_residency,
               dist_multi_host_serve, dist_difficulty_serve):
        rows, headline = fn()
        print(headline)
        for r in rows:
            print(r)
        out[fn.__name__] = {"headline": headline, "rows": rows}
    print("wrote", write_bench_artifact(out))
