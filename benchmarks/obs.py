"""Tracing-overhead benchmark: the repro.obs contract is that turning
tracing ON costs <= 5% p99 chunk latency (docs/observability.md).

The trajectory ring rides the existing chunk jits and is drained only
at the sync boundaries serve() already pays for, so the only added
work is one [slots, traj_cap] dynamic-index write per engine step plus
host-side span bookkeeping. This benchmark serves the SAME workload
through a traced and an untraced server, interleaved over several
repeats (so CPU frequency / page-cache drift hits both arms equally),
and gates on the ratio of the best-of-repeats p99 chunk wall times.

Run standalone (exits nonzero when the gate fails):
  PYTHONPATH=src python -m benchmarks.obs
"""
from __future__ import annotations

import numpy as np

#: the overhead contract: tracing-on p99 chunk latency <= 1.05x off
OVERHEAD_GATE = 1.05


def _build(tracer=None, metrics=None):
    import jax.numpy as jnp

    from repro.core import api, engines
    from repro.data import vectors
    from repro.index import ivf
    from repro.serve import DarthServer

    ds = vectors.make_dataset(n=8_000, d=16, num_learn=512,
                              num_queries=192, clusters=32, seed=7)
    index = ivf.build(ds.base, nlist=32, seed=7)
    eng = engines.ivf_engine(index, k=10, nprobe=32)
    darth = api.Darth(
        make_engine=lambda **kw: engines.ivf_engine(index, **kw),
        engine=eng)
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    server = DarthServer(darth.engine, darth.trained.predictor,
                         darth.interval_for_target, num_slots=32,
                         steps_per_sync=2, tracer=tracer, metrics=metrics)
    return ds, server


def obs_tracing_overhead(repeats: int = 5):
    """p99 chunk-latency ratio, traced vs untraced, same workload."""
    from repro.obs import Tracer

    ds, base_server = _build()
    tracer = Tracer(traj_cap=64)
    _, traced_server = _build(tracer=tracer)
    rts = np.tile(np.asarray([0.8, 0.9, 0.95], np.float32),
                  ds.queries.shape[0])[:ds.queries.shape[0]]

    # warmup: compile both servers' chunk jits before timing anything
    base_server.serve(ds.queries, rts)
    traced_server.serve(ds.queries, rts)

    p99_off, p99_on = [], []
    for _ in range(repeats):
        _, s_off = base_server.serve(ds.queries, rts)
        _, s_on = traced_server.serve(ds.queries, rts)
        p99_off.append(s_off.chunk_ms_p99)
        p99_on.append(s_on.chunk_ms_p99)
    # best-of-repeats damps scheduler noise on shared CI hosts: the
    # minimum is the least-interfered run of each arm
    off, on = min(p99_off), min(p99_on)
    ratio = on / off if off > 0 else float("nan")
    spans = len(tracer.last_spans)

    rows = [{
        "queries": int(ds.queries.shape[0]), "repeats": repeats,
        "p99_off_ms": round(off, 3), "p99_on_ms": round(on, 3),
        "ratio": round(ratio, 4), "gate": OVERHEAD_GATE,
        "spans_per_serve": spans,
        "passed": bool(ratio <= OVERHEAD_GATE),
    }]
    headline = (f"tracing p99 {on:.2f} ms vs {off:.2f} ms off = "
                f"{ratio:.3f}x (gate {OVERHEAD_GATE}x, {spans} spans)")
    if not rows[0]["passed"]:
        raise AssertionError(
            f"tracing overhead gate failed: p99 ratio {ratio:.3f} > "
            f"{OVERHEAD_GATE} ({on:.3f} ms on vs {off:.3f} ms off)")
    return rows, headline


if __name__ == "__main__":
    from benchmarks.artifact import write_bench_artifact
    out_rows, out_headline = obs_tracing_overhead()
    print(out_headline)
    print("wrote", write_bench_artifact(
        {"obs_tracing_overhead": {"headline": out_headline,
                                  "rows": out_rows}}))
