"""One function per paper table/figure (deliverable d). Each returns
(rows, headline) where rows are dicts for the CSV and headline is the
paper-comparable number."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro import gbdt
from repro.core import (baselines, darth_search, engines, features,
                        intervals, metrics, training)
from repro.data import vectors
from repro.index import flat
from repro.core.predictor import regression_metrics

Rows = List[Dict]


def _run_darth(d, q, rt):
    t0 = time.time()
    dd, ii, st = d.search(q, rt)
    wall = time.time() - t0
    return dd, ii, st, wall


# --- Fig 6 / Fig 19: recall + speedup vs target, both indexes -------------

def fig6_darth_speedups(index_kind: str = "hnsw") -> Tuple[Rows, str]:
    b = common.setup()
    d = b.darth_hnsw if index_kind == "hnsw" else b.darth_ivf
    q = jnp.asarray(b.ds.queries)
    _, _, plain = d.search_plain(q)
    plain_nd = float(np.asarray(plain.ndis).mean())
    t0 = time.time()
    d.search_plain(q)
    plain_wall = time.time() - t0
    rows = []
    speeds = []
    for rt in common.TARGETS:
        dd, ii, st, wall = _run_darth(d, q, rt)
        rec = float(np.asarray(flat.recall_at_k(ii, jnp.asarray(b.gt["i"])
                                                )).mean())
        nd = float(np.asarray(st.inner.ndis).mean())
        speed = plain_nd / max(nd, 1)
        speeds.append(speed)
        rows.append({"target": rt, "recall": round(rec, 4),
                     "mean_ndis": round(nd, 1),
                     "speedup_dists": round(speed, 2),
                     "speedup_wall": round(plain_wall / max(wall, 1e-9), 2),
                     "met": rec >= rt - 0.01,
                     "npred": round(float(np.asarray(st.npred).mean()), 1)})
    headline = (f"speedup(dists) max={max(speeds):.1f}x "
                f"avg={np.mean(speeds):.1f}x median={np.median(speeds):.1f}x")
    return rows, headline


# --- Fig 8: optimality of termination points ------------------------------

def fig8_optimality(index_kind: str = "ivf") -> Tuple[Rows, str]:
    b = common.setup()
    d = b.darth_hnsw if index_kind == "hnsw" else b.darth_ivf
    q = jnp.asarray(b.ds.queries)
    gt_i = jnp.asarray(b.gt["i"])
    # per-query oracle: log the test queries' search, find first step >= Rt
    log = training.generate_observations(d.engine, q, gt_i, batch=512)
    rows = []
    ratios = []
    for rt in common.TARGETS:
        oracle = intervals.dists_to_target(log.recall, log.ndis, log.valid,
                                           rt)
        _, _, st, _ = _run_darth(d, q, rt)
        actual = np.asarray(st.inner.ndis, np.float64)
        ratio = float(actual.mean() / max(oracle.mean(), 1.0))
        ratios.append(ratio)
        rows.append({"target": rt, "oracle_ndis": round(oracle.mean(), 1),
                     "darth_ndis": round(actual.mean(), 1),
                     "overhead": round(ratio - 1.0, 3)})
    headline = f"mean dists vs oracle: +{100*(np.mean(ratios)-1):.0f}%"
    return rows, headline


# --- Table 5: recall predictor quality ------------------------------------

def table5_predictor_quality() -> Tuple[Rows, str]:
    b = common.setup()
    rows = []
    for name, d in (("ivf", b.darth_ivf), ("hnsw", b.darth_hnsw)):
        if d is None:
            continue
        m = d.trained.metrics
        rows.append({"index": name, "mse": round(m["mse"], 5),
                     "mae": round(m["mae"], 5), "r2": round(m["r2"], 3)})
    headline = f"ivf mse={rows[0]['mse']} r2={rows[0]['r2']}"
    return rows, headline


# --- Table 4: training cost -------------------------------------------------

def table4_training_cost() -> Tuple[Rows, str]:
    b = common.setup()
    rows = []
    for name, d in (("ivf", b.darth_ivf), ("hnsw", b.darth_hnsw)):
        if d is None:
            continue
        tr = d.trained
        rows.append({
            "index": name,
            "gen_seconds": round(b.build_seconds.get(f"darth_{name}_fit", 0.0)
                                 - tr.train_seconds, 1),
            "train_seconds": round(tr.train_seconds, 1),
            "train_samples": tr.num_samples,
            "index_build_seconds": round(
                b.build_seconds.get(f"{name}_build", 0.0), 1),
        })
    headline = (f"fit<<build: train={rows[0]['train_seconds']}s vs "
                f"build={rows[0]['index_build_seconds']}s")
    return rows, headline


# --- Fig 5: adaptive vs static intervals, heuristic vs tuned ---------------

def fig5_interval_ablation() -> Tuple[Rows, str]:
    b = common.setup()
    d = b.darth_ivf
    q = jnp.asarray(b.ds.queries)
    rt = 0.90
    dr = d.trained.dists_rt[rt]
    variants = {
        "adaptive_heuristic": intervals.heuristic_params(dr),
        "adaptive_static": intervals.static_params(dr, divisor=4.0),
        "static_small": intervals.IntervalParams(ipi=dr / 10, mpi=dr / 10),
        "static_large": intervals.IntervalParams(ipi=dr, mpi=dr),
    }
    rows = []
    for name, p in variants.items():
        st = darth_search.darth_search(d.engine, q, rt,
                                       d.trained.predictor, p)
        rec = float(np.asarray(flat.recall_at_k(
            d.engine.topk_i(st.inner), jnp.asarray(b.gt["i"]))).mean())
        rows.append({"variant": name,
                     "recall": round(rec, 4),
                     "mean_ndis": round(float(np.asarray(st.inner.ndis)
                                              .mean()), 1),
                     "npred": round(float(np.asarray(st.npred).mean()), 1)})
    base = [r for r in rows if r["variant"] == "adaptive_heuristic"][0]
    headline = (f"adaptive-heuristic ndis={base['mean_ndis']} "
                f"npred={base['npred']}")
    return rows, headline


# --- Fig 10 + 12-16: competitor comparison ---------------------------------

def fig10_competitors(r_target: float = 0.95) -> Tuple[Rows, str]:
    b = common.setup()
    d = b.darth_ivf
    eng = d.engine
    q = jnp.asarray(b.ds.queries)
    gt_i = jnp.asarray(b.gt["i"])
    x = jnp.asarray(b.ds.base)

    # validation split from learn pool for competitor tuning
    q_val = jnp.asarray(b.ds.learn[:512])
    _, gt_val = flat.search(q_val, x, common.K)

    # training log (shared with LAET)
    q_tr = jnp.asarray(b.ds.learn[512:1536])
    _, gt_tr = flat.search(q_tr, x, common.K)
    log = training.generate_observations(eng, q_tr, gt_tr, batch=512)

    runs = {}
    # DARTH
    _, ii, st, _ = _run_darth(d, q, r_target)
    runs["darth"] = (eng.topk_d(st.inner), ii)
    # Baseline: fixed dists_Rt budget
    drt = float(np.mean(intervals.dists_to_target(log.recall, log.ndis,
                                                  log.valid, r_target)))
    inner = darth_search.budget_search(eng, q, drt)
    runs["baseline"] = (eng.topk_d(inner), eng.topk_i(inner))
    # REM: recall -> nprobe mapping
    rem = baselines.fit_rem(
        lambda p: engines.ivf_engine(b.ivf_index, k=common.K, nprobe=p),
        q_val, gt_val, param_grid=[4, 8, 16, 32, 64, 96, 128, 192],
        targets=[r_target])
    eng_rem = engines.ivf_engine(b.ivf_index, k=common.K,
                                 nprobe=rem.mapping[r_target])
    inner = darth_search.plain_search(eng_rem, q)
    runs["rem"] = (eng_rem.topk_d(inner), eng_rem.topk_i(inner))
    # LAET
    laet = baselines.fit_laet(log, n0=2)
    laet = baselines.tune_laet(laet, eng, q_val, gt_val,
                               targets=[r_target], steps=6)
    inner = baselines.laet_search(laet, eng, q,
                                  laet.multipliers[r_target])
    runs["laet"] = (eng.topk_d(inner), eng.topk_i(inner))

    rows = []
    for name, (dd, ii) in runs.items():
        m = metrics.summarize(np.asarray(dd), np.asarray(ii),
                              b.gt["d"], b.gt["i"], b.gt["wide_i"], r_target)
        m = {k: round(v, 4) for k, v in m.items()}
        rows.append({"method": name, **m})
    darth_row = [r for r in rows if r["method"] == "darth"][0]
    best_rqut = min(r["rqut"] for r in rows)
    headline = (f"DARTH rqut={darth_row['rqut']} (best={best_rqut}), "
                f"rde={darth_row['rde']}")
    return rows, headline


# --- Fig 11: robustness on noisy (harder) workloads -------------------------

def fig11_hardness(r_target: float = 0.90) -> Tuple[Rows, str]:
    b = common.setup()
    d = b.darth_ivf
    eng = d.engine
    x = jnp.asarray(b.ds.base)
    q_val = jnp.asarray(b.ds.learn[:512])
    _, gt_val = flat.search(q_val, x, common.K)
    q_tr = jnp.asarray(b.ds.learn[512:1536])
    _, gt_tr = flat.search(q_tr, x, common.K)
    log = training.generate_observations(eng, q_tr, gt_tr, batch=512)
    drt = float(np.mean(intervals.dists_to_target(log.recall, log.ndis,
                                                  log.valid, r_target)))
    rem = baselines.fit_rem(
        lambda p: engines.ivf_engine(b.ivf_index, k=common.K, nprobe=p),
        q_val, gt_val, param_grid=[4, 8, 16, 32, 64, 96, 128, 192],
        targets=[r_target])
    laet = baselines.fit_laet(log, n0=2)
    laet = baselines.tune_laet(laet, eng, q_val, gt_val, targets=[r_target],
                               steps=6)

    rows = []
    # sigma^2 = pct * ||q|| (paper formula) is norm-scale dependent; on the
    # unit-ish synthetic norms the paper's 1-30% is imperceptible, so the
    # sweep uses pct values that span easy -> beyond-ceiling hardness here.
    for noise in (0.0, 1.0, 4.0, 10.0, 20.0):
        qn = jnp.asarray(vectors.noisy_queries(b.ds.queries, noise, seed=7))
        _, gt_n = flat.search(qn, x, common.K)
        # attainability ceiling: plain search recall
        plain = darth_search.plain_search(eng, qn)
        ceil = float(np.asarray(flat.recall_at_k(eng.topk_i(plain),
                                                 gt_n)).mean())
        _, ii, st, _ = _run_darth(d, qn, r_target)
        rec_darth = float(np.asarray(flat.recall_at_k(ii, gt_n)).mean())
        inner = darth_search.budget_search(eng, qn, drt)
        rec_base = float(np.asarray(flat.recall_at_k(
            eng.topk_i(inner), gt_n)).mean())
        eng_rem = engines.ivf_engine(b.ivf_index, k=common.K,
                                     nprobe=rem.mapping[r_target])
        inner = darth_search.plain_search(eng_rem, qn)
        rec_rem = float(np.asarray(flat.recall_at_k(
            eng_rem.topk_i(inner), gt_n)).mean())
        inner = baselines.laet_search(laet, eng, qn,
                                      laet.multipliers[r_target])
        rec_laet = float(np.asarray(flat.recall_at_k(
            eng.topk_i(inner), gt_n)).mean())
        rows.append({"noise_pct": noise, "ceiling": round(ceil, 4),
                     "darth": round(rec_darth, 4),
                     "baseline": round(rec_base, 4),
                     "rem": round(rec_rem, 4), "laet": round(rec_laet, 4)})
    # robustness score: mean shortfall vs attainable min(target, ceiling)
    def shortfall(key):
        return np.mean([max(min(r_target, r["ceiling"]) - r[key], 0.0)
                        for r in rows])
    headline = (f"shortfall darth={shortfall('darth'):.3f} "
                f"baseline={shortfall('baseline'):.3f} "
                f"rem={shortfall('rem'):.3f} laet={shortfall('laet'):.3f}")
    return rows, headline


# --- Fig 18/20: OOD workloads ----------------------------------------------

def fig18_ood(r_target: float = 0.90) -> Tuple[Rows, str]:
    b = common.setup()
    d = b.darth_ivf
    eng = d.engine
    x = jnp.asarray(b.ds.base)
    q_ood = jnp.asarray(vectors.ood_queries(b.ds.base.shape[1], 512, seed=9,
                                             cluster_std=1.3))
    _, gt_o = flat.search(q_ood, x, common.K)
    plain = darth_search.plain_search(eng, q_ood)
    ceil = float(np.asarray(flat.recall_at_k(eng.topk_i(plain),
                                             gt_o)).mean())
    plain_nd = float(np.asarray(plain.ndis).mean())
    rows = []
    for rt in (0.80, 0.90, 0.95):
        _, ii, st, _ = _run_darth(d, q_ood, rt)
        rec = float(np.asarray(flat.recall_at_k(ii, gt_o)).mean())
        nd = float(np.asarray(st.inner.ndis).mean())
        rows.append({"target": rt, "recall": round(rec, 4),
                     "ceiling": round(ceil, 4),
                     "speedup_dists": round(plain_nd / max(nd, 1), 2),
                     "met": rec >= min(rt, ceil - 0.01) - 0.02})
    headline = f"OOD: all targets attainable met={all(r['met'] for r in rows)}"
    return rows, headline


# --- §4.1.4 feature ablation -------------------------------------------------

def feature_ablation() -> Tuple[Rows, str]:
    b = common.setup()
    d = b.darth_ivf
    log = d._last_log
    mask = log.valid.reshape(-1)
    xf = log.features.reshape(-1, features.NUM_FEATURES)[mask]
    y = log.recall.reshape(-1)[mask]
    rng = np.random.default_rng(0)
    sel = rng.choice(xf.shape[0], min(300_000, xf.shape[0]), replace=False)
    xf, y = xf[sel], y[sel]
    n_hold = int(0.1 * len(y))
    groups = {
        "index_only": [0, 1, 2],
        "index+nn_dist": [0, 1, 2, 3, 4, 5],
        "index+nn_stats": [0, 1, 2, 6, 7, 8, 9, 10],
        "nn_only": [3, 4, 5, 6, 7, 8, 9, 10],
        "all": list(range(features.NUM_FEATURES)),
    }
    rows = []
    for name, cols in groups.items():
        p = gbdt.fit(xf[n_hold:][:, cols], y[n_hold:],
                     gbdt.GBDTConfig(num_trees=60, depth=5))
        pred = np.asarray(gbdt.predict_jit(p, jnp.asarray(xf[:n_hold][:, cols])))
        m = regression_metrics(pred, y[:n_hold])
        rows.append({"features": name, "mse": round(m["mse"], 5),
                     "r2": round(m["r2"], 3)})
    best = min(rows, key=lambda r: r["mse"])
    headline = f"best={best['features']} mse={best['mse']}"
    return rows, headline


# --- §4.1.5 model selection ---------------------------------------------------

def model_selection() -> Tuple[Rows, str]:
    b = common.setup()
    log = b.darth_ivf._last_log
    mask = log.valid.reshape(-1)
    xf = log.features.reshape(-1, features.NUM_FEATURES)[mask]
    y = log.recall.reshape(-1)[mask]
    rng = np.random.default_rng(0)
    sel = rng.choice(xf.shape[0], min(200_000, xf.shape[0]), replace=False)
    xf, y = xf[sel], y[sel]
    n_hold = int(0.1 * len(y))
    xtr, ytr, xho, yho = xf[n_hold:], y[n_hold:], xf[:n_hold], y[:n_hold]
    rows = []
    p = gbdt.fit(xtr, ytr, gbdt.GBDTConfig(num_trees=100, depth=6))
    rows.append(("gbdt", gbdt.predict_jit(p, jnp.asarray(xho))))
    p = gbdt.fit_random_forest(xtr[:60_000], ytr[:60_000], num_trees=40,
                               depth=6)
    rows.append(("random_forest", gbdt.predict_jit(p, jnp.asarray(xho))))
    p = gbdt.fit_decision_tree(xtr, ytr, depth=8)
    rows.append(("decision_tree", gbdt.predict_jit(p, jnp.asarray(xho))))
    lm = gbdt.fit_linear(xtr, ytr)
    rows.append(("linear", lm.predict(jnp.asarray(xho))))
    out = []
    for name, pred in rows:
        m = regression_metrics(np.asarray(pred), yho)
        out.append({"model": name, "mse": round(m["mse"], 5),
                    "r2": round(m["r2"], 3)})
    order = [r["model"] for r in sorted(out, key=lambda r: r["mse"])]
    headline = f"ranking={order}"
    return out, headline


# --- beyond paper: serving engine compaction ---------------------------------

def serving_compaction() -> Tuple[Rows, str]:
    from repro.serve import DarthServer
    b = common.setup()
    d = b.darth_ivf

    q = b.ds.queries
    rts = np.full((q.shape[0],), 0.9, np.float32)
    rows = []
    # no-compaction reference: fixed batches, whole batch runs to slowest
    eng = d.engine
    st = darth_search.darth_search(eng, jnp.asarray(q), 0.9,
                                   d.trained.predictor,
                                   d.interval_params(0.9))
    batch_steps = float(np.asarray(st.steps))  # steps for whole batch
    no_compact_slot_steps = batch_steps * q.shape[0]
    server = DarthServer(eng, d.trained.predictor, d.interval_for_target,
                         num_slots=64, steps_per_sync=2)
    results, stats = server.serve(q, rts)
    rows.append({"mode": "no_compaction",
                 "slot_steps_per_query": round(no_compact_slot_steps
                                               / q.shape[0], 1)})
    rows.append({"mode": "compaction",
                 "slot_steps_per_query": round(stats.slot_steps
                                               / max(stats.completed, 1), 1),
                 "completed": stats.completed, "refills": stats.refills})
    gain = no_compact_slot_steps / max(stats.slot_steps, 1)
    headline = f"compaction throughput gain={gain:.2f}x"
    return rows, headline
