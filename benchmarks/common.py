"""Shared benchmark fixtures: one dataset + indexes + trained DARTH,
built once per process and cached (HNSW build is the expensive part)."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import api, engines
from repro.data import vectors
from repro.index import flat, hnsw, ivf

K = 10
TARGETS = (0.80, 0.85, 0.90, 0.95, 0.99)
SEED = 0


@dataclasses.dataclass
class Bench:
    ds: vectors.VectorDataset
    ivf_index: ivf.IVFIndex
    hnsw_index: Optional[hnsw.HNSWIndex]
    darth_ivf: api.Darth
    darth_hnsw: Optional[api.Darth]
    gt: Dict[str, np.ndarray]
    build_seconds: Dict[str, float]


@functools.lru_cache(maxsize=1)
def setup(with_hnsw: bool = True) -> Bench:
    t = {}
    t0 = time.time()
    ds = vectors.make_dataset(n=40_000, d=32, num_learn=3_000,
                              num_queries=512, clusters=192,
                              cluster_std=1.3, seed=SEED)
    t["dataset"] = time.time() - t0

    t0 = time.time()
    ivf_index = ivf.build(ds.base, nlist=192, seed=SEED)
    t["ivf_build"] = time.time() - t0

    hnsw_index = None
    if with_hnsw:
        t0 = time.time()
        hnsw_index = hnsw.build(ds.base, m=16, passes=1, ef_construction=64,
                                chunk=2048)
        t["hnsw_build"] = time.time() - t0

    q = jnp.asarray(ds.queries)
    x = jnp.asarray(ds.base)
    gt_d, gt_i = flat.search(q, x, K)
    gtw_d, gtw_i = flat.search(q, x, 100)
    gt = {"d": np.asarray(gt_d), "i": np.asarray(gt_i),
          "wide_i": np.asarray(gtw_i)}

    t0 = time.time()
    d_ivf = api.Darth(
        make_engine=lambda **kw: engines.ivf_engine(ivf_index, **kw),
        engine=engines.ivf_engine(ivf_index, k=K, nprobe=192))
    d_ivf.fit(jnp.asarray(ds.learn), x, targets=TARGETS, batch=512)
    t["darth_ivf_fit"] = time.time() - t0

    d_hnsw = None
    if with_hnsw:
        t0 = time.time()
        # ef over-provisioned for >=0.99 natural recall with headroom —
        # the paper's setup (their efSearch 500-2500); termination studies
        # need the natural stop to be far beyond the target-reach point.
        d_hnsw = api.Darth(
            make_engine=lambda **kw: engines.hnsw_engine(hnsw_index, **kw),
            engine=engines.hnsw_engine(hnsw_index, k=K, ef=384,
                                       max_steps=1200))
        d_hnsw.fit(jnp.asarray(ds.learn), x, targets=TARGETS, batch=512)
        t["darth_hnsw_fit"] = time.time() - t0

    return Bench(ds=ds, ivf_index=ivf_index, hnsw_index=hnsw_index,
                 darth_ivf=d_ivf, darth_hnsw=d_hnsw, gt=gt,
                 build_seconds=t)


def topk_metric_inputs(d, ii):
    return np.asarray(d), np.asarray(ii)
