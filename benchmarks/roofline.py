"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run's compiled artifacts.

  compute    = HLO_FLOPs / peak_FLOP/s          (per-device, loop-weighted)
  memory     = HLO_bytes / HBM_bw               (per-device kernel traffic)
  collective = collective_bytes / link_bw       (per-device wire bytes)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
MODEL_FLOPS = 6*N*D (train, dense), 6*N_active*D (MoE), 2*N*D (prefill),
2*N_active*tokens (decode) — the "useful compute" yardstick; the ratio
MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/redundancy waste.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s/link (conservative: single-link model)

_PARAM_COUNTS: Dict[str, Dict[str, float]] = {}


def _param_counts(arch: str) -> Dict[str, float]:
    if arch in _PARAM_COUNTS:
        return _PARAM_COUNTS[arch]
    import jax
    from repro import configs
    from repro.models import model_zoo
    cfg = configs.get_config(arch)
    shapes = model_zoo.param_shapes(cfg)
    total = 0
    expert = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
            shapes, is_leaf=lambda x: isinstance(x, tuple))[0]:
        n = int(np.prod(s))
        total += n
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if "moe/w" in keys:
            expert += n
    active = total - expert
    if cfg.num_experts:
        active += expert * cfg.experts_per_token / cfg.num_experts
    _PARAM_COUNTS[arch] = {"total": float(total), "active": float(active)}
    return _PARAM_COUNTS[arch]


def model_flops(arch: str, kind: str, seq: int, batch: int) -> float:
    counts = _param_counts(arch)
    n_act = counts["active"]
    tokens = seq * batch
    if kind == "train":
        return 6.0 * n_act * tokens
    if kind == "prefill":
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * batch


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    from repro.configs.base import SHAPES
    cell = SHAPES[rec["shape"]]
    chips = rec["num_devices"]
    t_c = rec["hlo_flops"] / PEAK_FLOPS
    t_m = rec["hlo_bytes"] / HBM_BW
    t_x = rec["collectives"]["total"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], cell.kind, cell.seq_len, cell.global_batch)
    useful = mf / max(rec["hlo_flops"] * chips, 1.0)
    bound = max(t_c, t_m, t_x)
    mfu_bound = (mf / chips / PEAK_FLOPS) / max(bound, 1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": cell.kind,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf, "useful_ratio": useful,
        "roofline_fraction": mfu_bound,
        "mem_gb": rec.get("memory", {}).get("temp_bytes", 0) / 1e9,
    }


def build_table(path: str = "results/dryrun.json",
                mesh: str = "16x16") -> str:
    with open(path) as f:
        recs = json.load(f)
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": mesh, "skip": r["reason"]})
            continue
        a = analyze_record(r)
        if a:
            rows.append(a)
    lines = [
        "| arch | shape | compute(s) | memory(s) | collective(s) | dominant "
        "| MODEL_FLOPS | useful | roofline frac | mem GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"SKIP | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['model_flops']:.3g} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['mem_gb']:.1f} |")
    return "\n".join(lines)


def main() -> None:
    for mesh, label in (("16x16", "single pod"), ("2x16x16", "multi-pod")):
        table = build_table(mesh=mesh)
        os.makedirs("results", exist_ok=True)
        out = f"results/roofline_{mesh}.md"
        with open(out, "w") as f:
            f.write(f"# Roofline table ({mesh}, {label})\n\n" + table + "\n")
        print(f"[roofline] wrote {out}")
        if mesh == "16x16":
            print(table)


if __name__ == "__main__":
    main()
