"""Quickstart: declarative recall in ~40 lines.

Builds an IVF index over a synthetic clustered collection, fits DARTH once
(training-data generation + GBDT recall predictor), then serves ANY recall
target per query with no further tuning — the paper's headline API:

    ANNS(q, G, k, R_t)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import api, engines
from repro.data import vectors
from repro.index import flat, ivf


def main():
    print("== DARTH quickstart ==")
    ds = vectors.make_dataset(n=30_000, d=32, num_learn=2_000,
                              num_queries=256, clusters=128, seed=0)
    t0 = time.time()
    index = ivf.build(ds.base, nlist=128, seed=0)
    print(f"IVF index: {index.num_vectors} vectors, nlist={index.nlist} "
          f"({time.time()-t0:.1f}s)")

    darth = api.Darth(
        make_engine=lambda **kw: engines.ivf_engine(index, **kw),
        engine=engines.ivf_engine(index, k=10, nprobe=128))
    t0 = time.time()
    trained = darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base))
    print(f"DARTH fit: predictor mse={trained.metrics['mse']:.5f} "
          f"r2={trained.metrics['r2']:.3f} ({time.time()-t0:.1f}s)")

    q = jnp.asarray(ds.queries)
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    _, _, plain = darth.search_plain(q)
    plain_nd = float(np.asarray(plain.ndis).mean())
    print(f"\nplain search: recall=1.000 mean-dists={plain_nd:.0f}")
    print(f"{'target':>7} {'recall':>7} {'dists':>7} {'speedup':>8} "
          f"{'pred-calls':>10}")
    for rt in (0.80, 0.85, 0.90, 0.95, 0.99):
        dd, ii, st = darth.search(q, rt)
        rec = float(np.asarray(flat.recall_at_k(ii, gt_i)).mean())
        nd = float(np.asarray(st.inner.ndis).mean())
        print(f"{rt:7.2f} {rec:7.3f} {nd:7.0f} {plain_nd/nd:7.1f}x "
              f"{float(np.asarray(st.npred).mean()):10.1f}")
    print("\nEvery target met from ONE fit — no per-target tuning.")


if __name__ == "__main__":
    main()
