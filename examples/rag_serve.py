"""RAG-style serving: LM plane + DARTH retrieval plane composed.

The paper's kind is serving, so this is the end-to-end driver (deliverable
b): a small LM embeds queries (mean-pooled hidden states), the DARTH
serving engine retrieves context with *per-request declared recall*
(continuous batching + compaction), and the LM decodes a few tokens
conditioned on the retrieved ids. The serve runs traced (repro.obs):
it ends by replaying one request's termination story through
``repro.obs.explain`` — why that query stopped, at which predicted
recall, and what it crossed in flight.

Run:  PYTHONPATH=src python examples/rag_serve.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import api, engines
from repro.data import vectors
from repro.index import flat, ivf
from repro.models import model_zoo
from repro.obs import Tracer
from repro.obs.explain import explain
from repro.serve import DarthServer


def main():
    rng = np.random.default_rng(0)

    # --- LM plane: a tiny smollm-family model (random init stands in for
    # a trained checkpoint; the point is the composed serving path).
    cfg = configs.get_config("smollm-360m").scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))

    def embed_texts(tokens):
        """Mean-pooled hidden states as retrieval embeddings."""
        x, _, _ = model_zoo.forward(cfg, params, {"tokens": tokens},
                                    remat=False)
        return np.asarray(x.mean(axis=1), np.float32)

    # --- Retrieval plane: corpus of "documents" = embedded token strings.
    n_docs = 8_000
    doc_tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (n_docs, 24)), jnp.int32)
    print("embedding corpus ...")
    corpus = np.concatenate([embed_texts(doc_tokens[i:i + 512])
                             for i in range(0, n_docs, 512)])

    index = ivf.build(corpus, nlist=64, seed=0)
    darth = api.Darth(
        make_engine=lambda **kw: engines.ivf_engine(index, **kw),
        engine=engines.ivf_engine(index, k=5, nprobe=64))
    learn_q = corpus[rng.choice(n_docs, 512, replace=False)] \
        + rng.normal(size=(512, corpus.shape[1])).astype(np.float32) * 0.05
    darth.fit(jnp.asarray(learn_q), jnp.asarray(corpus))
    print(f"retrieval fit: mse={darth.trained.metrics['mse']:.5f}")

    # --- Serve: mixed per-request recall targets through the engine.
    n_req = 64
    req_tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (n_req, 24)), jnp.int32)
    req_emb = embed_texts(req_tokens)
    r_targets = np.where(np.arange(n_req) % 2 == 0, 0.8, 0.95
                         ).astype(np.float32)

    tracer = Tracer(label="rag")            # in-memory trace of the serve
    server = DarthServer(darth.engine, darth.trained.predictor,
                         darth.interval_for_target, num_slots=32,
                         tracer=tracer)
    t0 = time.time()
    results, stats = server.serve(req_emb, r_targets)
    print(f"served {stats.completed} requests in {time.time()-t0:.1f}s "
          f"({stats.engine_steps} engine steps, {stats.refills} refills)")

    # --- Explain one request: the worst-served query's full story.
    print("\nwhy did the worst request terminate? (repro.obs.explain)")
    for line in explain(tracer.last_spans).splitlines():
        print("  " + line)
    print()

    # recall check vs exact
    gt_d, gt_i = flat.search(jnp.asarray(req_emb), jnp.asarray(corpus), 5)
    ids = np.stack([r[1] for r in results])
    rec = np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i))
    print(f"recall: target-0.80 reqs {rec[::2].mean():.3f}, "
          f"target-0.95 reqs {rec[1::2].mean():.3f}")

    # --- Decode a few tokens conditioned on top doc (toy generation).
    top_doc = int(results[0][1][0])
    prompt = jnp.concatenate([doc_tokens[top_doc][None, :8],
                              req_tokens[:1, :8]], axis=1)
    cache = model_zoo.make_cache(cfg, 1, prompt.shape[1] + 8)
    logits = None
    for t in range(prompt.shape[1]):
        logits, cache = model_zoo.decode_step(
            cfg, params, cache, prompt[:, t:t + 1],
            jnp.asarray(t, jnp.int32))
    gen = []
    pos = prompt.shape[1]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(6):
        gen.append(int(tok[0, 0]))
        logits, cache = model_zoo.decode_step(cfg, params, cache, tok,
                                              jnp.asarray(pos + t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print("generated token ids (toy):", gen)
    print("\nRAG path: embed -> declarative-recall retrieve -> decode  OK")


if __name__ == "__main__":
    main()
