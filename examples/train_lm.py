"""End-to-end LM training driver with fault tolerance.

Trains a reduced-width smollm-family model on the deterministic synthetic
token stream, checkpointing every --ckpt-every steps. Kill it at any point
and re-run: it resumes from the last committed checkpoint and reproduces
the exact loss trajectory (counter-based data pipeline, DESIGN.md §4).

Default is laptop-sized; --full trains a ~110M-param model for a few
hundred steps (CPU: expect tens of minutes).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""
import argparse

from repro import configs
from repro.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=40)
    ap.add_argument("--full", action="store_true",
                    help="~110M params (slow on CPU)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (then re-run)")
    args = ap.parse_args()

    base = configs.get_config("smollm-360m")
    if args.full:
        cfg = base.scaled(num_layers=12, d_model=768, num_heads=12,
                          num_kv_heads=4, d_ff=2048, vocab_size=32000,
                          head_dim=64)
        batch, seq = 8, 256
    else:
        cfg = base.scaled(num_layers=4, d_model=256, num_heads=4,
                          num_kv_heads=2, d_ff=688, vocab_size=4096,
                          head_dim=64)
        batch, seq = 8, 128

    out = train(cfg, steps=args.steps, global_batch=batch, seq_len=seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                peak_lr=1e-3, fail_at=args.fail_at, log_every=10)
    hist = out["history"]
    print(f"\nstep {hist[0]['step']}: loss={hist[0]['loss']:.3f}  ->  "
          f"step {hist[-1]['step']}: loss={hist[-1]['loss']:.3f} "
          f"({out['seconds']:.0f}s)")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss should fall"
    print("checkpoints in", args.ckpt_dir,
          "- kill and re-run to see restart-exact resume")


if __name__ == "__main__":
    main()
