"""DARTH serving engine: completeness, correctness, compaction savings."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api, engines, intervals
from repro.index import flat, ivf
from repro.serve import DarthServer


@pytest.fixture(scope="module")
def served_setup():
    from repro.data import vectors
    ds = vectors.make_dataset(n=5000, d=16, num_learn=512, num_queries=200,
                              clusters=25, cluster_std=1.0, seed=1)
    index = ivf.build(ds.base, nlist=25, seed=1)
    eng = engines.ivf_engine(index, k=10, nprobe=25)
    d = api.Darth(make_engine=lambda **kw: engines.ivf_engine(index, **kw),
                  engine=eng)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    return ds, index, d


def test_server_completes_all_queries(served_setup):
    ds, index, d = served_setup
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target,
                         num_slots=32, steps_per_sync=2)
    rts = np.full((200,), 0.9, np.float32)
    results, stats = server.serve(ds.queries, rts)
    assert stats.completed == 200
    assert all(r is not None for r in results)

    # quality: recall against ground truth
    gt_d, gt_i = flat.search(jnp.asarray(ds.queries), jnp.asarray(ds.base), 10)
    ids = np.stack([r[1] for r in results])
    rec = float(np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i)).mean())
    assert rec >= 0.85, rec


def test_server_step_budget_returns_partial_results(served_setup):
    """Regression: hitting max_engine_steps must harvest the in-flight
    slots' partial top-k (counted in stats.truncated), not silently
    leave results[qid] = None for queries that hold a valid result."""
    ds, index, d = served_setup

    def interval_for_target(rt):
        b = np.atleast_1d(rt).shape[0]
        # huge intervals: the predictor never fires, nothing terminates
        # early, so the tiny step budget is guaranteed to be exhausted
        return intervals.IntervalParams(
            ipi=np.full((b,), 1e9, np.float32),
            mpi=np.full((b,), 1e9, np.float32))

    server = DarthServer(d.engine, d.trained.predictor, interval_for_target,
                         num_slots=32, steps_per_sync=2)
    rts = np.full((60,), 0.9, np.float32)
    results, stats = server.serve(ds.queries[:60], rts,
                                  max_engine_steps=2)
    assert stats.engine_steps == 2
    assert stats.truncated == 32          # every admitted slot harvested
    assert stats.completed == 0
    done = [i for i, r in enumerate(results) if r is not None]
    assert done == list(range(32))        # admitted queries, in order
    for i in done:                        # partial top-k is real: after 2
        dists, ids = results[i]           # probes all k slots are filled
        assert ids.shape == (10,) and (ids >= 0).all()
        assert np.isfinite(dists).all()
    # never-admitted queries have no state to harvest
    assert all(results[i] is None for i in range(32, 60))


def test_step_budget_refills_never_return_junk(served_setup):
    """Regression: a refill in the same sync interval that exhausts
    max_engine_steps would splice queries that run zero steps — they
    must stay queued (None), never harvested as init-state junk."""
    ds, index, d = served_setup

    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target,
                         num_slots=8, steps_per_sync=2)
    rts = np.full((60,), 0.8, np.float32)
    results, stats = server.serve(ds.queries[:60], rts, max_engine_steps=8)
    done = [r for r in results if r is not None]
    assert len(done) == stats.completed + stats.truncated
    for dists, ids in done:       # every harvested slot ran >= 1 chunk,
        assert (ids >= 0).all()   # so its top-k holds real neighbors


def test_refill_splice_preserves_per_slot_targets(served_setup):
    """Regression: the refill splice must keep every slot's r_t and its
    ipi/mpi interval params consistent when mixed-target batches refill
    (a wrong mask / broadcast would decouple them)."""
    ds, index, d = served_setup

    # interval params as an injective function of the target, so any
    # slot mixing between r_t and ipi/mpi is visible at every chunk
    def interval_for_target(rt):
        rt = np.atleast_1d(rt).astype(np.float32)
        return intervals.IntervalParams(ipi=100.0 * rt, mpi=10.0 * rt)

    server = DarthServer(d.engine, d.trained.predictor, interval_for_target,
                         num_slots=8, steps_per_sync=2)
    seen = []
    orig = server._run_chunk

    def spy(index, st, rt, ipi, mpi):
        seen.append((np.asarray(rt).copy(), np.asarray(ipi).copy(),
                     np.asarray(mpi).copy()))
        return orig(index, st, rt, ipi, mpi)

    server._run_chunk = spy
    rts = np.tile([0.7, 0.9], 32).astype(np.float32)  # mixed targets
    results, stats = server.serve(ds.queries[:64], rts)
    assert stats.completed == 64 and stats.refills > 0
    assert all(r is not None for r in results)
    mixed_chunks = 0
    for rt, ipi, mpi in seen:
        np.testing.assert_allclose(ipi, 100.0 * rt, rtol=1e-5)
        np.testing.assert_allclose(mpi, 10.0 * rt, rtol=1e-5)
        mixed_chunks += len(np.unique(rt)) > 1
    assert mixed_chunks > 0               # mixed targets really in flight


def test_server_rejects_malformed_requests(served_setup):
    """Regression: per-query target arrays that do not line up with the
    query batch (or out-of-range targets) must raise before any state is
    broadcast."""
    ds, index, d = served_setup

    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target,
                         num_slots=8, steps_per_sync=2)
    q = ds.queries[:16]
    with pytest.raises(ValueError, match="does not match"):
        server.serve(q, np.full((15,), 0.9, np.float32))
    with pytest.raises(ValueError, match="does not match"):
        server.serve(q, np.full((16, 1), 0.9, np.float32))
    with pytest.raises(ValueError, match=r"in \(0, 1\]"):
        server.serve(q, np.full((16,), 0.0, np.float32))
    with pytest.raises(ValueError, match="queries must be"):
        server.serve(q[0], np.full((16,), 0.9, np.float32))


def test_server_hot_swap_predictor_and_engine(served_setup):
    """set_predictor / set_engine keep a running server serving (the
    drift-recalibration and mutation-burst paths)."""
    ds, index, d = served_setup

    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target,
                         num_slots=16, steps_per_sync=2)
    rts = np.full((32,), 0.9, np.float32)
    results, stats = server.serve(ds.queries[:32], rts)
    assert stats.completed == 32

    # contents-only engine swap must NOT rebuild the chunk jits (the
    # index crosses them as an argument)
    chunks = server._run_chunk
    server.set_engine(engines.ivf_engine(index, k=10, nprobe=25),
                      contents_only=True)
    assert server._run_chunk is chunks
    results, stats = server.serve(ds.queries[:32], rts)
    assert stats.completed == 32

    # a contents-only claim with a different protocol is rejected; a
    # default (non-contents-only) swap rebuilds
    with pytest.raises(ValueError, match="changed the engine protocol"):
        server.set_engine(engines.ivf_engine(index, k=5, nprobe=25),
                          contents_only=True)
    server.set_engine(engines.ivf_engine(index, k=10, nprobe=25))
    assert server._run_chunk is not chunks
    chunks = server._run_chunk

    # predictor swap rebuilds; serving continues with the new predictor
    server.set_predictor(d.trained.predictor)
    assert server._run_chunk is not chunks
    results, stats = server.serve(ds.queries[:32], rts)
    assert stats.completed == 32
    gt_d, gt_i = flat.search(jnp.asarray(ds.queries[:32]),
                             jnp.asarray(ds.base), 10)
    ids = np.stack([r[1] for r in results])
    rec = float(np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i)).mean())
    assert rec >= 0.85, rec


def test_interval_for_target_is_the_shared_builder(served_setup):
    """Dedup regression (PR 4 review): Darth.interval_for_target is the
    ONE per-query IntervalParams builder — element j equals the scalar
    interval_params(rt[j]) exactly, and the former re-implementations
    (launch/serve.py, benchmarks/mutate.py) are pinned to it."""
    import inspect

    ds, index, d = served_setup
    rt = np.array([0.8, 0.9, 0.95, 0.85, 0.5], np.float32)
    ip = d.interval_for_target(rt)
    assert ip.ipi.shape == ip.mpi.shape == (5,)
    for j, r in enumerate(rt):
        p = d.interval_params(float(r))
        assert ip.ipi[j] == np.float32(p.ipi), (j, r)
        assert ip.mpi[j] == np.float32(p.mpi), (j, r)
    # scalar input broadcasts like the vector path
    ip1 = d.interval_for_target(0.9)
    assert ip1.ipi.shape == (1,)
    assert ip1.ipi[0] == np.float32(d.interval_params(0.9).ipi)

    # the former call sites must not re-implement the builder
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from repro.launch import serve as serve_launch
    import benchmarks.mutate as bench_mutate
    for mod in (serve_launch, bench_mutate):
        src = inspect.getsource(mod)
        assert "def interval_for_target" not in src, mod.__name__
        assert "darth.interval_for_target" in src, mod.__name__


@pytest.mark.parametrize("hosts", [2, 4])
def test_multi_host_matches_single_controller(served_setup, hosts):
    """Tentpole parity bar: the multi-host slot pool (per-host
    admission / refill / compaction loops over slot slices) returns
    EXACTLY the single-controller server's output — per-query topk_d /
    topk_i, total harvested ndis, and truncated — because per-slot
    search state never crosses slots."""
    ds, index, d = served_setup
    rts = np.tile([0.7, 0.9, 0.8, 0.95], 50).astype(np.float32)

    ref_server = DarthServer(d.engine, d.trained.predictor,
                             d.interval_for_target,
                             num_slots=16, steps_per_sync=2, hosts=1)
    ref, ref_stats = ref_server.serve(ds.queries, rts)
    assert ref_stats.completed == 200

    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target,
                         num_slots=16, steps_per_sync=2, hosts=hosts)
    res, stats = server.serve(ds.queries, rts)
    assert stats.completed == 200 and stats.truncated == 0
    assert len(stats.hosts) == hosts
    for a, b in zip(ref, res):
        np.testing.assert_allclose(a[0], b[0], atol=0)   # dists, exact
        np.testing.assert_array_equal(a[1], b[1])        # ids
    assert stats.ndis_harvested == ref_stats.ndis_harvested
    assert stats.truncated == ref_stats.truncated
    assert stats.slot_steps > 0
    # every host really served its stripe (no host starved)
    for h in stats.hosts:
        assert h.admitted == 200 // hosts
        assert h.completed == h.admitted and not h.killed


@pytest.mark.parametrize("hosts", [1, 2, 4])
def test_multi_host_mutable_engine_matches_single_controller(hosts):
    """The Engine protocol keeps mutable (delta-tier) serving working
    unchanged through the multi-host split: after an insert/delete
    burst, every host count returns the hosts=1 output exactly."""
    from repro import mutate
    from repro.data import vectors

    ds = vectors.make_dataset(n=2000, d=16, num_learn=192, num_queries=64,
                              clusters=16, cluster_std=1.0, seed=2)
    index = ivf.build(ds.base, nlist=16, seed=2)
    mut = mutate.MutableIndex(index, capacity=512)
    mut.apply(vectors.mutation_stream(ds, insert_pct=0.2, delete_pct=0.1,
                                      drift=0.3, steps=4, seed=3))

    def make_engine(**kw):
        return engines.mutable_engine(
            engines.ivf_engine(mut.base, **kw), mut.delta)

    d = api.Darth(make_engine=make_engine,
                  engine=make_engine(k=10, nprobe=16))
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=128)
    rts = np.tile([0.8, 0.9], 32).astype(np.float32)

    ref = None
    for h in (1, hosts):
        server = DarthServer(d.engine, d.trained.predictor,
                             d.interval_for_target,
                             num_slots=8, steps_per_sync=2, hosts=h)
        res, stats = server.serve(ds.queries, rts)
        assert stats.completed == 64
        if ref is None:
            ref = (res, stats)
        else:
            for a, b in zip(ref[0], res):
                np.testing.assert_allclose(a[0], b[0], atol=0)
                np.testing.assert_array_equal(a[1], b[1])
            assert stats.ndis_harvested == ref[1].ndis_harvested


def test_multi_host_truncation_matches_single_controller(served_setup):
    """Budget truncation under multi-host: with one slot per query (no
    refill divergence possible) the truncated count and every partial
    top-k match the single-controller server at hosts {1, 2, 4}."""
    ds, index, d = served_setup

    def interval_for_target(rt):
        b = np.atleast_1d(rt).shape[0]
        # huge intervals: nothing terminates early, the tiny budget hits
        return intervals.IntervalParams(
            ipi=np.full((b,), 1e9, np.float32),
            mpi=np.full((b,), 1e9, np.float32))

    rts = np.full((32,), 0.9, np.float32)
    ref = None
    for hosts in (1, 2, 4):
        server = DarthServer(d.engine, d.trained.predictor,
                             interval_for_target,
                             num_slots=32, steps_per_sync=2, hosts=hosts)
        res, stats = server.serve(ds.queries[:32], rts, max_engine_steps=2)
        assert stats.truncated == 32 and stats.completed == 0
        assert all(r is not None for r in res)
        if ref is None:
            ref = res
        else:
            for a, b in zip(ref, res):
                np.testing.assert_allclose(a[0], b[0], atol=0)
                np.testing.assert_array_equal(a[1], b[1])


def test_kill_host_returns_every_admitted_query_exactly_once(served_setup):
    """Fault injection (the PR 3 truncation bug class, per-host): kill
    one host's slot slice mid-serve — its in-flight queries must be
    harvested exactly once (partial top-k, counted truncated), its
    queue abandoned (None), and the surviving hosts must drain their
    stripes completely."""
    ds, index, d = served_setup
    n = 120

    def interval_for_target(rt):
        b = np.atleast_1d(rt).shape[0]
        # huge intervals: the predictor never fires, every query runs to
        # natural termination (nprobe steps) — so the killed host is
        # GUARANTEED to hold in-flight slots at the kill boundary
        return intervals.IntervalParams(
            ipi=np.full((b,), 1e9, np.float32),
            mpi=np.full((b,), 1e9, np.float32))

    rts = np.full((n,), 0.9, np.float32)
    server = DarthServer(d.engine, d.trained.predictor,
                         interval_for_target,
                         num_slots=16, steps_per_sync=2, hosts=4)
    results, stats = server.serve(ds.queries[:n], rts,
                                  kill_hosts={1: 4})
    dead = stats.hosts[1]
    assert dead.killed
    # every admitted query on the dead host came back exactly once:
    # nothing naturally terminates by step 4 (< nprobe), so all 4
    # in-flight slots are truncated partial top-ks
    assert dead.admitted == dead.truncated == 4 and dead.completed == 0
    assert dead.abandoned == n // 4 - dead.admitted
    # survivors drained their stripes fully
    for h in (0, 2, 3):
        alive = stats.hosts[h]
        assert not alive.killed and alive.abandoned == 0
        assert alive.completed == n // 4
    # global ledger: every query is returned exactly once or abandoned
    done = [i for i, r in enumerate(results) if r is not None]
    assert len(done) == stats.completed + stats.truncated
    assert len(done) + dead.abandoned == n
    # the dead host's stripe is queries 1, 5, 9, ... (striped partition)
    none_ids = [i for i, r in enumerate(results) if r is None]
    assert all(i % 4 == 1 for i in none_ids)
    # harvested partial top-ks are real results, not init junk
    for i in done:
        dists, ids = results[i]
        assert (ids >= 0).all() and np.isfinite(dists).all()


def test_kill_host_counts_finished_slots_as_completed(served_setup):
    """Review regression: a killed host's slots that FINISHED at the
    kill boundary hold a full top-k — they count completed, not
    truncated (only still-running slots are truncated)."""
    ds, index, d = served_setup
    n = 120
    rts = np.full((n,), 0.9, np.float32)
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target,
                         num_slots=16, steps_per_sync=2, hosts=4)
    results, stats = server.serve(ds.queries[:n], rts,
                                  kill_hosts={1: 4})
    dead = stats.hosts[1]
    assert dead.killed
    assert dead.admitted == dead.completed + dead.truncated
    # with real intervals these fast queries finish within the first
    # chunks: the kill must not relabel their full top-ks as truncated
    assert dead.completed > 0
    done = [i for i, r in enumerate(results) if r is not None]
    assert len(done) == stats.completed + stats.truncated
    assert len(done) + dead.abandoned == n


def test_server_rejects_indivisible_host_split():
    with pytest.raises(ValueError, match="split evenly"):
        DarthServer(engine=None, predictor=None, interval_for_target=None,
                    num_slots=10, hosts=4)


def test_server_compaction_saves_slot_steps(served_setup):
    """With compaction, total slot-steps must be well below
    num_queries x natural-termination steps (the no-compaction cost)."""
    ds, index, d = served_setup
    from repro.core import darth_search
    q = jnp.asarray(ds.queries)
    inner = darth_search.plain_search(d.engine, q)
    natural_steps = float(np.asarray(inner.probe_pos).mean())

    def interval_for_target(rt):
        p = d.interval_params(0.9)
        b = np.atleast_1d(rt).shape[0]
        return intervals.IntervalParams(
            ipi=np.full((b,), p.ipi, np.float32),
            mpi=np.full((b,), p.mpi, np.float32))

    server = DarthServer(d.engine, d.trained.predictor, interval_for_target,
                         num_slots=32, steps_per_sync=2)
    results, stats = server.serve(ds.queries, np.full((200,), 0.9, np.float32))
    per_query_steps = stats.slot_steps / stats.completed
    assert per_query_steps < natural_steps, \
        (per_query_steps, natural_steps)


# -- difficulty-aware admission (serve.difficulty) ------------------------

@pytest.mark.parametrize("hosts", [1, 2, 4])
def test_uniform_tiers_match_untiered_exactly(served_setup, hosts):
    """Tiering off ≡ today's server: the identity TierConfig (nothing
    classified hard, no reserved slots, no boost/hedge/queue bound)
    must schedule byte-identically to tiers=None at every host count —
    same per-query results, same harvested ndis, same refill count."""
    from repro.serve import TierConfig

    ds, index, d = served_setup
    rts = np.tile([0.7, 0.9, 0.8, 0.95], 50).astype(np.float32)

    outs = []
    for tiers in (None, TierConfig.uniform()):
        server = DarthServer(d.engine, d.trained.predictor,
                             d.interval_for_target, num_slots=16,
                             steps_per_sync=2, hosts=hosts, tiers=tiers)
        outs.append(server.serve(ds.queries, rts))
    (ref, ref_stats), (res, stats) = outs
    assert stats.completed == ref_stats.completed == 200
    for a, b in zip(ref, res):
        np.testing.assert_allclose(a[0], b[0], atol=0)
        np.testing.assert_array_equal(a[1], b[1])
    assert stats.ndis_harvested == ref_stats.ndis_harvested
    assert stats.refills == ref_stats.refills
    assert stats.shed == stats.degraded == stats.hedged == 0
    # the uniform policy still reports tier SLOs (everything is "easy")
    assert stats.tiers["easy"].count == 200
    assert stats.tiers["hard"].count == 0


def test_tiered_serving_boost_only_deepens(served_setup):
    """A hard-tier boost may only ADD work: every query still returns,
    per-tier stats ledger balances, and total harvested ndis is >= the
    untiered serve's (deeper searches for the boosted tail)."""
    from repro.serve import TierConfig

    ds, index, d = served_setup
    rts = np.full((200,), 0.85, np.float32)
    base_server = DarthServer(d.engine, d.trained.predictor,
                              d.interval_for_target, num_slots=16,
                              steps_per_sync=2, hosts=2)
    _, base_stats = base_server.serve(ds.queries, rts)

    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=16,
                         steps_per_sync=2, hosts=2,
                         tiers=TierConfig(hard_quantile=0.75,
                                          hard_slot_fraction=0.25,
                                          boost=0.1))
    results, stats = server.serve(ds.queries, rts)
    assert stats.completed == 200
    assert all(r is not None for r in results)
    assert stats.ndis_harvested >= base_stats.ndis_harvested
    easy, hard = stats.tiers["easy"], stats.tiers["hard"]
    assert easy.count + hard.count == 200
    assert easy.completed + hard.completed == 200
    # SLO percentiles are populated for both tiers
    for t in (easy, hard):
        assert np.isfinite(t.recall_p50) and np.isfinite(t.recall_p99)
        assert np.isfinite(t.latency_p50) and np.isfinite(t.latency_p99)
        assert t.recall_p99 <= t.recall_p50
        assert t.latency_p99 >= t.latency_p50


def test_hedge_harvest_orderings_return_exactly_one_result():
    """_HostSlots.harvest hedge contract, both completion orders: the
    hedge finishing SECOND upgrades the stored result (unless
    truncated: dropped); the hedge finishing FIRST wins and the primary
    frees silently via hedge_winner. Either way the query has exactly
    one result and is never 'harvested twice'."""
    from repro.serve.engine import _HostSlots
    from repro.serve import TierConfig

    queries = np.zeros((2, 4), np.float32)
    tc = TierConfig(hard_quantile=0.0, hard_slot_fraction=1.0, hedge=True)
    is_hard = np.ones((2,), bool)

    def iv(rt):
        rt = np.atleast_1d(rt)
        return intervals.IntervalParams(
            ipi=np.full(rt.shape, 8.0, np.float32),
            mpi=np.full(rt.shape, 4.0, np.float32))

    def fresh():
        results = [None, None]
        hl = _HostSlots(0, 0, 2, [0], queries, np.full((2,), 0.9, np.float32),
                        iv, results, tiers=tc, is_hard=is_hard)
        # first fill admits the primary (hedges never launch in a fill
        # that admitted real work); second fill sees a drained queue
        # plus an idle hard slot and launches the hedge duplicate
        hl.fill(np.array([0]), step=0)
        hl.fill(np.array([1]), step=1)
        assert hl.slot_hedge[1] and not hl.slot_hedge[0]
        assert hl.stats.hedged == 1
        return hl, results

    d = np.arange(10, dtype=np.float32).reshape(2, 5)
    i = np.arange(10, dtype=np.int32).reshape(2, 5)
    nd = np.array([7, 9])

    # order A: primary first, hedge second -> hedge upgrades
    hl, results = fresh()
    hl.harvest(np.array([True, False]), d, i, nd, step=2)
    assert results[0] is not None and results[0][1][0] == i[0, 0]
    hl.harvest(np.array([False, True]), d, i, nd, step=4)
    assert results[0][1][0] == i[1, 0]      # upgraded to the hedge's topk
    assert hl.stats.hedge_upgrades == 1
    assert hl.stats.completed == 1          # ONE query completed, not two

    # order B: hedge first -> wins; primary then frees silently
    hl, results = fresh()
    hl.harvest(np.array([False, True]), d, i, nd, step=2)
    assert results[0] is not None and results[0][1][0] == i[1, 0]
    assert hl.stats.hedge_upgrades == 1
    hl.harvest(np.array([True, False]), d, i, nd, step=4)
    assert results[0][1][0] == i[1, 0]      # hedge result kept
    assert hl.stats.completed == 1 and not hl.occupied.any()

    # order C: truncated hedge while primary in flight -> hedge dropped,
    # primary's partial top-k stands
    hl, results = fresh()
    hl.harvest(np.array([False, True]), d, i, nd, truncated=True, step=2)
    assert results[0] is None               # hedge dropped, no result yet
    hl.harvest(np.array([True, False]), d, i, nd, truncated=True, step=2)
    assert results[0] is not None and results[0][1][0] == i[0, 0]
    assert hl.stats.hedge_upgrades == 0 and hl.stats.truncated == 1


def test_hedge_across_epoch_swap_never_merges_index_versions():
    """Regression (hot-swap between hedge launch and harvest): the
    hedge duplicate was admitted AFTER an engine/predictor swap, so its
    top-k was computed against a different index version than the
    primary's stored result. Upgrading would merge two versions into
    one hedge pair — the cross-epoch hedge must be DROPPED instead
    (hedge_epoch_dropped), keeping the primary's result. A same-epoch
    pair (the control) still upgrades."""
    from repro.serve.engine import _HostSlots
    from repro.serve import TierConfig

    queries = np.zeros((2, 4), np.float32)
    tc = TierConfig(hard_quantile=0.0, hard_slot_fraction=1.0, hedge=True)
    is_hard = np.ones((2,), bool)

    def iv(rt):
        rt = np.atleast_1d(rt)
        return intervals.IntervalParams(
            ipi=np.full(rt.shape, 8.0, np.float32),
            mpi=np.full(rt.shape, 4.0, np.float32))

    def fresh(hedge_epoch):
        results = [None, None]
        hl = _HostSlots(0, 0, 2, [0], queries,
                        np.full((2,), 0.9, np.float32), iv, results,
                        tiers=tc, is_hard=is_hard)
        hl.fill(np.array([0]), step=0, epoch=0)       # primary @ epoch 0
        hl.fill(np.array([1]), step=1, epoch=hedge_epoch)
        assert hl.slot_hedge[1] and not hl.slot_hedge[0]
        return hl, results

    d = np.arange(10, dtype=np.float32).reshape(2, 5)
    i = np.arange(10, dtype=np.int32).reshape(2, 5)
    nd = np.array([7, 9])

    # swap between launch and harvest: hedge is epoch 1, primary's
    # stored result is epoch 0 -> no upgrade, counted as dropped
    hl, results = fresh(hedge_epoch=1)
    hl.harvest(np.array([True, False]), d, i, nd, step=2)
    assert results[0][1][0] == i[0, 0]
    hl.harvest(np.array([False, True]), d, i, nd, step=4)
    assert results[0][1][0] == i[0, 0]      # primary's result KEPT
    assert hl.stats.hedge_epoch_dropped == 1
    assert hl.stats.hedge_upgrades == 0
    assert hl.stats.completed == 1 and not hl.occupied.any()

    # control: same epoch -> the usual upgrade
    hl, results = fresh(hedge_epoch=0)
    hl.harvest(np.array([True, False]), d, i, nd, step=2)
    hl.harvest(np.array([False, True]), d, i, nd, step=4)
    assert results[0][1][0] == i[1, 0]      # upgraded
    assert hl.stats.hedge_upgrades == 1
    assert hl.stats.hedge_epoch_dropped == 0


def test_hedged_serving_stable_under_per_boundary_epoch_bumps(
        served_setup):
    """Hedging + an epoch bump at every chunk boundary (the predictor
    hot-swap path): every query still returns exactly one result and
    cross-epoch hedge pairs are dropped, never merged."""
    from repro.serve import TierConfig

    ds, index, d = served_setup
    tiers = TierConfig(hard_quantile=0.75, hard_slot_fraction=0.25,
                       hedge=True)
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=16,
                         steps_per_sync=2, tiers=tiers)
    rts = np.full((200,), 0.9, np.float32)

    def bump(srv):
        srv.set_predictor(d.trained.predictor)

    results, stats = server.serve(ds.queries, rts, on_boundary=bump)
    assert stats.completed == 200
    assert all(r is not None for r in results)
    # every hedge either upgraded within its epoch or was dropped
    assert stats.hedged >= stats.hedge_upgrades + stats.hedge_epoch_dropped
