"""DARTH serving engine: completeness, correctness, compaction savings."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api, engines, intervals
from repro.index import flat, ivf
from repro.serve import DarthServer


@pytest.fixture(scope="module")
def served_setup():
    from repro.data import vectors
    ds = vectors.make_dataset(n=5000, d=16, num_learn=512, num_queries=200,
                              clusters=25, cluster_std=1.0, seed=1)
    index = ivf.build(ds.base, nlist=25, seed=1)
    eng = engines.ivf_engine(index, k=10, nprobe=25)
    d = api.Darth(make_engine=lambda **kw: engines.ivf_engine(index, **kw),
                  engine=eng)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    return ds, index, d


def test_server_completes_all_queries(served_setup):
    ds, index, d = served_setup
    def interval_for_target(rt):
        p = [d.interval_params(float(r)) for r in np.atleast_1d(rt)]
        return intervals.IntervalParams(
            ipi=np.array([x.ipi for x in p], np.float32),
            mpi=np.array([x.mpi for x in p], np.float32))

    server = DarthServer(d.engine, d.trained.predictor, interval_for_target,
                         num_slots=32, steps_per_sync=2)
    rts = np.full((200,), 0.9, np.float32)
    results, stats = server.serve(ds.queries, rts)
    assert stats.completed == 200
    assert all(r is not None for r in results)

    # quality: recall against ground truth
    gt_d, gt_i = flat.search(jnp.asarray(ds.queries), jnp.asarray(ds.base), 10)
    ids = np.stack([r[1] for r in results])
    rec = float(np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i)).mean())
    assert rec >= 0.85, rec


def test_server_compaction_saves_slot_steps(served_setup):
    """With compaction, total slot-steps must be well below
    num_queries x natural-termination steps (the no-compaction cost)."""
    ds, index, d = served_setup
    from repro.core import darth_search
    q = jnp.asarray(ds.queries)
    inner = darth_search.plain_search(d.engine, q)
    natural_steps = float(np.asarray(inner.probe_pos).mean())

    def interval_for_target(rt):
        p = d.interval_params(0.9)
        b = np.atleast_1d(rt).shape[0]
        return intervals.IntervalParams(
            ipi=np.full((b,), p.ipi, np.float32),
            mpi=np.full((b,), p.mpi, np.float32))

    server = DarthServer(d.engine, d.trained.predictor, interval_for_target,
                         num_slots=32, steps_per_sync=2)
    results, stats = server.serve(ds.queries, np.full((200,), 0.9, np.float32))
    per_query_steps = stats.slot_steps / stats.completed
    assert per_query_steps < natural_steps, \
        (per_query_steps, natural_steps)
