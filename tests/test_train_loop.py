"""Fault-tolerance contract: restart-exact continuation, atomic
checkpoints, failure injection (DESIGN.md §4)."""
import os

import numpy as np
import jax
import pytest

from repro import ckpt, configs
from repro.train import SimulatedFailure, train
from tests.conftest import small_config

CFG = small_config(configs.get_config("smollm-360m"))
KW = dict(global_batch=4, seq_len=32, peak_lr=1e-3, log_every=1)


def test_checkpoint_restart_bit_exact(tmp_path):
    d1 = str(tmp_path / "uninterrupted")
    d2 = str(tmp_path / "interrupted")

    ref = train(CFG, steps=8, ckpt_dir=d1, ckpt_every=4, **KW)

    with pytest.raises(SimulatedFailure):
        train(CFG, steps=8, ckpt_dir=d2, ckpt_every=4, fail_at=6, **KW)
    # restart resumes from step 4 and must reproduce the exact trajectory
    res = train(CFG, steps=8, ckpt_dir=d2, ckpt_every=4, **KW)

    ref_by_step = {m["step"]: m["loss"] for m in ref["history"]}
    for m in res["history"]:
        if m["step"] >= 4:
            assert abs(m["loss"] - ref_by_step[m["step"]]) < 1e-5, \
                (m["step"], m["loss"], ref_by_step[m["step"]])
    # final params identical
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(res["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_atomicity_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.latest_step(d) == 5
    # retention keeps only the newest 2 committed steps
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_") and not n.endswith(".done"))
    assert steps == [4, 5]
    # a stale tmp dir must never be picked up
    os.makedirs(os.path.join(d, ".tmp_ckpt_zzz"), exist_ok=True)
    assert ckpt.latest_step(d) == 5


def test_checkpoint_restore_structure(tmp_path):
    d = str(tmp_path / "ck2")
    tree = {"w": np.random.default_rng(0).normal(size=(4, 4)).astype(np.float32),
            "step": np.asarray(7)}
    ckpt.save(d, 7, tree)
    like = {"w": jax.ShapeDtypeStruct((4, 4), np.float32),
            "step": jax.ShapeDtypeStruct((), np.int64)}
    restored, meta = ckpt.restore(d, like)
    np.testing.assert_allclose(np.asarray(restored["w"]), tree["w"])
    assert meta["step"] == 7


def test_pipeline_restart_exact():
    from repro.data import PipelineConfig, TokenPipeline
    cfg = PipelineConfig(vocab_size=128, seq_len=16, global_batch=4, seed=3)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    # skip-ahead: batch at step 57 identical without generating 0..56
    b1 = p1.get_batch(57)
    b2 = p2.get_batch(57)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # shards partition the global batch deterministically
    sh0 = TokenPipeline(PipelineConfig(vocab_size=128, seq_len=16,
                                       global_batch=4, seed=3,
                                       num_shards=2, shard_id=0))
    sh1 = TokenPipeline(PipelineConfig(vocab_size=128, seq_len=16,
                                       global_batch=4, seed=3,
                                       num_shards=2, shard_id=1))
    a = np.asarray(sh0.get_batch(5)["tokens"])
    b = np.asarray(sh1.get_batch(5)["tokens"])
    assert a.shape == (2, 16) and b.shape == (2, 16)
    assert not np.array_equal(a, b)
