import os
import sys

# Tests run on the single real CPU device (the dry-run, and ONLY the
# dry-run, uses 512 placeholder devices via its own env line).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests use hypothesis when installed (CI does, via
# requirements.txt); offline containers fall back to the deterministic
# shim in tests/_vendor that covers the API subset the suite needs.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))


import pytest

from repro import configs


def small_config(cfg: configs.ArchConfig) -> configs.ArchConfig:
    """Reduced config of the same family (assignment: smoke tests)."""
    over = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=128, vocab_size=256, head_dim=16)
    if cfg.family == "moe":
        over.update(num_experts=4, experts_per_token=2, moe_d_ff=64)
    if cfg.family == "ssm":
        over.update(num_heads=4, num_kv_heads=4, head_dim=16, ssm_state=16)
    if cfg.family == "hybrid":
        over.update(num_layers=5, attn_every=2, ssm_state=16, num_kv_heads=4)
    if cfg.family == "audio":
        over.update(encoder_layers=2, frontend_len=8, frontend_dim=32)
    if cfg.family == "vlm":
        over.update(frontend_len=4, frontend_dim=32)
    return cfg.scaled(**over)


@pytest.fixture(scope="session")
def clustered_vectors():
    from repro.data import vectors
    return vectors.make_dataset(n=6000, d=24, num_learn=512, num_queries=128,
                                clusters=32, cluster_std=1.2, seed=0)
