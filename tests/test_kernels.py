"""Pallas kernel validation: interpret-mode shape/dtype sweeps against the
pure-jnp oracles in kernels/ref.py (deliverable c)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import gbdt
from repro.kernels import ops, ref


@pytest.mark.parametrize("b,n,d,k", [
    (4, 257, 16, 5),
    (16, 1024, 64, 10),
    (3, 96, 7, 8),
    (128, 2048, 128, 50),
    (1, 8, 4, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_topk_matches_oracle(b, n, d, k, dtype):
    rng = np.random.default_rng(hash((b, n, d, k)) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, d)), dtype)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    d_k, i_k = ops.l2_topk(q, x, k=k)
    d_r, i_r = ref.l2_topk_ref(q, x, k)
    atol = 1e-3 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), atol=atol)
    if dtype == jnp.float32:
        overlap = np.mean([
            len(set(np.asarray(i_k)[i]) & set(np.asarray(i_r)[i])) / k
            for i in range(b)])
        assert overlap > 0.99


@settings(deadline=None, max_examples=12)
@given(b=st.integers(1, 40), n=st.integers(8, 600), d=st.integers(2, 48),
       k=st.integers(1, 8))
def test_l2_topk_property(b, n, d, k):
    k = min(k, n)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    d_k, i_k = ops.l2_topk(q, x, k=k)
    d_np = np.asarray(d_k)
    # invariants: ascending, non-negative, ids valid & unique per row
    assert (np.diff(d_np, axis=1) >= -1e-5).all()
    assert (d_np >= 0).all()
    ids = np.asarray(i_k)
    for row in ids:
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid)
        assert (valid < n).all()


@pytest.mark.parametrize("n_feat,depth,trees,b", [
    (11, 4, 20, 37),
    (11, 6, 50, 128),
    (5, 3, 7, 9),
])
def test_gbdt_kernel_matches_oracle(n_feat, depth, trees, b):
    rng = np.random.default_rng(1)
    x = rng.normal(size=(3000, n_feat)).astype(np.float32)
    y = (np.sin(x[:, 0]) + x[:, 1] * 0.3).astype(np.float32)
    p = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=trees, depth=depth))
    xq = jnp.asarray(rng.normal(size=(b, n_feat)).astype(np.float32))
    out_k = ops.gbdt_predict(p, xq)
    out_r = ref.gbdt_predict_ref(p, xq)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-5)


def test_gbdt_kernel_vs_xla_path():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(2000, 11)).astype(np.float32)
    y = x[:, 0].astype(np.float32)
    p = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=10, depth=4))
    xq = jnp.asarray(rng.normal(size=(16, 11)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ops.gbdt_predict(p, xq)),
        np.asarray(gbdt.predict_efficient(p, xq)), atol=1e-5)


@pytest.mark.parametrize("b,c,d,k", [
    (5, 64, 16, 7),
    (16, 128, 32, 10),
    (3, 40, 8, 5),
    (1, 8, 4, 3),
])
def test_bucket_topk_matches_oracle(b, c, d, k):
    rng = np.random.default_rng(hash((b, c, d, k)) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    vecs = jnp.asarray(rng.normal(size=(b, c, d)), jnp.float32)
    sqn = jnp.sum(vecs**2, axis=2)
    ids = jnp.asarray(rng.integers(0, 10_000, (b, c)), jnp.int32)
    ids = jnp.where(jnp.asarray(rng.random((b, c))) < 0.1, -1, ids)
    run_d = jnp.sort(jnp.asarray(rng.random((b, k)) * 20, jnp.float32), 1)
    run_i = jnp.asarray(rng.integers(0, 10_000, (b, k)), jnp.int32)
    dk_, ik_ = ops.bucket_topk(q, vecs, sqn, ids, run_d, run_i)
    dr, ir = ref.bucket_topk_ref(q, vecs, sqn, ids, run_d, run_i)
    np.testing.assert_allclose(np.asarray(dk_), np.asarray(dr), atol=1e-3)
    # output stays sorted ascending and never worse than the old top-k
    out = np.asarray(dk_)
    assert (np.diff(out, axis=1) >= -1e-5).all()
    assert (out <= np.asarray(run_d) + 1e-5).all()


@settings(deadline=None, max_examples=10)
@given(b=st.integers(1, 12), c=st.integers(4, 128), d=st.integers(2, 24),
       k=st.integers(1, 8))
def test_bucket_topk_property(b, c, d, k):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    vecs = jnp.asarray(rng.normal(size=(b, c, d)), jnp.float32)
    sqn = jnp.sum(vecs**2, axis=2)
    ids = jnp.asarray(rng.integers(0, 1000, (b, c)), jnp.int32)
    run_d = jnp.full((b, k), jnp.inf, jnp.float32)
    run_i = jnp.full((b, k), -1, jnp.int32)
    dk_, ik_ = ops.bucket_topk(q, vecs, sqn, ids, run_d, run_i)
    dr, ir = ref.bucket_topk_ref(q, vecs, sqn, ids, run_d, run_i)
    np.testing.assert_allclose(np.asarray(dk_), np.asarray(dr), atol=1e-3)
