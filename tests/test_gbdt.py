import numpy as np
import jax.numpy as jnp

from repro import gbdt


def _toy(n=5000, f=11, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = (np.sin(x[:, 0]) + 0.5 * (x[:, 1] > 0.3) * x[:, 2]
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    return x, y


def test_gbdt_fits_nonlinear_target():
    x, y = _toy()
    p = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=40, depth=5))
    pred = np.asarray(gbdt.predict_jit(p, jnp.asarray(x)))
    mse = float(np.mean((pred - y) ** 2))
    assert mse < 0.05, mse          # noise floor ~0.01, var(y) ~0.5


def test_gbdt_deterministic():
    x, y = _toy(2000)
    p1 = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=10, depth=4))
    p2 = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=10, depth=4))
    np.testing.assert_array_equal(np.asarray(p1.leaf), np.asarray(p2.leaf))
    np.testing.assert_array_equal(np.asarray(p1.feat), np.asarray(p2.feat))


def test_model_selection_ordering():
    """Paper §4.1.5: GBDT <= RF < linear on nonlinear targets."""
    x, y = _toy(4000)
    g = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=40, depth=5))
    lin = gbdt.fit_linear(x, y)
    mse_g = float(np.mean((np.asarray(gbdt.predict_jit(g, jnp.asarray(x))) - y) ** 2))
    mse_l = float(np.mean((np.asarray(lin.predict(jnp.asarray(x))) - y) ** 2))
    assert mse_g < mse_l


def test_predict_paths_agree():
    x, y = _toy(2000)
    p = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=15, depth=4))
    a = np.asarray(gbdt.predict(p, jnp.asarray(x[:64])))
    b = np.asarray(gbdt.predict_efficient(p, jnp.asarray(x[:64])))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_state_dict_roundtrip():
    x, y = _toy(1000)
    p = gbdt.fit(x, y, gbdt.GBDTConfig(num_trees=5, depth=3))
    p2 = gbdt.from_state_dict(gbdt.to_state_dict(p))
    a = np.asarray(gbdt.predict_efficient(p, jnp.asarray(x[:32])))
    b = np.asarray(gbdt.predict_efficient(p2, jnp.asarray(x[:32])))
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_decision_tree_and_rf():
    x, y = _toy(3000)
    dt = gbdt.fit_decision_tree(x, y, depth=6)
    rf = gbdt.fit_random_forest(x, y, num_trees=10, depth=5)
    for p in (dt, rf):
        pred = np.asarray(gbdt.predict_jit(p, jnp.asarray(x)))
        assert np.isfinite(pred).all()
        assert float(np.mean((pred - y) ** 2)) < float(np.var(y))
