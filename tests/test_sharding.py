"""Sharding rules + HLO collective accounting + elastic restore."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs, ckpt
from repro.dist import sharding as sh
from repro.models import model_zoo
from repro.utils import hlo as hlo_lib


def _fake_mesh_161():
    # single-device mesh with production axis names: rules must degrade to
    # replication (divisibility check) without erroring.
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_param_specs_build_for_all_archs(arch):
    cfg = configs.get_config(arch)
    mesh = _fake_mesh_161()
    tree = model_zoo.abstract_params(cfg)
    specs = sh.param_shardings(tree, mesh)
    n = len(jax.tree.leaves(specs))
    assert n == len(jax.tree.leaves(tree))


def test_opt_sharding_structures():
    cfg = configs.get_config("smollm-360m")
    mesh = _fake_mesh_161()
    tree = model_zoo.abstract_params(cfg)
    from repro.train import step as step_lib
    init_opt, _ = step_lib.make_train_step(cfg)
    opt_abs = jax.eval_shape(init_opt, tree)
    o_sh = sh.opt_shardings(opt_abs, tree, mesh)
    assert set(o_sh.keys()) == set(opt_abs.keys())
    # adafactor variant
    init_opt2, _ = step_lib.make_train_step(cfg, optimizer="adafactor")
    opt_abs2 = jax.eval_shape(init_opt2, tree)
    o_sh2 = sh.opt_shardings(opt_abs2, tree, mesh)
    assert "leaves" in o_sh2


def test_collective_parser_weights_loops():
    hlo = """
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %ag = f32[8,8]{1,0} all-gather(%x), dimensions={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ag)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %ar = f32[8,8]{1,0} all-reduce(%a), to_apply=%add
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %ar)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    out = hlo_lib.collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 8 * 4           # once
    assert out["all-gather"] == 8 * 8 * 4 * 5       # 5 loop trips


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint saved de-sharded restores under a different mesh's
    shardings (the elastic contract; on 1 device both meshes are (1,1))."""
    mesh = _fake_mesh_161()
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(str(tmp_path), 3, tree)
    shardings = {"w": jax.NamedSharding(mesh, P(None, None))}
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    restored, meta = ckpt.restore(str(tmp_path), like, shardings=shardings)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]))


def test_sharded_flat_search_single_device():
    from repro.dist import collectives
    mesh = jax.make_mesh((1,), ("model",))
    fn = collectives.make_sharded_flat_search(mesh, k=5)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    d, i = fn(q, x)
    from repro.index import flat
    d_ref, i_ref = flat.search(q, x, 5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), atol=1e-3)
