"""Observability suite (repro.obs): shared percentile math, the metrics
registry, the trace-span/trajectory-ring contracts, traced-serve parity,
the trace-ledger property (exactly one terminal per admitted query, under
host kills and mid-serve hot-swaps), the mixed-target acceptance scenario
(hosts {1, 2}, ivf + hnsw, hedging + one online compaction swap) and the
explain CLI."""
import json

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import mutate
from repro.core import api, engines
from repro.index import hnsw, ivf
from repro.obs import explain as explain_lib
from repro.obs import metrics as metrics_lib
from repro.obs import stats as stats_lib
from repro.obs import trace as trace_lib
from repro.serve import DarthServer, TierConfig


# -- obs.stats: the one percentile definition ------------------------------

def test_percentile_empty_and_single_sample():
    assert np.isnan(stats_lib.percentile([], 99))
    assert np.isnan(stats_lib.p50([]))
    assert np.isnan(stats_lib.p99([np.nan, np.inf]))   # non-finite dropped
    # a single sample IS its own p50 / p99 / p01
    for q in (1, 50, 99):
        assert stats_lib.percentile([3.5], q) == 3.5


def test_percentile_conservative_tail_rounding():
    # 2-sample p99 is the max (linear would sit just under it), 2-sample
    # p01 is the min — tails round AWAY from the median
    assert stats_lib.p99([1.0, 10.0]) == 10.0
    assert stats_lib.p01([1.0, 10.0]) == 1.0
    # the median keeps linear interpolation (no conservative direction)
    assert stats_lib.p50([1.0, 10.0]) == pytest.approx(5.5)
    # tails always land ON an observed sample
    xs = list(np.linspace(0.0, 1.0, 7))
    for q in (1, 25, 75, 99):
        assert stats_lib.percentile(xs, q) in xs
    p50, p99 = stats_lib.summarize([2.0, 4.0, 9.0])
    assert p50 == 4.0 and p99 == 9.0


# -- obs.metrics -----------------------------------------------------------

def test_counter_is_monotonic_and_labelled():
    c = metrics_lib.Counter("x_total", "help")
    c.inc()
    c.inc(2.5, host="0")
    assert c.value() == 1.0
    assert c.value(host="0") == 2.5
    assert c.value(host="1") == 0.0
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_histogram_buckets_and_shared_summary():
    h = metrics_lib.Histogram("lat_ms", "help", edges=(1.0, 10.0))
    for v in (0.5, 2.0, 3.0, 100.0):
        h.observe(v)
    assert h.count() == 4
    p50, p99 = h.summary()
    assert p50 == 2.5 and p99 == 100.0   # same math as obs.stats
    assert h.count(host="9") == 0


def test_registry_declare_or_get_and_type_collision():
    reg = metrics_lib.MetricsRegistry()
    c = reg.counter("a_total", "h")
    assert reg.counter("a_total") is c          # declare-or-get
    with pytest.raises(TypeError, match="already declared"):
        reg.gauge("a_total")
    g = reg.gauge("g")
    g.set(4.0)
    assert g.value() == 4.0 and np.isnan(g.value(host="1"))
    e1 = reg.event("drift", worst_gap=0.03)
    e2 = reg.event("recal")
    assert e2["seq"] == e1["seq"] + 1           # seq-clocked, ordered


def test_prometheus_exposition_format(tmp_path):
    reg = metrics_lib.serve_metrics(metrics_lib.MetricsRegistry())
    assert metrics_lib.serve_metrics(None) is None
    reg.counter("darth_queries_total").inc(3, outcome="completed")
    reg.histogram("darth_chunk_latency_ms").observe(0.7)
    page = reg.to_prometheus()
    assert '# TYPE darth_queries_total counter' in page
    assert 'darth_queries_total{outcome="completed"} 3' in page
    assert '# TYPE darth_chunk_latency_ms histogram' in page
    assert 'darth_chunk_latency_ms_bucket{le="1"} 1' in page
    assert 'darth_chunk_latency_ms_bucket{le="+Inf"} 1' in page
    assert 'darth_chunk_latency_ms_count 1' in page
    # pre-declared families appear even with zero traffic
    assert "darth_harvest_recall" in page
    reg.write_prometheus(str(tmp_path / "m.prom"))
    reg.event("swap", epoch=1)
    reg.write_events(str(tmp_path / "ev.jsonl"), append=False)
    ev = [json.loads(x) for x in
          (tmp_path / "ev.jsonl").read_text().splitlines()]
    assert ev[0]["kind"] == "swap" and ev[0]["epoch"] == 1


# -- obs.trace: ring + tracer contracts ------------------------------------

def test_trajectory_ring_record_and_window():
    traj = trace_lib.traj_init(2, 4)
    assert traj.shape == (2, 4)
    assert (np.asarray(traj) == trace_lib.NO_PREDICTION).all()
    # step g lands at column (g - 1) % cap
    for g in range(1, 7):
        r = jnp.full((2,), g / 10.0, jnp.float32)
        traj = trace_lib.traj_record(traj, jnp.int32(g), r)
    row = np.asarray(traj)[0]
    # steps 5, 6 overwrote columns 0, 1: ring holds [.5, .6, .3, .4]
    np.testing.assert_allclose(row, [0.5, 0.6, 0.3, 0.4], atol=1e-6)
    # admitted at step 2, harvested at step 6 -> steps 3..6, oldest first
    w, trunc = trace_lib.traj_window(row, 2, 6, 0)
    np.testing.assert_allclose(w, [0.3, 0.4, 0.5, 0.6], atol=1e-6)
    assert not trunc
    # window longer than the ring keeps the most recent cap entries
    # (unrolled by the cursor) and reports the dropped prefix
    w, trunc = trace_lib.traj_window(row, 0, 6, 0)
    np.testing.assert_allclose(w, [0.3, 0.4, 0.5, 0.6], atol=1e-6)
    assert trunc
    assert trace_lib.traj_window(row, 6, 6, 0) == ([], False)
    # base offset: ring re-initialized at engine step 10 counts its
    # columns from there (device steps are chunk-local after a rebuild)
    t2 = trace_lib.traj_init(1, 4)
    for s, v in ((1, 0.1), (2, 0.2)):
        t2 = trace_lib.traj_record(t2, jnp.int32(s),
                                   jnp.full((1,), v, jnp.float32))
    row2 = np.asarray(t2)[0]
    np.testing.assert_allclose(trace_lib.traj_window(row2, 10, 12, 10)[0],
                               [0.1, 0.2], atol=1e-6)
    np.testing.assert_allclose(trace_lib.traj_window(row2, 11, 12, 10)[0],
                               [0.2], atol=1e-6)


def test_trajectory_window_outliving_ring_is_exact_suffix():
    """Regression: a query served for more than traj_cap steps must
    drain the most recent cap predictions IN STEP ORDER (unrolled by
    the cursor, not raw ring order) and be flagged truncated."""
    cap = 5
    traj = trace_lib.traj_init(1, cap)
    full = []
    for g in range(1, 14):                     # 13 steps >> cap
        v = g / 100.0
        full.append(v)
        traj = trace_lib.traj_record(traj, jnp.int32(g),
                                     jnp.full((1,), v, jnp.float32))
        row = np.asarray(traj)[0]
        w, trunc = trace_lib.traj_window(row, 0, g, 0)
        # the drained window is always the exact most-recent suffix of
        # the true step series, regardless of wrap count
        np.testing.assert_allclose(w, full[-cap:], atol=1e-6)
        assert trunc == (g > cap)
        assert w[-1] == pytest.approx(v)


def test_tracer_exactly_once_and_reason_taxonomy():
    tr = trace_lib.Tracer()
    tr.begin()
    with pytest.raises(ValueError, match="unknown termination reason"):
        tr.terminal(0, "gave_up")
    tr.event("admit", qid=0, host=1, step=0)
    tr.terminal(0, "interval_met", step=4, r_pred=0.93)
    with pytest.raises(RuntimeError, match="exactly-once"):
        tr.terminal(0, "engine_exhausted")
    # the one sanctioned mutation: a hedge upgrade
    sp = tr.upgrade_terminal(0, step=6, r_pred=0.97)
    assert sp.attrs["upgraded"] and sp.attrs["r_pred"] == 0.97
    assert sp.step == 6
    spans = tr.finish()
    assert [s.seq for s in spans] == sorted(s.seq for s in spans)
    assert tr.terminals()[0].attrs["reason"] == "interval_met"
    with pytest.raises(ValueError, match="traj_cap"):
        trace_lib.Tracer(traj_cap=0)


def test_trace_jsonl_roundtrip_and_serve_filter(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tr = trace_lib.Tracer(path)
    for reason in ("interval_met", "budget_truncated"):
        tr.begin()
        tr.event("admit", qid=7, host=0, step=0)
        tr.terminal(7, reason, step=3)
        tr.finish()
    last = trace_lib.load_trace(path)          # default: LAST serve
    assert {s["serve"] for s in last} == {2}
    assert [s for s in last if s["kind"] == "terminal"][0]["reason"] \
        == "budget_truncated"
    first = trace_lib.load_trace(path, serve=1)
    assert [s for s in first if s["kind"] == "terminal"][0]["reason"] \
        == "interval_met"
    assert trace_lib.load_trace(str(tmp_path / "t.jsonl")) != []


# -- served integration ----------------------------------------------------

@pytest.fixture(scope="module")
def obs_setup():
    from repro.data import vectors
    ds = vectors.make_dataset(n=2000, d=16, num_learn=192, num_queries=64,
                              clusters=16, cluster_std=1.0, seed=4)
    index = ivf.build(ds.base, nlist=16, seed=4)
    eng = engines.ivf_engine(index, k=10, nprobe=16)
    d = api.Darth(make_engine=lambda **kw: engines.ivf_engine(index, **kw),
                  engine=eng)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=128)
    return ds, index, d


def _ledger_reasons(results, terminals):
    """Cross-check every terminal reason against the results ledger."""
    for qid, span in terminals.items():
        reason = span.attrs["reason"]
        if results[qid] is not None:
            assert reason in ("interval_met", "engine_exhausted",
                              "budget_truncated", "host_killed"), \
                (qid, reason)
        else:
            assert reason in ("shed", "abandoned"), (qid, reason)


def _check_trajectories(terminals):
    """Terminal trajectory's final value must equal the harvested slot's
    prediction (the device ring and the host fetch agree)."""
    checked = 0
    for span in terminals.values():
        traj = span.attrs.get("trajectory")
        rp = span.attrs.get("r_pred")
        if traj and rp is not None:
            assert traj[-1] == pytest.approx(rp, abs=1e-6), span
            checked += 1
    return checked


def test_traced_serve_matches_untraced_and_closes_every_query(obs_setup):
    """Tracing must be a pure observer: byte-identical results/ndis vs
    the untraced server, plus exactly one terminal span per query whose
    trajectory ends at the harvested slot's prediction."""
    ds, index, d = obs_setup
    rts = np.tile([0.7, 0.9, 0.8, 0.95], 16).astype(np.float32)

    ref_server = DarthServer(d.engine, d.trained.predictor,
                             d.interval_for_target, num_slots=8,
                             steps_per_sync=2)
    ref, ref_stats = ref_server.serve(ds.queries, rts)

    tracer = trace_lib.Tracer(traj_cap=32)
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2, tracer=tracer)
    res, stats = server.serve(ds.queries, rts)
    assert stats.completed == ref_stats.completed == 64
    assert stats.ndis_harvested == ref_stats.ndis_harvested
    for a, b in zip(ref, res):
        np.testing.assert_allclose(a[0], b[0], atol=0)
        np.testing.assert_array_equal(a[1], b[1])

    terms = tracer.terminals()
    assert sorted(terms) == list(range(64))        # every query, once
    for qid, span in terms.items():
        assert span.attrs["reason"] in ("interval_met", "engine_exhausted")
        assert span.attrs["target"] == pytest.approx(float(rts[qid]))
    assert _check_trajectories(terms) == 64
    # refill splices after the first fill leave admit spans marked so
    admits = [s for s in tracer.last_spans if s.kind == "admit"]
    assert len(admits) == 64 and stats.refills > 0
    assert any(s.attrs.get("refill") for s in admits)


def test_served_trajectory_outliving_ring(obs_setup):
    """Regression (queries served > traj_cap steps): the drained
    trajectory must be the exact most-recent suffix of the full series
    (cursor-unrolled, in step order), flagged truncated, and still end
    at the harvested r_pred; explain marks the dropped prefix."""
    ds, index, d = obs_setup
    rts = np.full((64,), 0.95, np.float32)    # high target -> long lives
    cap = 2

    big = trace_lib.Tracer(traj_cap=64)       # never wraps here
    DarthServer(d.engine, d.trained.predictor, d.interval_for_target,
                num_slots=8, steps_per_sync=3,
                tracer=big).serve(ds.queries, rts)
    small = trace_lib.Tracer(traj_cap=cap)
    DarthServer(d.engine, d.trained.predictor, d.interval_for_target,
                num_slots=8, steps_per_sync=3,
                tracer=small).serve(ds.queries, rts)

    terms_small, terms_big = small.terminals(), big.terminals()
    truncated_qids = []
    for qid, span in terms_small.items():
        traj = span.attrs.get("trajectory")
        if traj is None:
            continue
        assert len(traj) <= cap
        ref = terms_big[qid].attrs["trajectory"]
        lived = span.step - span.attrs["admit_step"]
        # exact suffix of the unwrapped reference trajectory
        np.testing.assert_allclose(traj, ref[-len(traj):], atol=0)
        assert bool(span.attrs.get("trajectory_truncated")) == \
            (lived > cap), span
        rp = span.attrs.get("r_pred")
        if traj and rp is not None:
            assert traj[-1] == pytest.approx(rp, abs=1e-6)
        if span.attrs.get("trajectory_truncated"):
            truncated_qids.append(qid)
    assert truncated_qids, "workload never outlived the ring (cap=2?)"

    from repro.obs import explain as explain_lib
    story = explain_lib.explain(small.last_spans, qid=truncated_qids[0])
    assert "…" in story and "last " in story


def test_single_chunk_serve_has_degenerate_percentiles(obs_setup):
    """ServeStats edge case: one chunk -> one latency sample, so p50 and
    p99 are that sample (NaN/interp regressions pinned by obs.stats)."""
    ds, index, d = obs_setup
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2)
    _, stats = server.serve(ds.queries[:8],
                            np.full((8,), 0.9, np.float32),
                            max_engine_steps=2)
    assert np.isfinite(stats.chunk_ms_p50)
    assert stats.chunk_ms_p50 == stats.chunk_ms_p99


@settings(deadline=None, max_examples=5)
@given(hosts=st.sampled_from([1, 2, 4]), budget=st.sampled_from([0, 4]),
       kill=st.booleans(), kill_step=st.integers(2, 6),
       swap_at=st.integers(0, 2))
def test_trace_ledger_exactly_once_property(obs_setup, hosts, budget,
                                            kill, kill_step, swap_at):
    """Satellite property: every admitted query id appears in the trace
    with EXACTLY one terminal span whose reason is consistent with the
    results ledger (served / shed / abandoned) — including under
    kill_hosts fault injection and a mid-serve request_swap."""
    ds, index, d = obs_setup
    n = 64
    rts = np.tile([0.8, 0.9], n // 2).astype(np.float32)
    tracer = trace_lib.Tracer(traj_cap=16)
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2, hosts=hosts, tracer=tracer)
    kill_hosts = {1: kill_step} if kill and hosts > 1 else {}
    seen = {"n": 0}

    def on_boundary(srv):
        seen["n"] += 1
        if swap_at and seen["n"] == swap_at and not srv.swap_pending:
            srv.request_swap(engines.ivf_engine(index, k=10, nprobe=16),
                             contents_only=True)

    results, stats = server.serve(
        ds.queries[:n], rts, max_engine_steps=budget or 10_000,
        kill_hosts=kill_hosts,
        on_boundary=on_boundary if swap_at else None)

    terms = tracer.terminals()
    assert sorted(terms) == list(range(n))         # exactly once, all n
    _ledger_reasons(results, terms)
    reasons = [s.attrs["reason"] for s in terms.values()]
    assert stats.completed == sum(
        r in ("interval_met", "engine_exhausted") for r in reasons)
    assert stats.truncated == sum(
        r in ("budget_truncated", "host_killed") for r in reasons)
    assert sum(h.abandoned for h in stats.hosts) == reasons.count(
        "abandoned")
    # killed hosts close their in-flight queries as host_killed
    if kill_hosts and any(h.killed and h.truncated for h in stats.hosts):
        assert "host_killed" in reasons
    # a swap that applied left its server-level breadcrumbs
    if stats.swaps:
        kinds = [s.kind for s in tracer.last_spans]
        assert "swap_staged" in kinds and "swap_applied" in kinds


@pytest.mark.parametrize("kind,hosts", [("ivf", 1), ("ivf", 2),
                                        ("hnsw", 2)])
def test_acceptance_hedged_compacting_serve_closes_every_query(
        obs_setup, kind, hosts):
    """The PR acceptance bar: a mixed-target serve on hosts {1, 2} with
    both engine families, hedging tiers and ONE online compaction swap
    yields exactly one terminal span per query, with a correct reason
    and a trajectory whose final value matches the harvested slot's
    prediction; the compaction lifecycle is visible in the trace."""
    ds, _, _ = obs_setup
    if kind == "ivf":
        index = ivf.build(ds.base, nlist=16, seed=4)
        make = lambda mut, **kw: engines.mutable_engine(        # noqa: E731
            engines.ivf_engine(mut.base, k=10, nprobe=16), mut.delta)
    else:
        index = hnsw.build(ds.base, m=8, passes=1, ef_construction=32,
                           seed=4)
        make = lambda mut, **kw: engines.mutable_engine(        # noqa: E731
            engines.hnsw_engine(mut.base, k=10, ef=32), mut.delta)
    mut = mutate.MutableIndex(index, capacity=256)
    d = api.Darth(make_engine=lambda **kw: make(mut, **kw),
                  engine=make(mut))
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=128)

    tracer = trace_lib.Tracer(traj_cap=32)
    tiers = TierConfig(hard_quantile=0.75, hard_slot_fraction=0.25,
                       hedge=True)
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2, hosts=hosts, tiers=tiers,
                         tracer=tracer)
    state = {"swapped": False}

    def on_boundary(srv):
        if srv.swap_pending or state["swapped"]:
            return
        if not mut.compacting:
            mut.begin_compaction()
            srv.tracer.event("compact_begin", step=srv.boundary_step,
                             epoch=srv.engine_epoch)
        elif mut.compact_tick():
            mut.swap_compaction()
            srv.tracer.event("compact_swap", step=srv.boundary_step,
                             epoch=srv.engine_epoch)
            srv.request_swap(make(mut), contents_only=True)
            state["swapped"] = True

    n = ds.queries.shape[0]
    rts = np.tile([0.7, 0.9, 0.8, 0.95], n // 4).astype(np.float32)
    results, stats = server.serve(ds.queries, rts,
                                  on_boundary=on_boundary)
    assert stats.completed == n and all(r is not None for r in results)
    assert state["swapped"] and stats.swaps == 1

    terms = tracer.terminals()
    assert sorted(terms) == list(range(n))         # exactly one each
    _ledger_reasons(results, terms)
    assert _check_trajectories(terms) == n
    assert stats.hedged >= stats.hedge_upgrades + stats.hedge_epoch_dropped
    kinds = [s.kind for s in tracer.last_spans]
    for k in ("compact_begin", "compact_swap", "swap_staged",
              "swap_applied"):
        assert k in kinds, k
    # some query's flight window crossed the server-level swap events
    crossed = [explain_lib.query_story(tracer.last_spans, q)["crossed"]
               for q in range(n)]
    assert any(crossed)


def test_shed_queries_get_shed_terminals(obs_setup):
    """Overload shedding closes refused queries with reason 'shed' (they
    never held a slot) and the trace agrees with HostStats.shed_ids."""
    ds, index, d = obs_setup
    tracer = trace_lib.Tracer(traj_cap=16)
    tiers = TierConfig(hard_quantile=0.5, hard_slot_fraction=0.25,
                       max_queue=2, overload="shed")
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2, tiers=tiers, tracer=tracer)
    results, stats = server.serve(ds.queries,
                                  np.full((64,), 0.9, np.float32))
    assert stats.shed > 0
    terms = tracer.terminals()
    assert sorted(terms) == list(range(64))
    shed_ids = sorted(i for h in stats.hosts for i in h.shed_ids)
    traced_shed = sorted(q for q, s in terms.items()
                         if s.attrs["reason"] == "shed")
    assert traced_shed == shed_ids
    for q in traced_shed:
        assert results[q] is None
        assert "closed without holding a slot" in explain_lib.explain(
            tracer.last_spans, qid=q)


def test_serve_exports_metrics_matching_stats(obs_setup):
    """Metrics work tracer-less: terminal-outcome counters equal the
    ServeStats ledger and the exposition page renders every family."""
    ds, index, d = obs_setup
    reg = metrics_lib.MetricsRegistry()
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2, hosts=2, metrics=reg)
    _, stats = server.serve(ds.queries, np.full((64,), 0.9, np.float32))
    q = reg.counter("darth_queries_total")
    assert q.value(outcome="completed") == stats.completed == 64
    assert q.value(outcome="truncated") == 0
    lat = reg.histogram("darth_chunk_latency_ms")
    assert lat.count() > 0
    assert reg.histogram("darth_harvest_recall").count() > 0
    assert reg.histogram("darth_service_steps").count() == 64
    assert reg.counter("darth_refills_total").value(host="0") > 0
    assert reg.gauge("darth_engine_epoch").value() == server.engine_epoch
    page = reg.to_prometheus()
    assert 'darth_queries_total{outcome="completed"} 64' in page


def test_compaction_and_drift_metrics_events(obs_setup):
    """mutate.MutableIndex and the drift monitor land their lifecycle
    in an attached registry: compact begin/tick/swap events + the
    compaction counter, drift events + the worst-gap gauge."""
    from repro.mutate import monitor as monitor_lib

    ds, index, d = obs_setup
    reg = metrics_lib.MetricsRegistry()
    mut = mutate.MutableIndex(ivf.build(ds.base, nlist=16, seed=4),
                              capacity=256)
    mut.attach_metrics(reg)
    mut.begin_compaction()
    while not mut.compact_tick():
        pass
    mut.swap_compaction()
    kinds = [e["kind"] for e in reg.events]
    assert kinds[0] == "compact_begin" and kinds[-1] == "compact_swap"
    assert "compact_tick" in kinds
    assert reg.counter("darth_compactions_total").value() == 1

    mon = monitor_lib.RecalibrationMonitor(mut, d, metrics=reg)
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2)
    res, _ = server.serve(ds.queries[:16],
                          np.full((16,), 0.9, np.float32))
    mon.observe(ds.queries[:16], np.full((16,), 0.9, np.float32),
                np.stack([r[1] for r in res]))
    rep = mon.drift()
    drift_ev = [e for e in reg.events if e["kind"] == "drift"]
    assert drift_ev and drift_ev[-1]["num_queries"] == 16
    assert reg.gauge("darth_drift_worst_gap").value() == pytest.approx(
        rep.worst_gap)


# -- explain ---------------------------------------------------------------

def test_explain_story_and_cli(obs_setup, tmp_path, capsys):
    ds, index, d = obs_setup
    path = str(tmp_path / "trace.jsonl")
    tracer = trace_lib.Tracer(path, traj_cap=32, label="unit")
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8,
                         steps_per_sync=2, tracer=tracer)
    rts = np.tile([0.8, 0.95], 32).astype(np.float32)
    server.serve(ds.queries, rts)

    story = explain_lib.query_story(tracer.last_spans, 5)
    assert story["qid"] == 5 and story["admissions"]
    assert story["terminal"]["reason"] in ("interval_met",
                                           "engine_exhausted")
    with pytest.raises(KeyError, match="no terminal span"):
        explain_lib.query_story(tracer.last_spans, 999)

    text = explain_lib.explain(tracer.last_spans, qid=5)
    assert text.startswith("query 5:") and "admitted on host" in text
    assert "trajectory" in text
    # default pick: the worst final predicted recall among terminals
    worst = min(tracer.terminals().values(),
                key=lambda s: s.attrs.get("r_pred", float("inf")))
    assert explain_lib.explain(tracer.last_spans).startswith(
        f"query {worst.qid}:")
    roll = explain_lib.summary(tracer.last_spans)
    assert "64 queries" in roll and "p50/p99" in roll

    # CLI round-trips through the JSONL file the tracer appended
    assert explain_lib.main([path, "--summary"]) == 0
    assert "64 queries" in capsys.readouterr().out
    assert explain_lib.main([path, "--qid", "5"]) == 0
    assert "query 5:" in capsys.readouterr().out
    assert explain_lib.main([path]) == 0
    assert f"query {worst.qid}:" in capsys.readouterr().out
    assert explain_lib.explain([]) == \
        "trace holds no terminal spans (nothing was served?)"
