"""repro.mutate: delta tier + tombstones + compaction + recalibration.

Covers the streaming-conformance contract (ISSUE 4): after a burst of
>= 20% inserts + >= 10% deletes, DARTH search through mutable_engine
meets declared recall targets {0.80, 0.90, 0.95} within 0.03 against
fresh base+delta ground truth for BOTH engine families, tombstoned ids
are never returned, and post-compaction search through the wrapper
matches a from-scratch search over the compacted index exactly."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api, darth_search, engines
from repro.data import vectors
from repro.index import flat, hnsw, ivf
from repro import mutate

K = 10
TARGETS = (0.80, 0.90, 0.95)
TOLERANCE = 0.03


def _live_gt(mut, q, k=K):
    live_ids, live_vecs = mut.live_vectors()
    _, rows = flat.search(jnp.asarray(q), jnp.asarray(live_vecs), k)
    rows = np.asarray(rows)
    return np.where(rows >= 0, live_ids[np.maximum(rows, 0)], -1
                    ).astype(np.int32)


@pytest.fixture(scope="module")
def small_ds():
    return vectors.make_dataset(n=2000, d=16, num_learn=128,
                                num_queries=64, clusters=16,
                                cluster_std=1.0, seed=0)


# --- delta tier -------------------------------------------------------------

def test_delta_ring_write_scan_tombstone():
    delta = mutate.make_delta(8, 4)
    assert int(mutate.delta.live_count(delta)) == 0
    vecs = np.eye(4, dtype=np.float32)[:3] * 2.0
    delta = mutate.delta.write(delta, jnp.asarray([0, 1, 2], jnp.int32),
                               jnp.asarray(vecs),
                               jnp.asarray([100, 101, 102], jnp.int32))
    assert int(mutate.delta.live_count(delta)) == 3
    q = jnp.asarray(vecs[:1])
    d, g, live, nins = mutate.delta.delta_topk(delta, q, 3)
    assert int(live) == 3
    assert np.asarray(g)[0, 0] == 100
    assert np.asarray(d)[0, 0] == pytest.approx(0.0)
    # padded slot -1 in the write is dropped, not scattered to slot 0
    delta2 = mutate.delta.write(delta, jnp.asarray([-1], jnp.int32),
                                jnp.zeros((1, 4), jnp.float32),
                                jnp.asarray([-1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(delta2.ids),
                                  np.asarray(delta.ids))
    # tombstone: masked back to the pad convention
    delta = mutate.delta.tombstone(delta, jnp.asarray([0, -1], jnp.int32))
    assert int(mutate.delta.live_count(delta)) == 2
    d, g, _, _ = mutate.delta.delta_topk(delta, q, 3)
    assert 100 not in np.asarray(g)


def test_delta_capacity_guard(small_ds):
    index = ivf.build(small_ds.base[:500], nlist=8, seed=0)
    mut = mutate.MutableIndex(index, capacity=16)
    mut.insert(small_ds.queries[:10])
    with pytest.raises(RuntimeError, match="delta tier full"):
        mut.insert(small_ds.queries[:10])
    # deleting frees capacity (ring reuses tombstoned slots)
    ids = np.arange(500, 510)
    assert mut.delete(ids) == 10
    mut.insert(small_ds.queries[:10])
    assert mut.num_delta == 10


def test_ring_reuse_never_overwrites_live_slots(small_ds):
    """Regression: with tombstoned slots interleaved behind the cursor,
    a blind cursor walk could land on a LIVE slot and silently drop its
    vector; placement must skip live slots."""
    index = ivf.build(small_ds.base[:500], nlist=8, seed=0)
    mut = mutate.MutableIndex(index, capacity=4)
    ids = mut.insert(small_ds.queries[:4])        # ids 500..503, full ring
    mut.delete([int(ids[0])])                     # slot 0 dead
    (id4,) = mut.insert(small_ds.queries[4:5])    # reuses slot 0
    mut.delete([int(ids[2])])                     # slot 2 dead
    (id5,) = mut.insert(small_ds.queries[5:6])    # must land on slot 2,
    #                                               NOT live slot 1
    live = set(np.asarray(mut.delta.ids).tolist()) - {-1}
    expect = {int(ids[1]), int(ids[3]), int(id4), int(id5)}
    assert live == expect
    assert mut.num_delta == 4


def test_delete_of_just_inserted_id_across_wrap(small_ds):
    """Audit regression (delete-of-just-inserted-id): deleting an id in
    the same tick it was inserted — after the cursor has wrapped and
    the insert reused a tombstoned slot — must keep the host live-count,
    the device live_count and the slot maps agreed, and the freed slot
    must be reusable without overwriting any live slot."""
    index = ivf.build(small_ds.base[:500], nlist=8, seed=0)
    mut = mutate.MutableIndex(index, capacity=4)
    a = mut.insert(small_ds.queries[:3])       # slots 0,1,2; cursor -> 3
    mut.delete([int(a[0]), int(a[1])])         # slots 0,1 tombstoned
    b = mut.insert(small_ds.queries[3:6])      # wraps: slots 3, 0, 1
    mut.delete([int(b[2])])                    # delete the JUST-inserted id
    assert mut.num_delta == 3
    assert int(mutate.delta.live_count(mut.delta)) == 3
    assert mut.num_live == 500 + 6 - 3
    # the freed slot is reused; no live slot is overwritten
    (c,) = mut.insert(small_ds.queries[6:7])
    live = set(np.asarray(mut.delta.ids).tolist()) - {-1}
    assert live == {int(a[2]), int(b[0]), int(b[1]), int(c)}
    assert mut.num_delta == 4
    assert int(mutate.delta.live_count(mut.delta)) == 4
    # slot maps agree with the device ring exactly
    ids_dev = np.asarray(mut.delta.ids)
    for i, s in mut._delta_slot.items():
        assert ids_dev[s] == i
    # deleted ids never surface through the wrapper
    meng = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=4, nprobe=8), mut.delta)
    ws = darth_search.plain_search(meng, jnp.asarray(small_ds.queries[:8]))
    found = set(np.asarray(meng.topk_i(ws)).ravel().tolist())
    assert not (found & {int(a[0]), int(a[1]), int(b[2])})


def test_mutable_engine_requires_capacity_ge_k(small_ds):
    index = ivf.build(small_ds.base[:500], nlist=8, seed=0)
    eng = engines.ivf_engine(index, k=10, nprobe=4)
    with pytest.raises(ValueError, match="delta capacity"):
        engines.mutable_engine(eng, mutate.make_delta(4, 16))


# --- empty-delta parity (the wrapper must be invisible) ---------------------

def test_empty_delta_parity_ivf(small_ds):
    ds = small_ds
    index = ivf.build(ds.base, nlist=16, seed=0)
    mut = mutate.MutableIndex(index, capacity=64)
    meng = engines.mutable_engine(engines.ivf_engine(mut.base, k=5,
                                                     nprobe=6), mut.delta)
    q = jnp.asarray(ds.queries[:16])
    d0, i0, s0 = ivf.search(index, q, k=5, nprobe=6)
    ws = darth_search.plain_search(meng, q)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(meng.topk_d(ws)),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i0),
                                  np.asarray(meng.topk_i(ws)))
    np.testing.assert_array_equal(np.asarray(s0.ndis), np.asarray(ws.ndis))
    np.testing.assert_array_equal(np.asarray(s0.ninserts),
                                  np.asarray(ws.ninserts))


def test_empty_delta_parity_hnsw(small_ds):
    ds = small_ds
    index = hnsw.build(ds.base, m=8, passes=1, ef_construction=32, seed=0)
    mut = mutate.MutableIndex(index, capacity=64)
    meng = engines.mutable_engine(engines.hnsw_engine(mut.base, k=5,
                                                      ef=24), mut.delta)
    q = jnp.asarray(ds.queries[:16])
    d0, i0, s0 = hnsw.search(index, q, k=5, ef=24)
    ws = darth_search.plain_search(meng, q)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(meng.topk_d(ws)),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i0),
                                  np.asarray(meng.topk_i(ws)))
    np.testing.assert_array_equal(np.asarray(s0.ndis), np.asarray(ws.ndis))
    np.testing.assert_array_equal(np.asarray(s0.ninserts),
                                  np.asarray(ws.ninserts))


# --- inserts / deletes ------------------------------------------------------

def test_insert_found_delete_masked_ivf(small_ds):
    ds = small_ds
    index = ivf.build(ds.base, nlist=16, seed=0)
    mut = mutate.MutableIndex(index, capacity=128)
    new = ds.queries[:8]
    ids = mut.insert(new)
    assert ids.tolist() == list(range(2000, 2008))
    meng = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=5, nprobe=16), mut.delta)
    ws = darth_search.plain_search(meng, jnp.asarray(new))
    ii = np.asarray(meng.topk_i(ws))
    # an inserted vector is its own exact nearest neighbor
    np.testing.assert_array_equal(ii[:, 0], ids)

    # delete base NNs + one delta insert: none may ever surface again
    _, gt = flat.search(jnp.asarray(ds.queries), jnp.asarray(ds.base), 5)
    kill = np.unique(np.asarray(gt)[:, 0])[:40].tolist() + [int(ids[0])]
    assert mut.delete(kill) == len(kill)
    assert mut.delete(kill) == 0          # idempotent
    meng = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=5, nprobe=16), mut.delta)
    ws = darth_search.plain_search(meng, jnp.asarray(ds.queries))
    found = set(np.asarray(meng.topk_i(ws)).ravel().tolist())
    assert not (found & set(kill))
    # recall vs the live universe stays exact (full probe = brute force)
    gt_live = _live_gt(mut, ds.queries, k=5)
    rec = np.asarray(flat.recall_at_k(
        jnp.asarray(np.asarray(meng.topk_i(ws))), jnp.asarray(gt_live)))
    assert rec.mean() == pytest.approx(1.0)


def test_insert_found_delete_masked_hnsw(small_ds):
    ds = small_ds
    index = hnsw.build(ds.base, m=8, passes=1, ef_construction=32, seed=0)
    mut = mutate.MutableIndex(index, capacity=128)
    new = ds.queries[:8]
    ids = mut.insert(new)
    meng = engines.mutable_engine(
        engines.hnsw_engine(mut.base, k=5, ef=48), mut.delta)
    ws = darth_search.plain_search(meng, jnp.asarray(new))
    ii = np.asarray(meng.topk_i(ws))
    np.testing.assert_array_equal(ii[:, 0], ids)

    _, gt = flat.search(jnp.asarray(ds.queries), jnp.asarray(ds.base), 5)
    kill = np.unique(np.asarray(gt)[:, 0])[:40].tolist() + [int(ids[0])]
    assert mut.delete(kill) == len(kill)
    meng = engines.mutable_engine(
        engines.hnsw_engine(mut.base, k=5, ef=48), mut.delta)
    ws = darth_search.plain_search(meng, jnp.asarray(ds.queries))
    found = set(np.asarray(meng.topk_i(ws)).ravel().tolist())
    assert not (found & set(kill))


# --- compaction parity ------------------------------------------------------

def _burst(mut, ds, seed=3):
    events = vectors.mutation_stream(ds, insert_pct=0.2, delete_pct=0.1,
                                     drift=0.3, steps=4, seed=seed)
    mut.apply(events)
    return events


@pytest.mark.parametrize("quantize", [False, True])
def test_compaction_parity_ivf(small_ds, quantize):
    ds = small_ds
    index = ivf.build(ds.base, nlist=16, seed=0, quantize=quantize)
    mut = mutate.MutableIndex(index, capacity=512)
    _burst(mut, ds)
    dead = set(int(i) for i in mut.deleted_ids)
    mut.compact()
    assert mut.num_delta == 0
    # compacted storage holds exactly the live set, under stable ids
    bi = np.asarray(mut.base.bucket_ids)
    stored = set(bi[bi >= 0].tolist())
    live_ids, _ = mut.live_vectors()
    assert stored == set(int(i) for i in live_ids)
    assert not (stored & dead)
    # post-compaction search through the wrapper == from-scratch search
    # over the compacted index (exact: topk_d / topk_i / ndis)
    q = jnp.asarray(ds.queries[:32])
    d0, i0, s0 = ivf.search(mut.base, q, k=K, nprobe=16)
    meng = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=K, nprobe=16), mut.delta)
    ws = darth_search.plain_search(meng, q)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(meng.topk_d(ws)),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i0),
                                  np.asarray(meng.topk_i(ws)))
    np.testing.assert_array_equal(np.asarray(s0.ndis), np.asarray(ws.ndis))


def test_compaction_parity_hnsw(small_ds):
    ds = small_ds
    index = hnsw.build(ds.base, m=8, passes=1, ef_construction=32, seed=0)
    mut = mutate.MutableIndex(index, capacity=512)
    _burst(mut, ds)
    dead = set(int(i) for i in mut.deleted_ids)
    mut.compact(ef_construction=48, seed=1)
    assert mut.num_delta == 0
    # dead rows are inert (pad convention) and never referenced
    sq = np.asarray(mut.base.sqnorm)
    nbr = np.asarray(mut.base.neighbors)
    rows = np.fromiter(dead, np.int64)
    assert np.isposinf(sq[rows]).all()
    assert (nbr[rows] == -1).all()
    live_edges = nbr[np.isfinite(sq)]
    assert not (set(live_edges[live_edges >= 0].tolist()) & dead)
    assert not (set(np.asarray(mut.base.route_ids).tolist()) & dead)

    q = jnp.asarray(ds.queries[:32])
    d0, i0, s0 = hnsw.search(mut.base, q, k=K, ef=64)
    meng = engines.mutable_engine(
        engines.hnsw_engine(mut.base, k=K, ef=64), mut.delta)
    ws = darth_search.plain_search(meng, q)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(meng.topk_d(ws)),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i0),
                                  np.asarray(meng.topk_i(ws)))
    np.testing.assert_array_equal(np.asarray(s0.ndis), np.asarray(ws.ndis))


# --- monitor ----------------------------------------------------------------

def test_monitor_drift_detection(small_ds):
    ds = small_ds
    index = ivf.build(ds.base, nlist=16, seed=0)
    mut = mutate.MutableIndex(index, capacity=512)
    eng = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=K, nprobe=16), mut.delta)
    d = api.Darth(make_engine=lambda **kw: eng, engine=eng)
    mon = mutate.RecalibrationMonitor(mut, d, targets=(0.9,),
                                      threshold=0.02, capacity=64)
    assert not mon.drift().drifted         # empty buffer: no signal

    # perfect results: no drift
    q = ds.queries[:32]
    stale_gt = _live_gt(mut, q)
    mon.observe(q, np.full((32,), 0.9, np.float32), stale_gt)
    rep = mon.drift()
    assert rep.achieved[0.9] == pytest.approx(1.0)
    assert not rep.drifted

    # a burst bumps the mutation epoch: the pre-burst replay entries
    # are excluded from drift (their gap is irreducible by a refit)
    mut.insert(q)
    mut.insert(q + 1e-3)
    mut.insert(q - 1e-3)
    rep = mon.drift()
    assert rep.num_queries == 0 and not rep.drifted

    # post-burst observations whose results miss the inserted
    # near-duplicates (a stale predictor terminating too early) DO
    # count — the gap is real and a refit can close it
    mon.observe(q, np.full((32,), 0.9, np.float32), stale_gt)
    rep = mon.drift()
    assert rep.num_queries == 32
    assert rep.achieved[0.9] < 1.0 - 0.02
    assert rep.drifted

    # recalibration drops the stale replay entries: they predate the
    # burst and would otherwise keep step() refitting forever
    mon.recalibrate(ds.learn[:64], batch=64)
    assert mon.recalibrations == 1
    assert mon.drift().num_queries == 0
    assert not mon.drift().drifted


# --- streaming conformance (the acceptance contract) ------------------------

@pytest.fixture(scope="module")
def conformance_ds():
    return vectors.make_dataset(n=6000, d=24, num_learn=512,
                                num_queries=128, clusters=32,
                                cluster_std=1.2, seed=0)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["ivf", "hnsw"])
def test_streaming_conformance(conformance_ds, kind):
    ds = conformance_ds
    if kind == "ivf":
        index = ivf.build(ds.base, nlist=32, seed=0)
    else:
        index = hnsw.build(ds.base, m=16, passes=2, ef_construction=96,
                           seed=0)
    mut = mutate.MutableIndex(index, capacity=2048)
    events = vectors.mutation_stream(ds, insert_pct=0.22, delete_pct=0.11,
                                     drift=0.25, steps=6, seed=3)
    mut.apply(events)
    assert mut.num_delta >= 0.2 * 6000
    assert len(mut.deleted_ids) >= 0.1 * 6000

    def make_engine(**kw):
        if kind == "ivf":
            return engines.mutable_engine(
                engines.ivf_engine(mut.base, **kw), mut.delta)
        return engines.mutable_engine(
            engines.hnsw_engine(mut.base, **kw), mut.delta)

    kw = (dict(k=K, nprobe=32) if kind == "ivf"
          else dict(k=K, ef=192, max_steps=400))
    d = api.Darth(make_engine=make_engine, engine=make_engine(**kw))
    # recalibration refit: predictor + intervals learned through the
    # mutated engine against fresh base+delta ground truth
    mon = mutate.RecalibrationMonitor(mut, d, targets=TARGETS)
    mon.recalibrate(ds.learn, batch=256)

    q = jnp.asarray(ds.queries)
    gt_live = _live_gt(mut, ds.queries)
    inner = darth_search.plain_search(d.engine, q)
    plain_rec = float(np.asarray(flat.recall_at_k(
        d.engine.topk_i(inner), jnp.asarray(gt_live))).mean())
    plain_ndis = float(np.asarray(inner.ndis).mean())
    assert plain_rec >= max(TARGETS), plain_rec  # targets attainable

    dead = set(int(i) for i in mut.deleted_ids)
    delta_ids = set(int(i) for i in mut._delta_slot)
    saw_delta = False
    for rt in TARGETS:
        _, ii, st = d.search(q, rt)
        ii = np.asarray(ii)
        rec = float(np.asarray(flat.recall_at_k(
            jnp.asarray(ii), jnp.asarray(gt_live))).mean())
        nd = float(np.asarray(st.inner.ndis).mean())
        assert rec >= rt - TOLERANCE, (kind, rt, rec)
        assert nd < plain_ndis, (kind, rt, nd, plain_ndis)
        found = set(ii.ravel().tolist())
        assert not (found & dead), (kind, rt)   # tombstones never surface
        saw_delta |= bool(found & delta_ids)
    assert saw_delta                            # the delta tier is really

    # post-compaction: same contract against the folded live set
    mut.compact(ef_construction=96, seed=1)
    d.engine = make_engine(**kw)
    mon.recalibrate(ds.learn, batch=256)
    gt_live = _live_gt(mut, ds.queries)
    for rt in TARGETS:
        _, ii, st = d.search(q, rt)
        rec = float(np.asarray(flat.recall_at_k(
            jnp.asarray(np.asarray(ii)), jnp.asarray(gt_live))).mean())
        assert rec >= rt - TOLERANCE, (kind, "post-compact", rt, rec)
        assert not (set(np.asarray(ii).ravel().tolist()) & dead)
