"""Known-bad corpus: the replicated-constant bug class (PR 3).

A ~256 KiB score table is captured by closure instead of crossing the
jit boundary as an argument, so it compiles into the program as a
`constant(...)` — replicated onto every device. This is exactly how a
closure-captured index shard silently undoes dist.place_index; the
gate's replicated-constant pass must flag it with a file:line into
this module (python -m repro.analysis --selftest asserts it does).
"""
MIN_DEVICES = 1
EXPECT_PASS = "replicated-constant"


def build_bad():
    """The bad program: (jitted_fn, args) ready to lower."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    # 64 * 1024 f32 = 256 KiB, well above the 64 KiB gate threshold.
    table = jnp.asarray(np.random.default_rng(0).normal(
        size=(64, 1024)).astype(np.float32))

    @jax.jit
    def score(q):
        # BUG: `table` is a closure capture, not an argument — it bakes
        # into the compiled HLO as a replicated constant right here.
        return q @ table.T

    return score, (jnp.zeros((8, 1024), jnp.float32),)
