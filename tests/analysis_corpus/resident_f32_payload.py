"""Known-bad corpus: the f32-residency regression bug class (PR 10).

An SQ8-resident entry point whose N-scaled vector payload enters the
compiled step as f32 — the shape of a quantizer silently dropped from
the manifest (or an engine refactor re-materialising the float store
on device). The program computes the right answer at 4x the device
bytes the residency contract budgets for, so only the two-build
resident-bytes pass catches it: the payload's per-device element
count grows small -> large, its trailing dim is the vector dim, and
its dtype is f32 where int8 codes were promised. The pass must flag
the payload's use-site with a file:line into this module (python -m
repro.analysis --selftest asserts it does).
"""
MIN_DEVICES = 1
EXPECT_PASS = "resident-bytes"

_DIM = 16  # the gate's vector dim (registry.SIZES)


def _build(n):
    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(0)
    # BUG: the "resident" payload is f32 — the SQ8 quantizer was never
    # applied, so every device holds 4 bytes/dim instead of 1.
    payload = jnp.asarray(rng.normal(size=(n, _DIM)).astype(np.float32))
    sqn = jnp.sum(payload * payload, axis=1)

    @jax.jit
    def scan(q, x, xsq):
        # The f32 payload is consumed right here — the resident-bytes
        # finding anchors at this distance expansion.
        dist = xsq[None, :] - 2.0 * (q @ x.T)
        return jax.lax.top_k(-dist, 8)

    return scan, (jnp.zeros((8, _DIM), jnp.float32), payload, sqn)


def build_bad():
    """The bad program at the small size: (jitted_fn, args)."""
    return _build(2048)


def build_bad_large():
    """The same program at the large size (the pass compares the two
    builds to tell N-scaled payloads from batch-sized state)."""
    return _build(8192)
