"""Known-bad corpus: the unpartitionable-TopK bug class (PR 6).

A top-k merge runs OUTSIDE the shard_map over candidates whose slot
(batch) dim is split across host groups — the pre-`pin_merge` layout
of the sharded engine steps. GSPMD cannot partition the TopK/sort
custom-call over the sharded dim, so it materialises the operand with
an `all-gather` over dim 0 right in front of the merge: every chunk
step pays a cross-host gather of the whole candidate array. The
gate's unpartitionable-topk pass must flag the sort/TopK with a
file:line into this module (python -m repro.analysis --selftest
asserts it does; needs a forced multidevice CPU).
"""
MIN_DEVICES = 2
EXPECT_PASS = "unpartitionable-topk"


def build_bad():
    """The bad program: (jitted_fn, args) ready to lower."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((jax.device_count(),), ("hosts",))
    cand = jax.device_put(
        jnp.asarray(np.random.default_rng(0).normal(
            size=(8 * jax.device_count(), 128)).astype(np.float32)),
        NamedSharding(mesh, P("hosts", None)))

    @jax.jit
    def merge(c):
        # BUG: the candidate rows are hosts-split, but this top-k runs
        # outside any shard_map — GSPMD all-gathers dim 0 to feed it.
        return jax.lax.top_k(c, 8)

    return merge, (cand,)
