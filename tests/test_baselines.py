"""Competitor implementations: REM sweep, LAET, fixed-budget Baseline."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import baselines, darth_search, engines, training
from repro.index import flat, ivf


@pytest.fixture(scope="module")
def setup():
    from repro.data import vectors
    ds = vectors.make_dataset(n=5000, d=16, num_learn=512, num_queries=128,
                              clusters=25, cluster_std=1.0, seed=2)
    index = ivf.build(ds.base, nlist=25, seed=2)
    q_learn = jnp.asarray(ds.learn[:256])
    _, gt_learn = flat.search(q_learn, jnp.asarray(ds.base), 10)
    eng = engines.ivf_engine(index, k=10, nprobe=25)
    log = training.generate_observations(eng, q_learn, gt_learn, batch=256)
    return ds, index, eng, log


def test_rem_mapping_monotone(setup):
    ds, index, eng, log = setup
    q_val = jnp.asarray(ds.learn[256:384])
    _, gt_val = flat.search(q_val, jnp.asarray(ds.base), 10)
    rem = baselines.fit_rem(
        lambda p: engines.ivf_engine(index, k=10, nprobe=p),
        q_val, gt_val, param_grid=[2, 4, 8, 16, 25],
        targets=[0.8, 0.9, 0.99])
    # sweep recall is monotone in nprobe
    ps = sorted(rem.sweep)
    recs = [rem.sweep[p] for p in ps]
    assert all(b >= a - 0.02 for a, b in zip(recs, recs[1:]))
    # higher target -> no smaller parameter
    assert rem.mapping[0.99] >= rem.mapping[0.8]


def test_laet_budget_and_tuning(setup):
    ds, index, eng, log = setup
    laet = baselines.fit_laet(log, n0=2)
    q = jnp.asarray(ds.queries[:64])
    inner = baselines.laet_search(laet, eng, q, multiplier=1.0)
    nd = np.asarray(inner.ndis)
    assert (nd > 0).all()
    # bigger multiplier -> more work, better or equal recall
    inner2 = baselines.laet_search(laet, eng, q, multiplier=2.0)
    assert np.asarray(inner2.ndis).mean() >= nd.mean()

    q_val = jnp.asarray(ds.learn[256:384])
    _, gt_val = flat.search(q_val, jnp.asarray(ds.base), 10)
    tuned = baselines.tune_laet(laet, eng, q_val, gt_val, targets=[0.9],
                                steps=4)
    assert 0.9 in tuned.multipliers


def test_baseline_fixed_budget(setup):
    ds, index, eng, log = setup
    from repro.core import intervals
    d90 = float(np.mean(intervals.dists_to_target(
        log.recall, log.ndis, log.valid, 0.9)))
    inner = darth_search.budget_search(eng, jnp.asarray(ds.queries[:64]), d90)
    gt_d, gt_i = flat.search(jnp.asarray(ds.queries[:64]),
                             jnp.asarray(ds.base), 10)
    rec = float(flat.recall_at_k(eng.topk_i(inner), gt_i).mean())
    # Baseline roughly hits the target on average on easy data
    assert rec > 0.6
