"""Double-buffered compaction lifecycle: background incremental rebuild
(begin/tick/swap) vs the synchronous compact(), mutations landing while
the rebuild is in flight, and the DarthServer drained atomic swap —
in-flight chunks keep stepping the active view, the shadow installs at
an empty-pool boundary, and every result is attributable to exactly one
index version."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import mutate
from repro.core import darth_search, engines
from repro.data import vectors
from repro.index import hnsw, ivf


@pytest.fixture(scope="module")
def small_ds():
    return vectors.make_dataset(n=2000, d=16, num_learn=128,
                                num_queries=64, clusters=16,
                                cluster_std=1.0, seed=0)


def _twins(small_ds, kind):
    if kind == "ivf":
        index = ivf.build(small_ds.base, nlist=16, seed=0)
    else:
        index = hnsw.build(small_ds.base, m=8, passes=1,
                           ef_construction=32, seed=0)
    a = mutate.MutableIndex(index, capacity=512)
    b = mutate.MutableIndex(index, capacity=512)
    events = vectors.mutation_stream(small_ds, insert_pct=0.2,
                                     delete_pct=0.1, drift=0.3,
                                     steps=4, seed=3)
    a.apply(events)
    b.apply(events)
    return a, b


_FIELDS = {"ivf": ("centroids", "bucket_vecs", "bucket_ids",
                   "bucket_sqnorm"),
           "hnsw": ("vectors", "neighbors", "sqnorm", "entry",
                    "route_ids")}


def _assert_base_equal(x, y, kind):
    for f in _FIELDS[kind]:
        np.testing.assert_array_equal(
            np.asarray(getattr(x.base, f)), np.asarray(getattr(y.base, f)),
            err_msg=f"base.{f} diverged")


@pytest.mark.parametrize("kind", ["ivf", "hnsw"])
def test_background_rebuild_equals_sync_compact(small_ds, kind):
    """Ticking the generator at boundaries and swapping produces the
    bit-identical base that the synchronous compact() does — they drain
    the same generator, so there is no second code path to diverge."""
    sync, bg = _twins(small_ds, kind)
    sync.compact()

    job = bg.begin_compaction()
    assert bg.compacting
    ticks = 0
    while not bg.compact_tick():
        ticks += 1
    assert ticks >= 3          # genuinely incremental, not one big step
    assert job.done
    bg.swap_compaction()
    assert not bg.compacting

    _assert_base_equal(sync, bg, kind)
    assert bg.num_delta == 0 and sync.num_delta == 0
    np.testing.assert_array_equal(np.asarray(sync.delta.ids),
                                  np.asarray(bg.delta.ids))
    assert bg.num_live == sync.num_live
    assert bg.version > 0


def test_compaction_job_api_contract(small_ds):
    index = ivf.build(small_ds.base[:500], nlist=8, seed=0)
    mut = mutate.MutableIndex(index, capacity=64)
    mut.insert(small_ds.queries[:8])
    with pytest.raises(RuntimeError, match="no compaction"):
        mut.compact_tick()
    with pytest.raises(RuntimeError, match="no compaction"):
        mut.swap_compaction()
    mut.begin_compaction()
    with pytest.raises(RuntimeError, match="already in progress"):
        mut.begin_compaction()
    with pytest.raises(RuntimeError, match="not finished"):
        mut.swap_compaction()
    while not mut.compact_tick():
        pass
    mut.swap_compaction()
    assert mut.num_delta == 0


@pytest.mark.parametrize("kind", ["ivf", "hnsw"])
def test_mid_rebuild_delete_is_retombstoned_in_shadow(small_ds, kind):
    """A delete landing while the rebuild runs hits the ACTIVE view
    immediately and must be re-applied to the shadow at swap — the
    folded snapshot predates it."""
    _, mut = _twins(small_ds, kind)
    delta_id = int(next(iter(mut._delta_slot)))
    base_id = 7
    assert base_id not in set(int(i) for i in mut.deleted_ids)

    mut.begin_compaction()
    mut.compact_tick()                       # snapshot taken, job running
    assert mut.delete([base_id, delta_id]) == 2
    # active view already hides them
    eng = (engines.ivf_engine(mut.base, k=5, nprobe=16) if kind == "ivf"
           else engines.hnsw_engine(mut.base, k=5, ef=48))
    meng = engines.mutable_engine(eng, mut.delta)
    ws = darth_search.plain_search(meng, jnp.asarray(small_ds.queries))
    assert not ({base_id, delta_id}
                & set(np.asarray(meng.topk_i(ws)).ravel().tolist()))

    while not mut.compact_tick():
        pass
    mut.swap_compaction()
    # the swapped-in shadow hides them too
    if kind == "ivf":
        bi = np.asarray(mut.base.bucket_ids)
        stored = set(bi[bi >= 0].tolist())
        assert base_id not in stored and delta_id not in stored
    else:
        sq = np.asarray(mut.base.sqnorm)
        assert np.isposinf(sq[base_id]) and np.isposinf(sq[delta_id])
    live_ids, _ = mut.live_vectors()
    assert base_id not in set(int(i) for i in live_ids)
    assert delta_id not in set(int(i) for i in live_ids)


def test_mid_rebuild_insert_survives_in_ring(small_ds):
    """Ids inserted after begin_compaction were never snapshotted: they
    must stay live in the delta ring across the swap, and their slots
    must NOT be freed with the folded ones."""
    index = ivf.build(small_ds.base[:500], nlist=8, seed=0)
    mut = mutate.MutableIndex(index, capacity=64)
    folded = mut.insert(small_ds.queries[:8])
    mut.begin_compaction()
    mut.compact_tick()
    late = mut.insert(small_ds.queries[8:11])
    while not mut.compact_tick():
        pass
    mut.swap_compaction()

    assert mut.num_delta == 3
    assert set(int(i) for i in late) == set(int(i) for i in mut._delta_slot)
    assert int(mutate.delta.live_count(mut.delta)) == 3
    bi = np.asarray(mut.base.bucket_ids)
    stored = set(bi[bi >= 0].tolist())
    assert set(int(i) for i in folded) <= stored
    assert not (set(int(i) for i in late) & stored)
    # the late inserts are still found, exactly, through the wrapper
    meng = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=5, nprobe=8), mut.delta)
    ws = darth_search.plain_search(meng, jnp.asarray(small_ds.queries[8:11]))
    np.testing.assert_array_equal(np.asarray(meng.topk_i(ws))[:, 0], late)
    # a second, quiescent compaction folds them and resets the ring
    mut.compact()
    assert mut.num_delta == 0
    bi = np.asarray(mut.base.bucket_ids)
    assert set(int(i) for i in late) <= set(bi[bi >= 0].tolist())


# --- drained atomic swap in the serving loop --------------------------------

@pytest.fixture(scope="module")
def served_mutable(small_ds):
    from repro.core import api

    ds = small_ds
    index = ivf.build(ds.base, nlist=16, seed=0)
    mut = mutate.MutableIndex(index, capacity=512)

    def make_engine(**kw):
        return engines.mutable_engine(
            engines.ivf_engine(mut.base, **kw), mut.delta)

    d = api.Darth(make_engine=make_engine,
                  engine=make_engine(k=10, nprobe=16))
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=64)
    return ds, mut, d


def test_drained_swap_mid_serve_matches_no_swap(served_mutable):
    """request_swap with an identical-contents engine must be invisible
    to results: admissions pause, the pool drains, the swap applies at
    an empty boundary, and every query's topk/ndis is unchanged (the
    per-slot search state never mixes index versions)."""
    from repro.serve import DarthServer

    ds, mut, d = served_mutable
    rts = np.full((ds.queries.shape[0],), 0.9, np.float32)

    def run(swap_at):
        server = DarthServer(d.engine, d.trained.predictor,
                             d.interval_for_target, num_slots=8,
                             steps_per_sync=2)
        seen = {"n": 0}

        def on_boundary(srv):
            seen["n"] += 1
            if seen["n"] == swap_at and not srv.swap_pending:
                srv.request_swap(
                    mutate.refresh_view(srv.engine, delta=mut.delta),
                    contents_only=True)
        results, stats = server.serve(
            ds.queries, rts,
            on_boundary=on_boundary if swap_at else None)
        return results, stats

    plain, st0 = run(0)
    swapped, st1 = run(2)
    assert st0.swaps == 0 and st1.swaps == 1
    assert st1.completed == ds.queries.shape[0]
    assert st1.ndis_harvested == st0.ndis_harvested
    for a, b in zip(plain, swapped):
        assert (a is None) == (b is None)
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a[0]),
                                          np.asarray(b[0]))
            np.testing.assert_array_equal(np.asarray(a[1]),
                                          np.asarray(b[1]))


def test_swap_requires_engine_or_predictor_and_rejects_double(
        served_mutable):
    from repro.serve import DarthServer

    ds, mut, d = served_mutable
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=8)
    with pytest.raises(ValueError):
        server.request_swap()
    # outside a serve the pool is trivially drained: applies immediately
    epoch0 = server.engine_epoch
    server.request_swap(mutate.refresh_view(server.engine,
                                            delta=mut.delta))
    assert not server.swap_pending
    assert server.engine_epoch == epoch0 + 1


def test_background_compaction_through_serve_boundaries(served_mutable):
    """End-to-end tentpole path on one server: mutation events land at
    boundaries as contents-only refreshes, the rebuild ticks in the
    background, and the folded base hot-swaps mid-serve — zero full-pool
    pauses, all queries complete, post-swap state matches a synchronous
    rebuild of a twin."""
    from repro.serve import DarthServer

    ds, _, d = served_mutable
    index = ivf.build(ds.base, nlist=16, seed=0)
    mut = mutate.MutableIndex(index, capacity=512)
    twin = mutate.MutableIndex(index, capacity=512)
    events = vectors.mutation_stream(ds, insert_pct=0.2, delete_pct=0.1,
                                     drift=0.3, steps=2, seed=3)
    twin.apply(events)
    twin.compact()

    eng = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=10, nprobe=16), mut.delta)
    server = DarthServer(eng, d.trained.predictor, d.interval_for_target,
                         num_slots=4, steps_per_sync=2)
    ev = list(events)
    state = {"swapped": False}

    def on_boundary(srv):
        if srv.swap_pending or state["swapped"]:
            return
        if ev:
            e = ev.pop(0)
            mut.apply([e])
            srv.set_engine(mutate.refresh_view(
                srv.engine,
                base=mut.base if e.kind == "delete" else None,
                delta=mut.delta), contents_only=True)
        elif not mut.compacting:
            mut.begin_compaction()
        elif mut.compact_tick():
            mut.swap_compaction()
            srv.request_swap(engines.mutable_engine(
                engines.ivf_engine(mut.base, k=10, nprobe=16),
                mut.delta), contents_only=True)
            state["swapped"] = True

    rts = np.full((ds.queries.shape[0],), 0.9, np.float32)
    results, stats = server.serve(ds.queries, rts,
                                  on_boundary=on_boundary)
    assert stats.completed == ds.queries.shape[0]
    assert all(r is not None for r in results)
    assert state["swapped"] and stats.swaps == 1
    assert not ev and not mut.compacting
    _assert_base_equal(mut, twin, "ivf")
    assert mut.num_delta == 0
    # a mid-stream result may legally contain an id deleted LATER (it
    # was live in that result's index version); but once every delete
    # has landed and the fold swapped in, tombstones never surface
    results2, stats2 = server.serve(ds.queries, rts)
    assert stats2.completed == ds.queries.shape[0]
    dead = set(int(i) for i in mut.deleted_ids)
    for r in results2:
        assert not (dead & set(np.asarray(r[1]).ravel().tolist()))
