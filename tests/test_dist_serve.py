"""Multi-host slot-pool serving over the sharded engines: slot-dim
placement specs (dist.sharding "hosts" axis), index placement on a
("hosts", "model") serve mesh (index global per host group, slot dim
split), and exact single-controller parity of the full serve loop at
(hosts, shards) combinations on real placeholder devices — for BOTH
sharded engine families, including through mutable_engine."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro import dist
from repro.launch import mesh as mesh_lib


def _serve_mesh1():
    return jax.make_mesh((1, 1), ("hosts", "model"))


class _FakeServeMesh:
    """spec_for only reads axis_names + shape — a fake lets the spec
    rules be tested for >1-sized axes on the 1-device test host."""
    axis_names = ("hosts", "model")
    shape = {"hosts": 2, "model": 2}


class _FakeDataMesh:
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 2}


def test_slot_dim_spec_rules():
    """Slot-dim specs: the leading (slot) dim splits over "hosts" and
    ONLY "hosts" (the device programs key on collectives.BATCH_AXIS, so
    any other axis would split inputs they treat as replicated), and
    replicates when the axis is absent or the slot count does not
    divide."""
    from repro.dist import sharding

    spec = sharding.spec_for(_FakeServeMesh, (8, 16), ("hosts", None))
    assert tuple(spec) == ("hosts", None)
    spec = sharding.spec_for(_FakeDataMesh, (8,), ("hosts",))
    assert tuple(spec) == (None,)
    spec = sharding.spec_for(_FakeServeMesh, (7,), ("hosts",))
    assert tuple(spec) == (None,)


def test_slot_sharding_and_serve_batch_shardings():
    """batch_shardings kind="serve" and slot_sharding build
    NamedShardings on a real serve mesh (the 1-sized hosts axis of the
    test host drops to replication — the divisibility contract)."""
    mesh = _serve_mesh1()
    qb = np.zeros((8, 16), np.float32)
    rt = np.zeros((8,), np.float32)
    sh = dist.batch_shardings({"q": qb, "rt": rt}, mesh, kind="serve")
    assert sh["q"].mesh.axis_names == ("hosts", "model")
    assert all(e is None for e in sh["q"].spec)
    s = dist.slot_sharding(mesh, 8, trailing=1)
    assert all(e is None for e in s.spec)


def test_place_index_on_serve_mesh_keeps_index_global():
    """place_index on a ("hosts", "model") mesh: every sharded dim
    names only "model", so the index replicates across host groups —
    each host group sees the whole sharded index."""
    from repro.data import vectors
    from repro.index import ivf

    ds = vectors.make_dataset(n=1200, d=16, num_learn=32, num_queries=8,
                              clusters=8, cluster_std=1.0, seed=0)
    index = ivf.build(ds.base, nlist=8, seed=0)
    mesh = _serve_mesh1()
    placed = dist.place_index(index, mesh)
    for name in ("bucket_vecs", "bucket_ids", "bucket_sqnorm"):
        spec = tuple(getattr(placed, name).sharding.spec)
        assert "hosts" not in spec, (name, spec)
    np.testing.assert_array_equal(np.asarray(placed.bucket_sizes),
                                  np.asarray(index.bucket_sizes))


def test_make_serve_mesh_validates():
    with pytest.raises(ValueError, match="needs"):
        mesh_lib.make_serve_mesh(hosts=4, shards=4)
    with pytest.raises(ValueError, match="hosts must be"):
        mesh_lib.make_serve_mesh(hosts=0)
    mesh = mesh_lib.make_serve_mesh(hosts=1, shards=1)
    assert mesh.axis_names == ("hosts", "model")


def test_serve_mesh_single_device_serves():
    """The full multi-host serve loop on the (1, 1) serve mesh: the
    slot-dim placement path is exercised (mesh has a "hosts" axis) and
    results match the meshless server exactly."""
    import jax.numpy as jnp
    from repro.core import api, engines
    from repro.data import vectors
    from repro.index import ivf
    from repro.serve import DarthServer

    ds = vectors.make_dataset(n=1500, d=16, num_learn=128, num_queries=32,
                              clusters=12, cluster_std=1.0, seed=0)
    index = ivf.build(ds.base, nlist=12, seed=0)
    d = api.Darth(
        make_engine=lambda **kw: engines.ivf_engine(index, **kw),
        engine=engines.ivf_engine(index, k=5, nprobe=12))
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=128)
    rts = np.tile([0.8, 0.9], 16).astype(np.float32)

    ref_server = DarthServer(d.engine, d.trained.predictor,
                             d.interval_for_target, num_slots=8,
                             steps_per_sync=2)
    ref, ref_stats = ref_server.serve(ds.queries, rts)

    mesh = _serve_mesh1()
    placed = dist.place_index(index, mesh)
    eng = engines.sharded_ivf_engine(placed, mesh, k=5, nprobe=12)
    server = DarthServer(eng, d.trained.predictor, d.interval_for_target,
                         num_slots=8, steps_per_sync=2, mesh=mesh, hosts=2)
    res, stats = server.serve(ds.queries, rts)
    assert stats.completed == ref_stats.completed == 32
    for a, b in zip(ref, res):
        np.testing.assert_allclose(a[0], b[0], atol=1e-4)
        np.testing.assert_array_equal(a[1], b[1])
    assert stats.ndis_harvested == ref_stats.ndis_harvested


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax, jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")
from repro import dist, mutate
from repro.core import api, engines
from repro.data import vectors
from repro.index import hnsw, ivf
from repro.launch import mesh as mesh_lib
from repro.serve import DarthServer

ds = vectors.make_dataset(n=1501, d=16, num_learn=128, num_queries=48,
                          clusters=12, cluster_std=1.0, seed=0)
rts = np.tile([0.8, 0.9, 0.95], 16).astype(np.float32)
events = vectors.mutation_stream(ds, insert_pct=0.15, delete_pct=0.05,
                                 drift=0.3, steps=3, seed=3)

out = {"ndev": jax.device_count(), "cases": []}
for kind in ("ivf", "hnsw"):
    if kind == "ivf":
        index = ivf.build(ds.base, nlist=12, seed=0, cap_round=1)
        kw = dict(k=5, nprobe=12)
        mk = lambda idx, **k2: engines.ivf_engine(idx, **k2)
        mk_sh = lambda idx, mesh, **k2: engines.sharded_ivf_engine(
            idx, mesh, **k2)
    else:
        index = hnsw.build(ds.base, m=8, passes=1, ef_construction=32,
                           seed=0)
        kw = dict(k=5, ef=24)
        mk = lambda idx, **k2: engines.hnsw_engine(idx, **k2)
        mk_sh = lambda idx, mesh, **k2: engines.sharded_hnsw_engine(
            idx, mesh, **k2)
    for mutated in (False, True):
        if mutated:
            mut = mutate.MutableIndex(index, capacity=512)
            mut.apply(events)
            base_idx = mut.base
            wrap = lambda eng: engines.mutable_engine(eng, mut.delta)
        else:
            base_idx = index
            wrap = lambda eng: eng
        d = api.Darth(make_engine=lambda **k2: wrap(mk(base_idx, **k2)),
                      engine=wrap(mk(base_idx, **kw)))
        d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=128)
        ref_server = DarthServer(d.engine, d.trained.predictor,
                                 d.interval_for_target, num_slots=8,
                                 steps_per_sync=2)
        ref, ref_stats = ref_server.serve(ds.queries, rts)
        for hosts, shards in ((1, 4), (2, 2), (4, 1)):
            mesh = mesh_lib.make_serve_mesh(hosts, shards)
            if mutated:
                view = dist.place_index(mut.view(), mesh)
                eng = engines.mutable_engine(
                    mk_sh(view.base, mesh, **kw), view.delta)
            else:
                eng = mk_sh(dist.place_index(index, mesh), mesh, **kw)
            server = DarthServer(eng, d.trained.predictor,
                                 d.interval_for_target, num_slots=8,
                                 steps_per_sync=2, mesh=mesh, hosts=hosts)
            res, stats = server.serve(ds.queries, rts)
            out["cases"].append({
                "kind": kind, "mutated": mutated,
                "hosts": hosts, "shards": shards,
                "completed": stats.completed,
                "all_done": all(r is not None for r in res),
                "d_ok": bool(all(np.allclose(a[0], b[0], atol=1e-4)
                                 for a, b in zip(ref, res))),
                "i_ok": bool(all(np.array_equal(a[1], b[1])
                                 for a, b in zip(ref, res))),
                "ndis_ok": stats.ndis_harvested == ref_stats.ndis_harvested,
                "trunc_ok": stats.truncated == ref_stats.truncated == 0,
            })
print(json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_multi_host_sharded_serve_parity_hosts_1_2_4():
    """Acceptance bar: multi-host serve output exactly matches the
    single-controller server (topk_d/topk_i/ndis/truncated) at host
    counts {1, 2, 4} on real placeholder-device serve meshes, for both
    sharded engines, plain AND through mutable_engine."""
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 4
    # {ivf,hnsw} x {plain,mutable} x {(1,4),(2,2),(4,1)}
    assert len(res["cases"]) == 2 * 2 * 3
    for case in res["cases"]:
        assert case["completed"] == 48, case
        for key in ("all_done", "d_ok", "i_ok", "ndis_ok", "trunc_ok"):
            assert case[key], case
