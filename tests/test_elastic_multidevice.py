"""Elastic checkpoint/restart across DIFFERENT mesh shapes, on real
(placeholder) multi-device meshes. Runs in a subprocess because jax locks
the device count at first init and the main test process must stay
single-device."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.multidevice]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, "src")
from repro import ckpt
from repro.dist import sharding as sh
from repro.utils import meshctx

tmp = sys.argv[1]

# --- phase 1: "train" on a (4, 2) mesh, save sharded state ---
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
w = jnp.arange(64.0, dtype=jnp.float32).reshape(8, 8)
sh_a = NamedSharding(mesh_a, P("data", "model"))
w_a = jax.device_put(w, sh_a)

@jax.jit
def step(w):
    return w * 1.5 + 1.0

w_a = step(w_a)
ckpt.save(tmp, 1, {"w": w_a})

# --- phase 2: restore onto a (2, 4) mesh (elastic reshard) ---
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
sh_b = NamedSharding(mesh_b, P("data", "model"))
like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
restored, meta = ckpt.restore(tmp, like, shardings={"w": sh_b})
w_b = step(restored["w"])

expect = (np.arange(64.0).reshape(8, 8) * 1.5 + 1.0) * 1.5 + 1.0
ok_values = bool(np.allclose(np.asarray(w_b), expect))
ok_shard = restored["w"].sharding.is_equivalent_to(sh_b, 2)
print(json.dumps({"ok_values": ok_values, "ok_shard": bool(ok_shard),
                  "ndev": jax.device_count()}))
"""


def test_elastic_reshard_across_meshes(tmp_path):
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, str(tmp_path)],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 8
    assert res["ok_values"], res
    assert res["ok_shard"], res
