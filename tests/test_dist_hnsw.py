"""Sharded HNSW beam engine: numeric parity with the single-device beam
loop (topk_d / topk_i / ndis / ninserts) on the 1-device mesh in-process,
and on real (placeholder) {1, 2, 4}-shard meshes in a subprocess — with a
node count that does not divide the shard count (place_index pads the
node dim; pad rows must keep sqnorm +inf / neighbor ids -1)."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import dist
from repro.core import darth_search, engines
from repro.index import hnsw


def _mesh1():
    return jax.make_mesh((1,), ("model",))


@pytest.fixture(scope="module")
def small_hnsw():
    from repro.data import vectors
    ds = vectors.make_dataset(n=1501, d=16, num_learn=64, num_queries=32,
                              clusters=12, cluster_std=1.0, seed=0)
    index = hnsw.build(ds.base, m=8, passes=1, ef_construction=32, seed=0)
    return ds, index


def test_sharded_beam_matches_single_device(small_hnsw):
    ds, index = small_hnsw
    mesh = _mesh1()
    placed = dist.place_index(index, mesh)
    q = jnp.asarray(ds.queries[:16])
    d0, i0, s0 = hnsw.search(index, q, k=5, ef=24)
    d1, i1, s1 = hnsw.search_sharded(placed, q, k=5, ef=24, mesh=mesh)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0.ndis), np.asarray(s1.ndis))
    np.testing.assert_array_equal(np.asarray(s0.ninserts),
                                  np.asarray(s1.ninserts))


def test_sharded_engine_protocol_drivers(small_hnsw):
    """darth_search's plain / budget drivers run the sharded beam engine
    unchanged (Engine protocol) and reproduce single-device results."""
    ds, index = small_hnsw
    mesh = _mesh1()
    placed = dist.place_index(index, mesh)
    q = jnp.asarray(ds.queries[:16])
    eng_ref = engines.hnsw_engine(index, k=5, ef=24)
    eng_sh = engines.sharded_hnsw_engine(placed, mesh, k=5, ef=24)
    assert eng_sh.name == "hnsw-sharded"
    assert eng_sh.max_steps == eng_ref.max_steps == 8 * 24

    plain_ref = darth_search.plain_search(eng_ref, q)
    plain_sh = darth_search.plain_search(eng_sh, q)
    np.testing.assert_array_equal(np.asarray(plain_ref.cand_i[:, :5]),
                                  np.asarray(plain_sh.cand_i[:, :5]))
    np.testing.assert_array_equal(np.asarray(plain_ref.nstep),
                                  np.asarray(plain_sh.nstep))

    budget = float(index.route_ids.shape[0] + 120)
    bud_ref = darth_search.budget_search(eng_ref, q, budget)
    bud_sh = darth_search.budget_search(eng_sh, q, budget)
    np.testing.assert_array_equal(np.asarray(bud_ref.ndis),
                                  np.asarray(bud_sh.ndis))
    np.testing.assert_array_equal(np.asarray(bud_ref.cand_i[:, :5]),
                                  np.asarray(bud_sh.cand_i[:, :5]))


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

sys.path.insert(0, "src")
from repro import dist
from repro.data import vectors
from repro.index import hnsw

# n=1501 is odd AND 1 mod 4: place_index must pad the node dim for both
# the 2- and 4-shard meshes.
ds = vectors.make_dataset(n=1501, d=16, num_learn=64, num_queries=32,
                          clusters=12, cluster_std=1.0, seed=0)
index = hnsw.build(ds.base, m=8, passes=1, ef_construction=32, seed=0)
q = jnp.asarray(ds.queries[:16])
d0, i0, s0 = hnsw.search(index, q, k=5, ef=24)
n = index.num_vectors
out = {"ndev": jax.device_count(), "n": n, "cases": []}
for nsh in (1, 2, 4):
    mesh = Mesh(np.asarray(jax.devices()[:nsh]), ("model",))
    placed = dist.place_index(index, mesh)
    # padding contract on the placed arrays
    sqn_pad = np.asarray(placed.sqnorm)[n:]
    nbr_pad = np.asarray(placed.neighbors)[n:]
    d1, i1, s1 = hnsw.search_sharded(placed, q, k=5, ef=24, mesh=mesh)
    out["cases"].append({
        "shards": nsh, "n_padded": placed.num_vectors,
        "pad_ok": bool(np.isposinf(sqn_pad).all()
                       and (nbr_pad == -1).all()),
        "d_ok": bool(np.allclose(np.asarray(d0), np.asarray(d1),
                                 atol=1e-4)),
        "i_ok": bool(np.array_equal(np.asarray(i0), np.asarray(i1))),
        "ndis_ok": bool(np.array_equal(np.asarray(s0.ndis),
                                       np.asarray(s1.ndis))),
        "nins_ok": bool(np.array_equal(np.asarray(s0.ninserts),
                                       np.asarray(s1.ninserts))),
    })
print(json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_beam_parity_mesh_1_2_4():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 4
    assert len(res["cases"]) == 3
    for case in res["cases"]:
        if case["shards"] > 1:     # 1501 padded up to the shard multiple
            assert case["n_padded"] % case["shards"] == 0, case
            assert case["n_padded"] > res["n"], case
        for key in ("pad_ok", "d_ok", "i_ok", "ndis_ok", "nins_ok"):
            assert case[key], case
