import numpy as np
import jax.numpy as jnp

from repro.data import vectors


def test_dataset_splits_disjoint_shapes():
    ds = vectors.make_dataset(n=1000, d=8, num_learn=100, num_queries=50,
                              clusters=10, seed=0)
    assert ds.base.shape == (1000, 8)
    assert ds.learn.shape == (100, 8)
    assert ds.queries.shape == (50, 8)
    assert ds.base.dtype == np.float32


def test_noisy_queries_scale_with_pct():
    ds = vectors.make_dataset(n=500, d=16, num_learn=10, num_queries=100,
                              clusters=5, seed=1)
    q1 = vectors.noisy_queries(ds.queries, 0.05, seed=0)
    q2 = vectors.noisy_queries(ds.queries, 0.30, seed=0)
    d1 = np.linalg.norm(q1 - ds.queries, axis=1).mean()
    d2 = np.linalg.norm(q2 - ds.queries, axis=1).mean()
    assert d2 > d1 > 0


def test_noisy_queries_increase_hardness():
    """The paper's hardness definition: computational effort (distance
    calcs) required to reach a recall target grows with query noise."""
    import jax.numpy as jnp
    from repro.index import flat, ivf
    from repro.core import engines, intervals, training
    ds = vectors.make_dataset(n=6000, d=16, num_learn=10, num_queries=64,
                              clusters=48, cluster_std=2.0, seed=3)
    index = ivf.build(ds.base, nlist=48, seed=3)
    eng = engines.ivf_engine(index, k=10, nprobe=48)

    def effort(queries):
        q = jnp.asarray(queries)
        _, gt = flat.search(q, jnp.asarray(ds.base), 10)
        log = training.generate_observations(eng, q, gt, batch=64)
        return float(np.mean(intervals.dists_to_target(
            log.recall, log.ndis, log.valid, 0.99)))

    base = effort(ds.queries)
    noisy = effort(vectors.noisy_queries(ds.queries, 8.0, seed=1))
    ood = effort(vectors.ood_queries(16, 64, seed=2))
    assert noisy > base, (base, noisy)
    assert ood > noisy, (noisy, ood)


def test_ood_queries_far_from_base():
    ds = vectors.make_dataset(n=500, d=16, num_learn=10, num_queries=50,
                              clusters=5, seed=1)
    ood = vectors.ood_queries(16, 50, seed=2)
    # mean NN distance of OOD queries exceeds in-distribution queries'
    def mean_nn(qs):
        d = ((qs[:, None, :] - ds.base[None]) ** 2).sum(-1)
        return np.sqrt(d.min(1)).mean()
    assert mean_nn(ood) > mean_nn(ds.queries)


def test_lid_estimator():
    rng = np.random.default_rng(0)
    # higher-dimensional data -> higher LID
    def lid_of(d):
        x = rng.normal(size=(2000, d)).astype(np.float32)
        q = rng.normal(size=(50, d)).astype(np.float32)
        from repro.index import flat
        dists, _ = flat.search(jnp.asarray(q), jnp.asarray(x), 20)
        return float(np.median(vectors.local_intrinsic_dimensionality(
            np.asarray(dists))))
    assert lid_of(32) > lid_of(4)
