"""Tests for the trace-time SPMD lint suite (repro.analysis).

Three layers: pure-text unit tests for the pass logic (canned HLO, no
jax), the known-bad corpus detected at 1 device, and the zero-finding
fixture over the REAL registered entry points (the in-process gate).
The forced-multidevice gate — where the sharding passes actually bite
— runs the CLI in a subprocess, same idiom as the other multidevice
tests."""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import hlo_passes, padlint, runner
from repro.analysis.findings import Finding, format_findings
from repro.analysis.registry import SIZES, entry_points

REPO = os.path.dirname(runner.SRC_ROOT)
CORPUS = os.path.join(REPO, "tests", "analysis_corpus")


def _load_corpus(name):
    path = os.path.join(CORPUS, name + ".py")
    spec = importlib.util.spec_from_file_location("corpus_" + name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------

def test_finding_location_and_format():
    f = Finding("p", "e", "msg", file="a/b.py", line=7)
    assert f.location() == "a/b.py:7"
    assert Finding("p", "e", "msg").location() == "e"
    out = format_findings([f])
    assert "a/b.py:7" in out and "[p/e]" in out and "msg" in out
    assert f.to_dict()["line"] == 7


# ---------------------------------------------------------------------------
# pad-convention lint (pure AST)
# ---------------------------------------------------------------------------

BAD_SRC = """
import jax.numpy as jnp
def f(x):
    a = jnp.full((4,), -1, jnp.int32)
    b = jnp.where(x > 0, x, jnp.inf)
    c = x.at[0].set(-1)
    d = jnp.pad(x, (0, 2), constant_values=jnp.inf)
    return a, b, c, d
"""

OK_SRC = """
import jax.numpy as jnp
import numpy as np
def f(x):
    ok1 = x < np.inf                      # comparison, not a direct arg
    ok2 = jnp.full((4,), -1.0)            # float -1: recall sentinel
    ok3 = jnp.where(x > 0, x, -jnp.inf)   # -inf mask floor
    ok4 = x.at[0].add(-1)                 # arithmetic, not set
    ok5 = jnp.full((4,), -1, jnp.int32)   # padlint: ok
    # waiver on the preceding line also counts — padlint: ok
    ok6 = jnp.full((4,), -1, jnp.int32)
    return ok1, ok2, ok3, ok4, ok5, ok6
"""


def test_padlint_flags_all_pad_contexts():
    fs = padlint.lint_source("src/repro/index/fake.py", BAD_SRC)
    assert [f.line for f in fs] == [4, 5, 6, 7]
    assert all(f.pass_name == "pad-convention" for f in fs)


def test_padlint_precision_and_waivers():
    assert padlint.lint_source("src/repro/index/fake.py", OK_SRC) == []


def test_padlint_tree_is_clean():
    assert padlint.lint_tree(runner.SRC_ROOT) == []


def test_padlint_scope_excludes_kernels():
    # the kernels package masks with raw literals by design (see the
    # padlint module docstring) and must stay out of scope
    assert "kernels" not in padlint.SCOPE
    for sub in padlint.SCOPE:
        assert os.path.isdir(os.path.join(runner.SRC_ROOT, "repro", sub))


# ---------------------------------------------------------------------------
# HLO passes on canned text (no jax)
# ---------------------------------------------------------------------------

CONST_HLO = """
ENTRY %main (p0: f32[8,1024]) -> f32[8,64] {
  %p0 = f32[8,1024]{1,0} parameter(0)
  %small = f32[4,4]{1,0} constant({...})
  %big = f32[64,1024]{1,0} constant({...}), metadata={op_name="jit(f)/dot" source_file="repro/bad.py" source_line=12}
  ROOT %dot = f32[8,64]{1,0} dot(f32[8,1024]{1,0} %p0, f32[64,1024]{1,0} %big)
}
"""

TOPK_BAD_HLO = """
ENTRY %main (p0: f32[8,128]) -> f32[32,8] {
  %p0 = f32[8,128]{1,0} parameter(0)
  %all-gather = f32[32,128]{1,0} all-gather(f32[8,128]{1,0} %p0), dimensions={0}
  ROOT %custom-call = f32[32,8]{1,0} custom-call(f32[32,128]{1,0} %all-gather), custom_call_target="TopK", metadata={source_file="repro/bad.py" source_line=34}
}
"""

TOPK_OK_HLO = """
ENTRY %main (p0: f32[8,128]) -> f32[8,8] {
  %p0 = f32[8,128]{1,0} parameter(0)
  %all-gather = f32[8,512]{1,0} all-gather(f32[8,128]{1,0} %p0), dimensions={1}
  ROOT %custom-call = f32[8,8]{1,0} custom-call(f32[8,512]{1,0} %all-gather), custom_call_target="TopK"
}
"""

COLL_SMALL = """
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  ROOT %all-reduce = f32[8,16]{1,0} all-reduce(f32[8,16]{1,0} %p0)
}
"""

COLL_LARGE = COLL_SMALL.replace("[8,16]", "[8,64]")


def test_replicated_constants_threshold_and_anchor():
    fs = hlo_passes.replicated_constants("e", CONST_HLO)
    assert len(fs) == 1  # the 64-byte constant stays below threshold
    assert fs[0].file == "repro/bad.py" and fs[0].line == 12
    assert "262144 bytes" in fs[0].message


def test_unpartitionable_topk_dim0_only():
    fs = hlo_passes.unpartitionable_topk("e", TOPK_BAD_HLO)
    assert len(fs) == 1
    assert fs[0].file == "repro/bad.py" and fs[0].line == 34
    # deliberate candidate merges gather dim 1 (tiled) — never flagged
    assert hlo_passes.unpartitionable_topk("e", TOPK_OK_HLO) == []


def test_collective_n_independence_compare():
    assert hlo_passes.collective_n_independence(
        "e", COLL_SMALL, COLL_SMALL) == []
    fs = hlo_passes.collective_n_independence("e", COLL_SMALL, COLL_LARGE)
    assert len(fs) == 1 and "all-reduce" in fs[0].message


# ---------------------------------------------------------------------------
# known-bad corpus (1 device)
# ---------------------------------------------------------------------------

def test_corpus_replicated_const_detected():
    mod = _load_corpus("replicated_const")
    fn, args = mod.build_bad()
    hlo = fn.lower(*args).compile().as_text()
    fs = hlo_passes.replicated_constants("corpus", hlo)
    assert fs, "the known-bad closure capture must be detected"
    assert any(f.file and f.file.endswith("replicated_const.py")
               and f.line for f in fs)


def test_corpus_replicated_const_fixed_version_clean():
    # same program with the table as an ARGUMENT: no finding
    import jax
    import jax.numpy as jnp

    @jax.jit
    def score(q, table):
        return q @ table.T

    hlo = score.lower(jnp.zeros((8, 1024), jnp.float32),
                      jnp.zeros((64, 1024), jnp.float32)
                      ).compile().as_text()
    assert hlo_passes.replicated_constants("fixed", hlo) == []


# ---------------------------------------------------------------------------
# the real entry points (1-device in-process gate)
# ---------------------------------------------------------------------------

def test_manifest_registers_all_subsystems():
    names = {ep.name for ep in entry_points()}
    assert {"kernels/l2_topk", "kernels/bucket_topk", "dist/flat_search",
            "dist/ivf_probe_step", "dist/hnsw_beam_step",
            "serve/chunks_ivf", "serve/chunks_hnsw",
            "serve/retrace_loop"} <= names
    assert SIZES["small"][1] == SIZES["large"][1], \
        "pass 3 varies N only (D-scaled init payloads are legitimate)"


def test_gate_zero_findings_on_real_entry_points():
    assert runner.run_gate() == []


def test_gate_cli_in_process_single_device(tmp_path):
    # the CLI end-to-end at whatever device count this process has
    # (--devices 0 = no forcing; jax is already initialised here). The
    # 1-device selftest detects the replicated-constant corpus and
    # SKIPs the multidevice-only repro rather than failing.
    from repro.analysis.__main__ import main

    report = tmp_path / "gate.json"
    rc = main(["--gate", "--selftest", "--devices", "0",
               "--json", str(report)])
    assert rc == 0
    data = json.loads(report.read_text())
    assert data["findings"] == []
    assert data["selftest_errors"] == []


# ---------------------------------------------------------------------------
# forced-multidevice gate (subprocess, CI lane)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.slow
def test_gate_cli_multidevice(tmp_path):
    report = tmp_path / "gate.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--gate", "--selftest",
         "--devices", "4", "--json", str(report)],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stdout + out.stderr
    data = json.loads(report.read_text())
    assert data["findings"] == []
    assert data["selftest_errors"] == []
    # both historical bug classes must have been exercised, not skipped
    assert "replicated_const.py" in out.stdout
    assert "unpartitionable_topk.py" in out.stdout
