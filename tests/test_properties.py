"""Property-based invariants (hypothesis; deterministic shim in
tests/_vendor when the real package is absent):

  * collectives.merge_topk — idempotence, permutation-invariance of the
    candidate columns, and the +inf -> id -1 masking contract that keeps
    shard padding out of results.
  * ivf.build SQ8 storage — per-dim affine round-trip error is bounded
    by half a quantization step, and bucket_sqnorm matches the norms of
    the DEQUANTIZED vectors (what quantized search actually measures).
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.dist import collectives
from repro.index import ivf


def _candidates(rng, b, m, inf_frac):
    d = rng.uniform(0.0, 100.0, (b, m)).astype(np.float32)
    # distinct distances -> unique top-k selection, no tie ambiguity
    d = d + np.arange(b * m, dtype=np.float32).reshape(b, m) * 1e-3
    mask = rng.random((b, m)) < inf_frac
    d = np.where(mask, np.inf, d)
    ids = np.where(mask, -1, rng.integers(0, 10_000, (b, m))).astype(np.int32)
    return jnp.asarray(d), jnp.asarray(ids)


@settings(deadline=None, max_examples=20)
@given(b=st.integers(1, 8), m=st.integers(1, 40), k=st.integers(1, 12),
       inf_frac=st.floats(0.0, 1.0))
def test_merge_topk_idempotent_and_masked(b, m, k, inf_frac):
    k = min(k, m)   # merge_topk contract: at least k candidate columns
    rng = np.random.default_rng(b * 1000 + m * 10 + k)
    cand_d, cand_i = _candidates(rng, b, m, inf_frac)
    d1, i1 = collectives.merge_topk(cand_d, cand_i, k)
    assert d1.shape == (b, k) and i1.shape == (b, k)
    d_np = np.asarray(d1)
    # ascending (inf -> finite sentinel: inf-inf diffs are nan), and +inf
    # slots report id -1 (the shard-padding contract)
    assert (np.diff(np.nan_to_num(d_np, posinf=3e38), axis=1) >= 0).all()
    assert (np.asarray(i1)[~np.isfinite(d_np)] == -1).all()
    # idempotence: merging the merged list again is a fixed point
    d2, i2 = collectives.merge_topk(d1, i1, k)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@settings(deadline=None, max_examples=20)
@given(b=st.integers(1, 6), m=st.integers(2, 40), k=st.integers(1, 10),
       seed=st.integers(0, 10_000))
def test_merge_topk_permutation_invariant(b, m, k, seed):
    k = min(k, m)
    rng = np.random.default_rng(seed)
    cand_d, cand_i = _candidates(rng, b, m, 0.2)
    perm = rng.permutation(m)
    d1, i1 = collectives.merge_topk(cand_d, cand_i, k)
    d2, i2 = collectives.merge_topk(cand_d[:, perm], cand_i[:, perm], k)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@settings(deadline=None, max_examples=10)
@given(n=st.integers(64, 400), d=st.integers(2, 24),
       scale_pow=st.floats(-2.0, 2.0), seed=st.integers(0, 1000))
def test_sq8_round_trip_error_bound(n, d, scale_pow, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n, d)) * 10.0 ** scale_pow).astype(np.float32)
    index = ivf.build(x, nlist=4, iters=3, seed=0, quantize=True)
    assert index.quantized

    ids = np.asarray(index.bucket_ids)
    vecs = np.asarray(index.bucket_vecs).astype(np.float32)
    scale = np.asarray(index.scale)
    offset = np.asarray(index.offset)
    x_hat = vecs * scale[None, None, :] + offset[None, None, :]

    valid = ids >= 0
    err = np.abs(x_hat[valid] - x[ids[valid]])
    # affine SQ8: |x - x_hat| <= scale/2 per dim (0.51 absorbs the f32
    # rounding of the round-trip itself, which is << scale); in-range
    # data never clips because scale >= (hi - lo) / 254 maps to ±127.
    bound = 0.51 * scale[None, :]
    assert (err <= bound).all(), float((err - bound).max())

    # bucket_sqnorm is computed on the DEQUANTIZED vectors
    sqn = np.asarray(index.bucket_sqnorm)
    np.testing.assert_allclose(sqn[valid], (x_hat[valid] ** 2).sum(axis=1),
                               rtol=1e-4, atol=1e-4)
    # padding contract survives quantized builds
    assert np.isposinf(sqn[~valid]).all()
    assert (np.asarray(index.bucket_vecs)[~valid] == 0).all()
