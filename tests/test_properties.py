"""Property-based invariants (hypothesis; deterministic shim in
tests/_vendor when the real package is absent):

  * collectives.merge_topk — idempotence, permutation-invariance of the
    candidate columns, and the +inf -> id -1 masking contract that keeps
    shard padding out of results.
  * ivf.build SQ8 storage — per-dim affine round-trip error is bounded
    by half a quantization step, and bucket_sqnorm matches the norms of
    the DEQUANTIZED vectors (what quantized search actually measures).
  * mutate.delta.DeltaTier — arbitrary insert/delete/wrap interleavings
    preserve the ring invariants (free-slot-only placement, tombstone
    pad convention, live-count accounting), and merging an EMPTY delta
    into a base top-k is the identity.
  * mutate.MutableIndex compaction under load — arbitrary interleavings
    of insert/delete/background-tick/swap keep the ledger coherent: the
    live set always equals a model-dict oracle, tombstones never surface
    through the serving wrapper (including deletes landing mid-rebuild),
    and the post-drain base equals the oracle exactly.
"""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.dist import collectives
from repro.index import ivf
from repro.mutate import delta as delta_lib


def _candidates(rng, b, m, inf_frac):
    d = rng.uniform(0.0, 100.0, (b, m)).astype(np.float32)
    # distinct distances -> unique top-k selection, no tie ambiguity
    d = d + np.arange(b * m, dtype=np.float32).reshape(b, m) * 1e-3
    mask = rng.random((b, m)) < inf_frac
    d = np.where(mask, np.inf, d)
    ids = np.where(mask, -1, rng.integers(0, 10_000, (b, m))).astype(np.int32)
    return jnp.asarray(d), jnp.asarray(ids)


@settings(deadline=None, max_examples=20)
@given(b=st.integers(1, 8), m=st.integers(1, 40), k=st.integers(1, 12),
       inf_frac=st.floats(0.0, 1.0))
def test_merge_topk_idempotent_and_masked(b, m, k, inf_frac):
    k = min(k, m)   # merge_topk contract: at least k candidate columns
    rng = np.random.default_rng(b * 1000 + m * 10 + k)
    cand_d, cand_i = _candidates(rng, b, m, inf_frac)
    d1, i1 = collectives.merge_topk(cand_d, cand_i, k)
    assert d1.shape == (b, k) and i1.shape == (b, k)
    d_np = np.asarray(d1)
    # ascending (inf -> finite sentinel: inf-inf diffs are nan), and +inf
    # slots report id -1 (the shard-padding contract)
    assert (np.diff(np.nan_to_num(d_np, posinf=3e38), axis=1) >= 0).all()
    assert (np.asarray(i1)[~np.isfinite(d_np)] == -1).all()
    # idempotence: merging the merged list again is a fixed point
    d2, i2 = collectives.merge_topk(d1, i1, k)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@settings(deadline=None, max_examples=20)
@given(b=st.integers(1, 6), m=st.integers(2, 40), k=st.integers(1, 10),
       seed=st.integers(0, 10_000))
def test_merge_topk_permutation_invariant(b, m, k, seed):
    k = min(k, m)
    rng = np.random.default_rng(seed)
    cand_d, cand_i = _candidates(rng, b, m, 0.2)
    perm = rng.permutation(m)
    d1, i1 = collectives.merge_topk(cand_d, cand_i, k)
    d2, i2 = collectives.merge_topk(cand_d[:, perm], cand_i[:, perm], k)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@settings(deadline=None, max_examples=10)
@given(n=st.integers(64, 400), d=st.integers(2, 24),
       scale_pow=st.floats(-2.0, 2.0), seed=st.integers(0, 1000))
def test_sq8_round_trip_error_bound(n, d, scale_pow, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n, d)) * 10.0 ** scale_pow).astype(np.float32)
    index = ivf.build(x, nlist=4, iters=3, seed=0, quantize=True)
    assert index.quantized

    ids = np.asarray(index.bucket_ids)
    vecs = np.asarray(index.bucket_vecs).astype(np.float32)
    scale = np.asarray(index.scale)
    offset = np.asarray(index.offset)
    x_hat = vecs * scale[None, None, :] + offset[None, None, :]

    valid = ids >= 0
    err = np.abs(x_hat[valid] - x[ids[valid]])
    # affine SQ8: |x - x_hat| <= scale/2 per dim (0.51 absorbs the f32
    # rounding of the round-trip itself, which is << scale); in-range
    # data never clips because scale >= (hi - lo) / 254 maps to ±127.
    bound = 0.51 * scale[None, :]
    assert (err <= bound).all(), float((err - bound).max())

    # bucket_sqnorm is computed on the DEQUANTIZED vectors
    sqn = np.asarray(index.bucket_sqnorm)
    np.testing.assert_allclose(sqn[valid], (x_hat[valid] ** 2).sum(axis=1),
                               rtol=1e-4, atol=1e-4)
    # padding contract survives quantized builds
    assert np.isposinf(sqn[~valid]).all()
    assert (np.asarray(index.bucket_vecs)[~valid] == 0).all()


# ---------------------------------------------------------------------------
# DeltaTier ring invariants under arbitrary insert/delete interleavings
# ---------------------------------------------------------------------------

def _tiny_base(dim):
    """Smallest possible base index: the properties target the DELTA
    ring bookkeeping, so the base just anchors MutableIndex (its one
    bucket never changes)."""
    from repro.index import ivf as ivf_lib
    rng = np.random.default_rng(7)
    x = rng.normal(size=(8, dim)).astype(np.float32)
    return ivf_lib.build(x, nlist=1, iters=1, seed=0)


@settings(deadline=None, max_examples=12)
@given(capacity=st.integers(4, 40), dim=st.integers(2, 8),
       seed=st.integers(0, 100_000), nops=st.integers(1, 30))
def test_delta_tier_interleavings_preserve_invariants(capacity, dim, seed,
                                                      nops):
    """Arbitrary interleavings of insert / delete (forcing ring wraps
    through repeated fill-and-free cycles) keep the DeltaTier invariants:

      * free-slot-only placement — a live slot is never overwritten, so
        every live id still holds exactly the vector it was inserted
        with;
      * tombstoned / empty slots carry the pad convention (ids -1,
        sqnorm +inf) and live slots carry their true sqnorm;
      * live-count accounting — num_delta == inserts - deletes (into /
        of the delta), and MutableIndex.num_live == issued - deleted.
    """
    from repro.mutate import MutableIndex

    mut = MutableIndex(_tiny_base(dim), capacity=capacity)
    rng = np.random.default_rng(seed)
    model = {}            # live delta id -> its vector (the oracle)
    n_ins = n_del = 0
    for _ in range(nops):
        room = capacity - mut.num_delta
        if model and (room == 0 or rng.random() < 0.45):
            kill = rng.choice(sorted(model), size=rng.integers(
                1, len(model) + 1), replace=False)
            assert mut.delete(kill) == len(kill)
            for i in kill:
                model.pop(int(i))
            n_del += len(kill)
        elif room > 0:
            m = int(rng.integers(1, room + 1))
            vecs = rng.normal(size=(m, dim)).astype(np.float32)
            ids = mut.insert(vecs)
            assert len(ids) == m
            for j, i in enumerate(ids):
                assert int(i) not in model   # ids never reused
                model[int(i)] = vecs[j]
            n_ins += m

        d_ids = np.asarray(jax.device_get(mut.delta.ids))
        d_vecs = np.asarray(jax.device_get(mut.delta.vecs))
        d_sqn = np.asarray(jax.device_get(mut.delta.sqnorm))
        live = d_ids >= 0
        # live-count accounting
        assert mut.num_delta == n_ins - n_del == int(live.sum())
        assert set(d_ids[live].tolist()) == set(model)
        # free-slot-only placement: every live id still holds its vector
        for slot in np.nonzero(live)[0]:
            np.testing.assert_array_equal(d_vecs[slot],
                                          model[int(d_ids[slot])])
        # pad convention: dead/empty slots are +inf / -1, live carry
        # their true sqnorm
        assert np.isposinf(d_sqn[~live]).all()
        np.testing.assert_allclose(
            d_sqn[live], (d_vecs[live] ** 2).sum(axis=1), rtol=1e-5,
            atol=1e-5)
    # base ids untouched by delta churn
    assert mut.num_live == 8 + n_ins - n_del


@settings(deadline=None, max_examples=15)
@given(b=st.integers(1, 8), k=st.integers(1, 10), dim=st.integers(2, 12),
       capacity=st.integers(10, 64), inf_frac=st.floats(0.0, 0.6),
       seed=st.integers(0, 10_000))
def test_empty_delta_merge_is_identity(b, k, dim, capacity, inf_frac,
                                       seed):
    """Merging an EMPTY delta's scan into any well-formed base top-k is
    the identity — the contract that makes mutable_engine bit-for-bit
    equal to its base engine post-compaction."""
    rng = np.random.default_rng(seed)
    delta = delta_lib.make_delta(capacity, dim)
    q = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    dd, di, live, nins = delta_lib.delta_topk(delta, q, k)
    assert int(live) == 0 and (np.asarray(nins) == 0).all()
    assert np.isposinf(np.asarray(dd)).all()
    assert (np.asarray(di) == -1).all()

    # well-formed base top-k: ascending, +inf tail with ids -1
    base_d = np.sort(rng.uniform(0.0, 100.0, (b, k)).astype(np.float32), 1)
    n_inf = (rng.random((b, 1)) * (k + 1)).astype(int)
    tail = np.arange(k)[None, :] >= (k - n_inf)
    base_d = np.where(tail, np.inf, base_d)
    base_i = np.where(tail, -1,
                      rng.integers(0, 10_000, (b, k))).astype(np.int32)

    m_d, m_i = collectives.merge_topk(
        jnp.concatenate([jnp.asarray(base_d), dd], axis=1),
        jnp.concatenate([jnp.asarray(base_i), di], axis=1), k)
    np.testing.assert_array_equal(np.asarray(m_d), base_d)
    np.testing.assert_array_equal(np.asarray(m_i), base_i)


# -- overload admission control (serve.difficulty) ------------------------

_SERVE_FIXTURE = {}


def _overload_fixture():
    """One tiny served stack shared across hypothesis examples (the
    chunk jits compile once; every example only re-runs the host-side
    admission logic plus a handful of small device chunks). The stub
    predictor pins recall at 0, so no query terminates early and every
    admitted query runs exactly nprobe engine steps — admission
    decisions, not search dynamics, drive the outcome."""
    if _SERVE_FIXTURE:
        return _SERVE_FIXTURE["v"]
    from repro.core import engines
    from repro.core.intervals import IntervalParams
    from repro.data import vectors

    ds = vectors.make_dataset(n=600, d=8, num_learn=16, num_queries=96,
                              clusters=4, cluster_std=1.0, seed=5)
    index = ivf.build(ds.base, nlist=4, seed=5)
    eng = engines.ivf_engine(index, k=5, nprobe=4)

    def predictor(feats):
        return jnp.zeros((feats.shape[0],), jnp.float32)

    def interval_for_target(rt):
        rt = np.atleast_1d(rt)
        return IntervalParams(ipi=np.full(rt.shape, 8.0, np.float32),
                              mpi=np.full(rt.shape, 4.0, np.float32))

    _SERVE_FIXTURE["v"] = (ds, eng, predictor, interval_for_target)
    return _SERVE_FIXTURE["v"]


@settings(deadline=None, max_examples=10)
@given(n=st.integers(9, 96), max_queue=st.integers(0, 24),
       shed=st.booleans(), log_hosts=st.integers(0, 2),
       hard_quantile=st.floats(0.0, 1.0),
       hard_frac=st.floats(0.0, 0.5))
def test_overload_admission_never_silently_drops(n, max_queue, shed,
                                                 log_hosts, hard_quantile,
                                                 hard_frac):
    """Overload admission control: under a query stream exceeding slot
    capacity with a bounded queue, EVERY query id is accounted for —
    served (a result came back), or explicitly shed (its id recorded in
    HostStats.shed_ids, its result None). Nothing is silently dropped,
    nothing returns twice, and under overload="degrade" every query is
    served. The per-host ledger (admitted = completed + truncated,
    stripe = admitted + shed) must balance exactly."""
    from repro.serve import DarthServer, TierConfig

    ds, eng, predictor, interval_for_target = _overload_fixture()
    hosts = 2 ** log_hosts
    tiers = TierConfig(hard_quantile=hard_quantile,
                       hard_slot_fraction=hard_frac,
                       max_queue=max_queue,
                       overload="shed" if shed else "degrade",
                       degrade_target=0.5)
    server = DarthServer(eng, predictor, interval_for_target,
                         num_slots=8, steps_per_sync=2, hosts=hosts,
                         tiers=tiers)
    rts = np.full((n,), 0.9, np.float32)
    results, stats = server.serve(ds.queries[:n], rts)

    served = {i for i, r in enumerate(results) if r is not None}
    shed_ids = [q for h in stats.hosts for q in h.shed_ids]
    assert len(shed_ids) == len(set(shed_ids))          # no double-shed
    assert served.isdisjoint(shed_ids)                  # shed => no result
    assert served | set(shed_ids) == set(range(n))      # total accounting
    assert stats.shed == len(shed_ids)
    if not shed:
        assert not shed_ids and len(served) == n        # degrade serves all
        # only queue overflow beyond max_queue is degraded, never more
        assert stats.degraded <= max(n - hosts * max_queue, 0)
    for h in stats.hosts:
        assert h.admitted == h.completed + h.truncated
        stripe = len(range(h.host, n, hosts))
        assert stripe == h.admitted + h.shed + h.abandoned


# ---------------------------------------------------------------------------
# Compaction under load: insert / delete / tick / swap interleavings
# ---------------------------------------------------------------------------

def _prop_base():
    """Shared tiny IVF base for the compaction-under-load property —
    deletes/compactions REPLACE MutableIndex.base functionally, so the
    built index object is never mutated and examples can share it."""
    from repro.index import ivf as ivf_lib
    rng = np.random.default_rng(11)
    x = rng.normal(size=(96, 6)).astype(np.float32)
    return x, ivf_lib.build(x, nlist=4, iters=2, seed=0)


_PROP_X, _PROP_INDEX = _prop_base()


@settings(deadline=None, max_examples=10)
@given(ops=st.lists(st.sampled_from(["insert", "delete", "tick", "swap"]),
                    min_size=4, max_size=28),
       seed=st.integers(0, 100_000))
def test_compaction_under_load_preserves_ledger_invariants(ops, seed):
    """Arbitrary interleavings of insert / delete / background-tick /
    swap keep the mutable-index ledger coherent:

      * the live set is always exactly (issued - tombstoned) — a
        model-dict oracle over ids -> vectors, regardless of where each
        id currently lives (base, shadow-in-flight, or delta ring);
      * tombstones never surface through the serving wrapper, even for
        ids deleted WHILE their fold was being rebuilt (the
        deleted_since re-application at swap);
      * mid-rebuild inserts survive the swap live in the ring;
      * a full-probe search through mutable_engine returns the exact
        nearest neighbor of the live universe (brute-force oracle).
    """
    from repro import mutate
    from repro.core import darth_search, engines

    mut = mutate.MutableIndex(_PROP_INDEX, capacity=32)
    rng = np.random.default_rng(seed)
    model = {int(i): _PROP_X[i] for i in range(96)}
    dead = set()
    for op in ops:
        if op == "insert":
            room = 32 - mut.num_delta
            if room <= 0:
                continue
            m = int(rng.integers(1, min(room, 4) + 1))
            vecs = rng.normal(size=(m, 6)).astype(np.float32)
            for j, i in enumerate(mut.insert(vecs)):
                model[int(i)] = vecs[j]
        elif op == "delete":
            if not model:
                continue
            kill = rng.choice(sorted(model), size=min(3, len(model)),
                              replace=False)
            assert mut.delete(kill) == len(kill)
            for i in kill:
                model.pop(int(i))
                dead.add(int(i))
        elif op == "tick":
            if not mut.compacting:
                mut.begin_compaction()
            else:
                mut.compact_tick()
        elif op == "swap":
            if mut.compacting and mut._job.done:
                mut.swap_compaction()
        # ledger: live set == oracle, tombstones out, delta counted
        assert mut.num_live == len(model)
        live_ids, live_vecs = mut.live_vectors()
        assert set(int(i) for i in live_ids) == set(model)
        assert not (set(int(i) for i in live_ids) & dead)
        order = np.argsort(live_ids)
        np.testing.assert_array_equal(
            live_vecs[order],
            np.stack([model[int(i)] for i in np.sort(live_ids)]))

    # drain: finish any in-flight rebuild, then fold the leftovers —
    # the end state must equal the oracle exactly
    if mut.compacting:
        while not mut.compact_tick():
            pass
        mut.swap_compaction()
    if mut.num_delta or len(model) != np.count_nonzero(
            np.asarray(mut.base.bucket_ids) >= 0):
        mut.compact()
    bi = np.asarray(mut.base.bucket_ids)
    assert set(bi[bi >= 0].tolist()) == set(model)
    assert mut.num_delta == 0

    if model:
        meng = engines.mutable_engine(
            engines.ivf_engine(mut.base, k=1, nprobe=4), mut.delta)
        probe_id = sorted(model)[int(rng.integers(0, len(model)))]
        ws = darth_search.plain_search(
            meng, jnp.asarray(model[probe_id][None, :]))
        assert int(np.asarray(meng.topk_i(ws))[0, 0]) == probe_id
        assert not (dead
                    & set(np.asarray(meng.topk_i(ws)).ravel().tolist()))
