"""End-to-end behaviour tests for the paper's system (deliverable c).

The paper's contract: ANNS(q, G, k, R_t) returns approximate k-NN with
recall >= R_t (w.h.p.), faster than plain search, with no per-target
tuning. These tests exercise the full pipeline on both supported indexes.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api, engines
from repro.data import vectors
from repro.index import flat, hnsw, ivf


@pytest.fixture(scope="module")
def ds():
    return vectors.make_dataset(n=6000, d=24, num_learn=600, num_queries=128,
                                clusters=32, cluster_std=1.2, seed=0)


def _check_declarative_recall(d, ds, targets=(0.8, 0.9)):
    q = jnp.asarray(ds.queries)
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), d.engine.k)
    _, _, plain = d.search_plain(q)
    plain_nd = float(np.asarray(plain.ndis).mean())
    prev_nd = 0.0
    for rt in targets:
        dd, ii, st = d.search(q, rt)
        rec = float(flat.recall_at_k(ii, gt_i).mean())
        nd = float(np.asarray(st.inner.ndis).mean())
        assert rec >= rt - 0.03, (rt, rec)
        assert nd <= plain_nd
        assert nd >= prev_nd - 1e-6   # higher target -> no less work
        prev_nd = nd
        # diagnostics coherent
        assert np.asarray(st.npred).min() >= 0
        early = np.asarray(st.early)
        assert early.mean() > 0.5     # most queries early-terminate


def test_darth_ivf_end_to_end(ds):
    index = ivf.build(ds.base, nlist=32, seed=0)
    eng = engines.ivf_engine(index, k=10, nprobe=32)
    d = api.Darth(make_engine=lambda **kw: engines.ivf_engine(index, **kw),
                  engine=eng)
    trained = d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    assert trained.metrics["mse"] < 0.02
    _check_declarative_recall(d, ds)


def test_darth_hnsw_end_to_end(ds):
    index = hnsw.build(ds.base, m=12, passes=1, ef_construction=48)
    eng = engines.hnsw_engine(index, k=10, ef=96)
    d = api.Darth(make_engine=lambda **kw: engines.hnsw_engine(index, **kw),
                  engine=eng)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=200)
    q = jnp.asarray(ds.queries)
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    _, _, plain = d.search_plain(q)
    plain_rec = float(flat.recall_at_k(plain.cand_i[:, :10], gt_i).mean())
    rt = min(0.85, plain_rec - 0.02)   # attainable target (paper §2.3)
    dd, ii, st = d.search(q, rt)
    rec = float(flat.recall_at_k(ii, gt_i).mean())
    assert rec >= rt - 0.04, (rt, rec, plain_rec)
    assert float(np.asarray(st.inner.ndis).mean()) <= \
        float(np.asarray(plain.ndis).mean())


def test_tuning_free_targets_without_refit(ds):
    """Any attainable target works from ONE fit — the paper's headline."""
    index = ivf.build(ds.base, nlist=32, seed=0)
    eng = engines.ivf_engine(index, k=10, nprobe=32)
    d = api.Darth(make_engine=lambda **kw: engines.ivf_engine(index, **kw),
                  engine=eng)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    q = jnp.asarray(ds.queries)
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    for rt in (0.82, 0.87, 0.93, 0.97):   # arbitrary targets, no refit
        _, ii, _ = d.search(q, rt)
        rec = float(flat.recall_at_k(ii, gt_i).mean())
        assert rec >= rt - 0.04, (rt, rec)
