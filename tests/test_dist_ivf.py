"""Sharded IVF probe engine: numeric parity with the single-device probe
loop (topk_d / topk_i / ndis / ninserts) on the 1-device mesh in-process,
and on real (placeholder) {1, 2, 4}-shard meshes in a subprocess — for
both f32 and SQ8 storage, with a bucket cap that does not divide the
shard count (place_index pads; padding must stay +inf / id -1)."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import dist
from repro.core import darth_search, engines
from repro.index import ivf


def _mesh1():
    return jax.make_mesh((1,), ("model",))


@pytest.fixture(scope="module")
def small_ivf():
    from repro.data import vectors
    ds = vectors.make_dataset(n=2000, d=16, num_learn=128, num_queries=32,
                              clusters=16, cluster_std=1.0, seed=0)
    return ds


@pytest.mark.parametrize("quantize", [False, True])
def test_sharded_probe_matches_single_device(small_ivf, quantize):
    ds = small_ivf
    index = ivf.build(ds.base, nlist=16, seed=0, cap_round=1,
                      quantize=quantize)
    mesh = _mesh1()
    placed = dist.place_index(index, mesh)
    q = jnp.asarray(ds.queries[:16])
    d0, i0, s0 = ivf.search(index, q, k=5, nprobe=6)
    d1, i1, s1 = ivf.search_sharded(placed, q, k=5, nprobe=6, mesh=mesh)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0.ndis), np.asarray(s1.ndis))
    np.testing.assert_array_equal(np.asarray(s0.ninserts),
                                  np.asarray(s1.ninserts))


def test_sharded_probe_xla_fallback_matches(small_ivf):
    ds = small_ivf
    index = ivf.build(ds.base, nlist=16, seed=0)
    mesh = _mesh1()
    placed = dist.place_index(index, mesh)
    q = jnp.asarray(ds.queries[:8])
    d0, i0, _ = ivf.search(index, q, k=5, nprobe=4)
    d1, i1, _ = ivf.search_sharded(placed, q, k=5, nprobe=4, mesh=mesh,
                                   use_kernel=False)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_sharded_engine_protocol_drivers(small_ivf):
    """darth_search's plain / budget drivers run the sharded engine
    unchanged (Engine protocol) and reproduce single-device results."""
    ds = small_ivf
    index = ivf.build(ds.base, nlist=16, seed=0)
    mesh = _mesh1()
    placed = dist.place_index(index, mesh)
    q = jnp.asarray(ds.queries[:16])
    eng_ref = engines.ivf_engine(index, k=5, nprobe=6)
    eng_sh = engines.sharded_ivf_engine(placed, mesh, k=5, nprobe=6)
    assert eng_sh.name == "ivf-sharded" and eng_sh.max_steps == 6

    plain_ref = darth_search.plain_search(eng_ref, q)
    plain_sh = darth_search.plain_search(eng_sh, q)
    np.testing.assert_array_equal(np.asarray(plain_ref.topk_i),
                                  np.asarray(plain_sh.topk_i))

    bud_ref = darth_search.budget_search(eng_ref, q, 300.0)
    bud_sh = darth_search.budget_search(eng_sh, q, 300.0)
    np.testing.assert_array_equal(np.asarray(bud_ref.ndis),
                                  np.asarray(bud_sh.ndis))
    np.testing.assert_array_equal(np.asarray(bud_ref.topk_i),
                                  np.asarray(bud_sh.topk_i))


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

sys.path.insert(0, "src")
from repro import dist
from repro.data import vectors
from repro.index import ivf

ds = vectors.make_dataset(n=2000, d=16, num_learn=64, num_queries=32,
                          clusters=16, cluster_std=1.0, seed=0)
q = jnp.asarray(ds.queries[:16])
out = {"ndev": jax.device_count(), "cases": []}
for quantize in (False, True):
    # cap_round=1 -> cap is the raw max bucket size (217 for this seed),
    # NOT a multiple of 2 or 4: place_index must pad the cap dim.
    index = ivf.build(ds.base, nlist=16, seed=0, cap_round=1,
                      quantize=quantize)
    d0, i0, s0 = ivf.search(index, q, k=5, nprobe=6)
    for nsh in (1, 2, 4):
        mesh = Mesh(np.asarray(jax.devices()[:nsh]), ("model",))
        placed = dist.place_index(index, mesh)
        # padding contract on the placed arrays
        ids_pad = np.asarray(placed.bucket_ids)[:, index.cap:]
        sqn_pad = np.asarray(placed.bucket_sqnorm)[:, index.cap:]
        d1, i1, s1 = ivf.search_sharded(placed, q, k=5, nprobe=6,
                                        mesh=mesh)
        out["cases"].append({
            "quantize": quantize, "shards": nsh,
            "cap": index.cap, "cap_padded": placed.cap,
            "pad_ok": bool((ids_pad == -1).all()
                           and np.isposinf(sqn_pad).all()),
            "d_ok": bool(np.allclose(np.asarray(d0), np.asarray(d1),
                                     atol=1e-4)),
            "i_ok": bool(np.array_equal(np.asarray(i0), np.asarray(i1))),
            "ndis_ok": bool(np.array_equal(np.asarray(s0.ndis),
                                           np.asarray(s1.ndis))),
            "nins_ok": bool(np.array_equal(np.asarray(s0.ninserts),
                                           np.asarray(s1.ninserts))),
        })
print(json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_probe_parity_mesh_1_2_4():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 4
    assert len(res["cases"]) == 6
    for case in res["cases"]:
        if case["shards"] > 1:     # 217 padded up to the shard multiple
            assert case["cap_padded"] % case["shards"] == 0, case
            assert case["cap_padded"] > case["cap"], case
        for key in ("pad_ok", "d_ok", "i_ok", "ndis_ok", "nins_ok"):
            assert case[key], case
