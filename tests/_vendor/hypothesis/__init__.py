"""Minimal deterministic stand-in for the subset of the `hypothesis` API
this test-suite uses (given / settings / strategies.integers / floats).

Only importable when the real package is absent: tests/conftest.py adds
this directory to sys.path as a fallback, so CI (which installs real
hypothesis from requirements.txt) is unaffected. Sampling is seeded and
replayable; the first two examples of every strategy are the interval
endpoints so boundary behavior is always exercised.
"""
from __future__ import annotations

import inspect
import random

from . import strategies  # noqa: F401  (re-export)

__version__ = "0.0-repro-shim"

_DEFAULT_MAX_EXAMPLES = 20


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording max_examples on the (already-@given-wrapped)
    test function; other hypothesis knobs are accepted and ignored."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    """Run the test once per drawn example, deterministically."""
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0xDA27)
            for i in range(n):
                drawn = {name: s.draw(rng, i) for name, s in strats.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:  # assume() failed: discard the example
                    continue

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        if hasattr(fn, "pytestmark"):
            wrapper.pytestmark = fn.pytestmark
        # hide the strategy kwargs from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for p in sig.parameters.values() if p.name not in strats])
        return wrapper
    return deco


def assume(condition) -> bool:
    """Shim: skip-on-false is not replayed; treat as a plain guard."""
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


__all__ = ["given", "settings", "assume", "strategies"]
