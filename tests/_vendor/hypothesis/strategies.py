"""Strategy objects for the hypothesis shim: seeded draws, endpoints
first (example 0 = lo, example 1 = hi, then uniform samples)."""
from __future__ import annotations

import random
from typing import Callable


class SearchStrategy:
    def __init__(self, lo, hi, sample: Callable[[random.Random], object]):
        self._lo, self._hi, self._sample = lo, hi, sample

    def draw(self, rng: random.Random, example_index: int):
        if example_index == 0:
            return self._lo
        if example_index == 1:
            return self._hi
        return self._sample(rng)

    def __repr__(self):
        return f"SearchStrategy({self._lo!r}, {self._hi!r})"


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(min_value, max_value,
                          lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_ignored) -> SearchStrategy:
    # log-uniform when the interval spans decades (matches how these
    # tests use wide scale ranges), uniform otherwise
    import math
    if min_value > 0 and max_value / min_value > 1e3:
        lo, hi = math.log(min_value), math.log(max_value)
        return SearchStrategy(min_value, max_value,
                              lambda rng: math.exp(rng.uniform(lo, hi)))
    return SearchStrategy(min_value, max_value,
                          lambda rng: rng.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(False, True, lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    return SearchStrategy(seq[0], seq[-1], lambda rng: rng.choice(seq))


def lists(element: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    # endpoints: shortest list of lo-elements, longest of hi-elements;
    # sampled examples draw length then elements from the child strategy
    def sample(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [element._sample(rng) for _ in range(n)]

    return SearchStrategy([element._lo] * min_size,
                          [element._hi] * max_size, sample)


__all__ = ["SearchStrategy", "integers", "floats", "booleans",
           "sampled_from", "lists"]
