import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import api, darth_search, engines, features, intervals
from repro.index import flat, ivf


# --- features ---------------------------------------------------------------

def test_feature_extraction_sorted_percentiles():
    topk = jnp.asarray([[1.0, 4.0, 9.0, 16.0, 25.0],
                        [1.0, jnp.inf, jnp.inf, jnp.inf, jnp.inf]])
    f = np.asarray(features.extract(
        jnp.asarray([3, 1]), jnp.asarray([100, 7]), jnp.asarray([5, 1]),
        jnp.asarray([2.0, 1.0]), topk))
    names = dict(zip(features.FEATURE_NAMES, range(features.NUM_FEATURES)))
    assert f[0, names["closestNN"]] == 1.0
    assert f[0, names["furthestNN"]] == 5.0
    assert f[0, names["med"]] == 3.0        # sqrt(9)
    assert f[0, names["perc25"]] == 2.0
    assert f[0, names["perc75"]] == 4.0
    assert f[0, names["ndis"]] == 100.0
    # partially-filled result set: stats over the single finite entry
    assert f[1, names["avg"]] == 1.0
    assert f[1, names["furthestNN"]] == 1.0


@settings(deadline=None, max_examples=25)
@given(rt=st.floats(0.5, 1.0), rp=st.floats(0.0, 1.0),
       ipi=st.floats(10.0, 5000.0), frac=st.floats(0.01, 1.0))
def test_adaptive_interval_bounds(rt, rp, ipi, frac):
    """Eq. 1 output is always clipped into [mpi, ipi] and monotone in
    (rt - rp)."""
    p = intervals.IntervalParams(ipi=ipi, mpi=ipi * frac)
    pi = float(intervals.next_interval(p, jnp.asarray(rt), jnp.asarray(rp)))
    tol = 1e-4 * max(abs(p.ipi), 1.0)   # f32 evaluation of f64 params
    assert p.mpi - tol <= pi <= p.ipi + tol
    pi_closer = float(intervals.next_interval(
        p, jnp.asarray(rt), jnp.asarray(min(rp + 0.1, 1.0))))
    assert pi_closer <= pi + 1e-6


def test_heuristic_params():
    p = intervals.heuristic_params(1000.0)
    assert p.ipi == 500.0 and p.mpi == 100.0


def test_dists_to_target():
    recall = np.array([[0.2, 0.5], [0.6, 0.9], [0.9, 0.95], [0.9, 1.0]])
    ndis = np.array([[10, 10], [20, 20], [30, 30], [40, 40]])
    valid = np.ones_like(recall, bool)
    d = intervals.dists_to_target(recall, ndis, valid, 0.9)
    np.testing.assert_allclose(d, [30.0, 20.0])


# --- input validation -------------------------------------------------------

def test_validate_targets_accepts_scalar_and_batch_vector():
    assert api.validate_targets(0.9, 8).shape == ()
    assert api.validate_targets(np.full((8,), 0.9), 8).shape == (8,)


@pytest.mark.parametrize("bad", [
    np.full((7,), 0.9),          # wrong length (stale batch size)
    np.full((8, 1), 0.9),        # 2-D: would broadcast garbage
    np.zeros((0,)),              # empty
])
def test_validate_targets_rejects_bad_shapes(bad):
    with pytest.raises(ValueError, match="r_target shape|finite"):
        api.validate_targets(bad, 8)


@pytest.mark.parametrize("bad", [0.0, -0.5, 1.2, float("nan"),
                                 float("inf")])
def test_validate_targets_rejects_out_of_range(bad):
    with pytest.raises(ValueError, match=r"in \(0, 1\]"):
        api.validate_targets(bad, 4)


# --- end-to-end declarative recall ------------------------------------------

@pytest.fixture(scope="module")
def trained_ivf_darth():
    from repro.data import vectors
    ds = vectors.make_dataset(n=6000, d=24, num_learn=512, num_queries=128,
                              clusters=32, cluster_std=1.2, seed=0)
    index = ivf.build(ds.base, nlist=32, seed=0)
    eng = engines.ivf_engine(index, k=10, nprobe=32)
    d = api.Darth(make_engine=lambda **kw: engines.ivf_engine(index, **kw),
                  engine=eng)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    return ds, index, d


def test_darth_meets_targets(trained_ivf_darth):
    ds, index, d = trained_ivf_darth
    q = jnp.asarray(ds.queries)
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    _, _, plain = d.search_plain(q)
    plain_ndis = float(np.asarray(plain.ndis).mean())
    for rt in (0.8, 0.9):
        dd, ii, st = d.search(q, rt)
        rec = float(flat.recall_at_k(ii, gt_i).mean())
        nd = float(np.asarray(st.inner.ndis).mean())
        assert rec >= rt - 0.02, (rt, rec)       # target met (avg)
        assert nd < plain_ndis, "early termination must save work"


def test_darth_predictor_quality(trained_ivf_darth):
    _, _, d = trained_ivf_darth
    m = d.trained.metrics
    # On the easy fixture most observations sit at recall ~1.0, so R^2 can
    # be modest even when absolute errors are tiny; require either.
    assert m["mse"] < 0.02, m
    assert m["r2"] > 0.3 or m["mse"] < 0.005, m


def test_darth_per_query_targets_mixed(trained_ivf_darth):
    """Mixed declared targets in one batch (per-query R_t)."""
    ds, index, d = trained_ivf_darth
    q = jnp.asarray(ds.queries[:64])
    rt = jnp.asarray([0.8, 0.95] * 32)
    dd, ii, st = d.search(q, rt)
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    rec = np.asarray(flat.recall_at_k(ii, gt_i))
    assert rec[1::2].mean() >= rec[::2].mean() - 0.05


def test_budget_search_respects_budget(trained_ivf_darth):
    ds, index, d = trained_ivf_darth
    eng = d.engine
    inner = darth_search.budget_search(eng, jnp.asarray(ds.queries[:32]),
                                       400.0)
    nd = np.asarray(inner.ndis)
    cap = np.asarray(index.bucket_sizes).max()
    assert (nd <= 400 + cap).all()   # can overshoot by at most one probe


def test_darth_search_rejects_malformed_targets(trained_ivf_darth):
    """Regression: a shape-mismatched per-query r_target (e.g. carried
    over from a differently sized batch) or an out-of-range target must
    raise, not broadcast garbage into the termination test."""
    ds, index, d = trained_ivf_darth
    q = jnp.asarray(ds.queries[:8])
    with pytest.raises(ValueError, match="does not match query batch"):
        d.search(q, np.full((7,), 0.9, np.float32))
    with pytest.raises(ValueError, match="does not match query batch"):
        d.search(q, np.full((8, 1), 0.9, np.float32))
    with pytest.raises(ValueError, match=r"in \(0, 1\]"):
        d.search(q, 1.5)
    with pytest.raises(ValueError, match=r"in \(0, 1\]"):
        d.search(q, np.asarray([0.9] * 7 + [np.nan], np.float32))
    dd, ii, _ = d.search(q, np.full((8,), 0.9, np.float32))  # valid
    assert ii.shape == (8, 10)


def test_npred_counts_reasonable(trained_ivf_darth):
    ds, _, d = trained_ivf_darth
    _, _, st = d.search(jnp.asarray(ds.queries[:64]), 0.9)
    npred = np.asarray(st.npred)
    assert (npred >= 1).all() and npred.mean() < 50
