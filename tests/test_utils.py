"""Unit tests for the analysis/infra utilities: meshctx, hlo parser,
metrics, roofline model-flops."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import metrics
from repro.utils import hlo as hlo_lib
from repro.utils import meshctx


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = meshctx.constrain(x, "dp", None)
    assert y is x  # literally untouched


def test_constrain_divisibility_degrades():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with meshctx.use_mesh(mesh):
        x = jnp.ones((3, 7))  # nothing divides -> P(None, None)
        y = meshctx.constrain(x, "dp", "tp")
        assert y.shape == x.shape


def test_sp_axis_gated():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with meshctx.use_mesh(mesh, sp=False):
        assert meshctx._resolve(mesh, "sp") is None
    with meshctx.use_mesh(mesh, sp=True):
        assert meshctx._resolve(mesh, "sp") == "model"
    # dpt = all axes
    assert meshctx._resolve(mesh, "dpt") == ("data", "model")


def test_hlo_shape_bytes():
    assert hlo_lib._shape_bytes("f32[8,8]{1,0}") == 256
    assert hlo_lib._shape_bytes("bf16[4]") == 8
    assert hlo_lib._shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert hlo_lib._shape_bytes("pred[]") == 1


def test_hlo_dot_flops_weighted():
    hlo = """
HloModule m

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %x = f32[4,4] get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4,4]) tuple(%i, %d)
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %a)
  %w = (s32[], f32[4,4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,4] get-tuple-element(%w), index=1
}
"""
    out = hlo_lib.analyze(hlo)
    # dot: 2*16*4 = 128 flops x 3 trips
    assert out["flops"] == 128 * 3


def test_metrics_recall_rde_nrs():
    found_i = np.array([[0, 1, 2], [3, 9, 8]])
    true_i = np.array([[0, 1, 3], [3, 4, 5]])
    r = metrics.recall(found_i, true_i)
    np.testing.assert_allclose(r, [2 / 3, 1 / 3])
    assert metrics.rqut(r, 0.5) == 0.5

    found_d = np.array([[1.0, 4.0, 9.0]])
    true_d = np.array([[1.0, 4.0, 4.0]])
    v = metrics.rde(found_d, true_d)          # only slot 3 deviates: (3-2)/2
    np.testing.assert_allclose(v, [0.5 / 3], atol=1e-6)

    gt_wide = np.array([[0, 1, 2, 3, 4]])
    n = metrics.nrs(np.array([[0, 1, 2]]), gt_wide)
    np.testing.assert_allclose(n, [1.0])      # perfect ranks

    es = metrics.error_stats(np.array([0.95, 0.5]), 0.9)
    assert es["worst1pct"] == pytest.approx(0.4)


def test_roofline_model_flops():
    import benchmarks.roofline as rl
    mf_train = rl.model_flops("smollm-360m", "train", 4096, 256)
    counts = rl._param_counts("smollm-360m")
    assert mf_train == 6 * counts["active"] * 4096 * 256
    # MoE active < total
    c = rl._param_counts("qwen3-moe-30b-a3b")
    assert c["active"] < 0.25 * c["total"]
