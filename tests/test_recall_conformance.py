"""Declarative-recall conformance: the paper's core contract, end to end.

For both engine families (IVF probe loop, HNSW beam loop): train the GBDT
recall predictor on synthetic data, run darth_search at declared targets
{0.80, 0.90, 0.95}, and assert that (a) mean achieved recall is within
0.03 of every declared target and (b) early termination measurably saves
distance calculations vs plain_search (the speedup that makes the
contract useful, paper §4.2).

The contract is also asserted under the DEPLOYED topology, not just
`Darth.search`: the multi-host slot-pool server (per-host admission /
refill / compaction over slot slices, with difficulty tiers enabled)
must meet the same targets with an ndis speedup — serving-harness
structure, not just the index, determines what users actually observe.
The serving assertions cover p99 achieved recall per declared target,
not only the mean: a mean can hide a tail, and per-query declarations
are only honored if the worst queries land near their targets too."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api, engines
from repro.index import flat, hnsw, ivf
from repro.serve import DarthServer, TierConfig

pytestmark = pytest.mark.slow

TARGETS = (0.80, 0.90, 0.95)
K = 10
TOLERANCE = 0.03
# p99 tail tolerance for the served path. Deliberately wider than the
# mean tolerance: with 128 queries p99 interpolates between the two
# worst queries, and per-query recall is quantized to multiples of
# 1/k = 0.1 — a single unlucky query two k-th-neighbor ties away from
# its target dominates the percentile. Empirically the worst
# tiers-boosted gap across both engines x hosts {2,4} x all targets is
# ~0.19; 0.25 bounds it without flaking on seed jitter.
P99_TOLERANCE = 0.25


@pytest.fixture(scope="module")
def conformance_ds():
    from repro.data import vectors
    return vectors.make_dataset(n=6000, d=24, num_learn=512,
                                num_queries=128, clusters=32,
                                cluster_std=1.2, seed=0)


def _fit_darth(ds, make_engine, engine):
    d = api.Darth(make_engine=make_engine, engine=engine)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    return d


def _assert_conformance(d, ds, name):
    q = jnp.asarray(ds.queries)
    _, gt_i = flat.search(q, jnp.asarray(ds.base), K)
    _, _, plain = d.search_plain(q)
    plain_ndis = float(np.asarray(plain.ndis).mean())
    plain_rec = float(np.asarray(flat.recall_at_k(
        d.engine.topk_i(plain), gt_i)).mean())
    # the declared targets must be attainable by the underlying engine
    assert plain_rec >= max(TARGETS), (name, plain_rec)

    speedups = []
    for rt in TARGETS:
        _, ii, st = d.search(q, rt)
        rec = float(np.asarray(flat.recall_at_k(ii, gt_i)).mean())
        nd = float(np.asarray(st.inner.ndis).mean())
        assert rec >= rt - TOLERANCE, (name, rt, rec)
        assert nd < plain_ndis, (name, rt, nd, plain_ndis)
        speedups.append(plain_ndis / max(nd, 1.0))
    # early termination must be a real speedup somewhere, not epsilon
    assert max(speedups) > 1.5, (name, speedups)


def _assert_serve_conformance(d, ds, name, *, hosts):
    """Same contract, through the deployed topology: every declared
    target served through the multi-host slot pool — with difficulty
    tiers enabled and a hard-tier boost, the shipped configuration —
    lands within TOLERANCE on the mean AND within P99_TOLERANCE at p99,
    with a real ndis saving vs plain search (ServeStats aggregates
    harvested ndis across the per-host loops)."""
    q = jnp.asarray(ds.queries)
    n = ds.queries.shape[0]
    _, gt_i = flat.search(q, jnp.asarray(ds.base), K)
    _, _, plain = d.search_plain(q)
    plain_ndis = float(np.asarray(plain.ndis).mean())

    tiers = TierConfig(hard_quantile=0.75, hard_slot_fraction=0.25,
                       boost=0.02)
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=32,
                         steps_per_sync=2, hosts=hosts, tiers=tiers)
    speedups = []
    for rt in TARGETS:
        results, stats = server.serve(
            ds.queries, np.full((n,), rt, np.float32))
        assert stats.completed == n, (name, hosts, rt, stats)
        ids = np.stack([r[1] for r in results])
        rec = np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i))
        nd = stats.ndis_harvested / stats.completed
        assert float(rec.mean()) >= rt - TOLERANCE, \
            (name, hosts, rt, float(rec.mean()))
        p99 = float(np.percentile(rec, 1))
        assert p99 >= rt - P99_TOLERANCE, (name, hosts, rt, p99)
        assert nd < plain_ndis, (name, hosts, rt, nd, plain_ndis)
        # per-tier ledger: every query landed in exactly one tier
        assert set(stats.tiers) == {"easy", "hard"}
        assert sum(t.count for t in stats.tiers.values()) == n
        assert sum(t.completed for t in stats.tiers.values()) == n
        speedups.append(plain_ndis / max(nd, 1.0))
    assert max(speedups) > 1.5, (name, hosts, speedups)


def test_ivf_meets_declared_targets(conformance_ds):
    ds = conformance_ds
    index = ivf.build(ds.base, nlist=32, seed=0)
    d = _fit_darth(
        ds, lambda **kw: engines.ivf_engine(index, **kw),
        engines.ivf_engine(index, k=K, nprobe=32))
    _assert_conformance(d, ds, "ivf")


def test_hnsw_meets_declared_targets(conformance_ds):
    ds = conformance_ds
    # two insertion passes push the graph's natural recall to ~0.999 at
    # ef=192, leaving room above the 0.95 target AND for early exit
    index = hnsw.build(ds.base, m=16, passes=2, ef_construction=96)
    d = _fit_darth(
        ds, lambda **kw: engines.hnsw_engine(index, **kw),
        engines.hnsw_engine(index, k=K, ef=192, max_steps=400))
    _assert_conformance(d, ds, "hnsw")


@pytest.mark.parametrize("hosts", [2, 4])
def test_ivf_multi_host_serving_meets_declared_targets(conformance_ds,
                                                       hosts):
    ds = conformance_ds
    index = ivf.build(ds.base, nlist=32, seed=0)
    d = _fit_darth(
        ds, lambda **kw: engines.ivf_engine(index, **kw),
        engines.ivf_engine(index, k=K, nprobe=32))
    _assert_serve_conformance(d, ds, "ivf", hosts=hosts)


@pytest.mark.parametrize("hosts", [2, 4])
def test_hnsw_multi_host_serving_meets_declared_targets(conformance_ds,
                                                        hosts):
    ds = conformance_ds
    index = hnsw.build(ds.base, m=16, passes=2, ef_construction=96)
    d = _fit_darth(
        ds, lambda **kw: engines.hnsw_engine(index, **kw),
        engines.hnsw_engine(index, k=K, ef=192, max_steps=400))
    _assert_serve_conformance(d, ds, "hnsw", hosts=hosts)
