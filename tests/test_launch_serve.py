"""Tier-1 smoke coverage for the serving launcher: drives main()
end-to-end on tiny configs for BOTH engine families, including the
--mutations streaming workload (burst -> drift check -> forced
recalibration hot-swap -> compaction)."""
import sys

import pytest

from repro.launch import serve as serve_launch


def _run_main(monkeypatch, capsys, argv):
    monkeypatch.setattr(sys, "argv", ["serve"] + argv)
    serve_launch.main()
    return capsys.readouterr().out


def test_serve_main_ivf_with_mutations(monkeypatch, capsys):
    out = _run_main(monkeypatch, capsys, [
        "--n", "1500", "--dim", "16", "--queries", "32", "--learn", "192",
        "--nlist", "12", "--slots", "8", "--targets", "0.8,0.9",
        "--mutations", "0.2,0.1", "--drift", "0.3",
        # threshold -1 forces the recalibration/hot-swap phase even when
        # the tiny workload's recall survives the burst
        "--recal-threshold", "-1",
    ])
    assert "ivf index built: 1500 vecs" in out
    assert "pre-mutation: target 0.80: mean recall" in out
    assert "mutation burst applied: 300 delta inserts live, 150 tombstones" \
        in out
    assert "post-burst: target" in out
    assert "RECALIBRATING" in out
    assert "predictor refit + hot-swap" in out
    assert "post-recalibration: target" in out
    assert "compaction folded delta into base" in out
    assert "post-compaction: target 0.90: mean recall" in out


def test_serve_main_hnsw(monkeypatch, capsys):
    out = _run_main(monkeypatch, capsys, [
        "--n", "900", "--dim", "16", "--queries", "24", "--learn", "128",
        "--engine", "hnsw", "--m", "8", "--ef", "32", "--slots", "8",
        "--targets", "0.8",
    ])
    assert "hnsw index built: 900 vecs" in out
    assert "DARTH fit" in out
    assert "steady-state: target 0.80: mean recall" in out


def test_serve_main_multi_host(monkeypatch, capsys):
    """--hosts N drives the per-host slot loops end-to-end (simulated
    multi-host on one process; every host must complete its stripe)."""
    out = _run_main(monkeypatch, capsys, [
        "--n", "900", "--dim", "16", "--queries", "24", "--learn", "128",
        "--nlist", "12", "--slots", "8", "--hosts", "2",
        "--targets", "0.8,0.9",
    ])
    assert "multi-host slot pool: 2 host loops x 4 slots" in out
    assert "steady-state: per-host completed 12/12" in out
    assert "steady-state: target 0.80: mean recall" in out


def test_serve_main_rejects_bad_targets(monkeypatch, capsys):
    with pytest.raises(ValueError, match=r"in \(0, 1\]"):
        _run_main(monkeypatch, capsys, [
            "--n", "600", "--dim", "8", "--queries", "8", "--learn", "64",
            "--nlist", "8", "--slots", "4", "--targets", "1.7",
        ])
