"""Cross-shard top-k merge: padding + id-masking invariants, and the
multi-shard numerics on a real (placeholder) 8-device mesh."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.dist import collectives
from repro.index import flat


def _mesh1():
    return jax.make_mesh((1,), ("model",))


def test_merge_topk_masks_padding_ids():
    # +inf candidates (shard padding) must come out as id -1, never a
    # padded row id.
    cand_d = jnp.asarray([[0.5, jnp.inf, 0.1, jnp.inf]], jnp.float32)
    cand_i = jnp.asarray([[7, 999, 3, 998]], jnp.int32)
    d, i = collectives.merge_topk(cand_d, cand_i, k=3)
    np.testing.assert_allclose(np.asarray(d[0, :2]), [0.1, 0.5])
    assert i[0, 0] == 3 and i[0, 1] == 7
    assert i[0, 2] == -1 and not np.isfinite(np.asarray(d[0, 2]))


def test_sharded_search_fewer_rows_than_k():
    # N < k: the tail slots must be (+inf, -1), matching flat.search.
    # (Goes through the flat.search_sharded convenience entry point.)
    mesh = _mesh1()
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    d, i = flat.search_sharded(q, x, 5, mesh)
    d_ref, i_ref = flat.search(q, x, 5)
    np.testing.assert_allclose(np.asarray(d)[:, :3],
                               np.asarray(d_ref)[:, :3], atol=1e-3)
    assert (np.asarray(i)[:, 3:] == -1).all()
    assert not np.isfinite(np.asarray(d)[:, 3:]).any()


def test_cached_search_keys_on_geometry_not_mesh_object():
    """Regression: the sharded-search cache used to key its lru_cache on
    the Mesh object, holding meshes (and through the jit cache, their
    device buffers) alive across tests. Keys must be (axis geometry, k)
    primitives, and equivalent meshes must share one compiled entry."""
    from jax.sharding import Mesh

    collectives._SEARCH_CACHE.clear()
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 4)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)

    mesh_a = Mesh(np.asarray(jax.devices()[:1]), ("model",))
    mesh_b = Mesh(np.asarray(jax.devices()[:1]), ("model",))
    d_a, _ = collectives.sharded_flat_search(q, x, 3, mesh_a)
    d_b, _ = collectives.sharded_flat_search(q, x, 3, mesh_b)
    np.testing.assert_array_equal(np.asarray(d_a), np.asarray(d_b))
    assert len(collectives._SEARCH_CACHE) == 1

    def flatten(obj):
        if isinstance(obj, tuple):
            for e in obj:
                yield from flatten(e)
        else:
            yield obj

    for key in collectives._SEARCH_CACHE:
        for leaf in flatten(key):
            assert isinstance(leaf, (str, int)), key
            assert not isinstance(leaf, Mesh)

    # a different k is a different entry, same bounded cache
    collectives.sharded_flat_search(q, x, 2, mesh_a)
    assert len(collectives._SEARCH_CACHE) == 2
    collectives._SEARCH_CACHE.clear()


def test_sharded_search_xla_fallback_matches():
    mesh = _mesh1()
    fn = collectives.make_sharded_flat_search(mesh, k=4, use_kernel=False)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(6, 12)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(97, 12)), jnp.float32)
    d, i = fn(q, x)
    d_ref, i_ref = flat.search(q, x, 4)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")
from repro.dist import collectives
from repro.index import flat

# N deliberately NOT divisible by the 8-way model axis: 1001 = 8*125 + 1,
# so 7 padded rows exist on the last shard and must never surface.
mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
n, d, b, k = 1001, 16, 32, 10
x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)

fn = collectives.make_sharded_flat_search(mesh, k)
ds, is_ = fn(q, x)
dr, ir = flat.search(q, x, k)

ids = np.asarray(is_)
ok_ids = bool(((ids >= 0) & (ids < n)).all())          # no padded-row ids
ok_d = bool(np.allclose(np.asarray(ds), np.asarray(dr), atol=1e-3))
ok_set = bool(np.mean(np.asarray(flat.recall_at_k(is_, ir))) > 0.999)
print(json.dumps({"ok_ids": ok_ids, "ok_d": ok_d, "ok_set": ok_set,
                  "ndev": jax.device_count()}))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_nondivisible_db_on_8_shards():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 8
    assert res["ok_ids"], res
    assert res["ok_d"], res
    assert res["ok_set"], res


def test_elastic_restore_from_mesh(tmp_path):
    """`restore(shardings=<Mesh>)` re-derives placement from the logical
    spec recorded at save time (degrading axes the new mesh lacks)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import ckpt

    mesh_a = jax.make_mesh((1, 1), ("data", "model"))
    w = jax.device_put(jnp.arange(32.0).reshape(4, 8),
                       NamedSharding(mesh_a, P("data", "model")))
    ckpt.save(str(tmp_path), 1, {"w": w})

    mesh_b = jax.make_mesh((1,), ("model",))
    like = {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
    restored, meta = ckpt.restore(str(tmp_path), like, shardings=mesh_b)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(w))
    assert restored["w"].sharding.mesh.axis_names == ("model",)
    assert meta["shardings"]["w"]["spec"] == ["data", "model"]
