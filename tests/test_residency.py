"""Compact index residency (PR 10): SQ8-resident serving, the hashed
visited filter, the f32 re-rank hook and the IVF cold bucket tier.

Three contracts:

  * **SQ8 + f32 re-rank recovers exact results.** The engine searches
    int8 codes at an over-provisioned k' = 4k; RerankStore re-scores
    the candidates in exact f32 and returns the final k. For IVF the
    probe order is centroid-driven (centroids stay f32), so the SQ8
    engine scans the SAME buckets as the f32 engine and the re-ranked
    ids must match the f32 search EXACTLY — on every shard count.
  * **Hashed visited filter costs bounded recall.** Replacing the
    [B, N] bitmap with a fixed-width filter introduces false-positive
    skips. The conformance sweep bounds the ceiling (plain-search
    recall) per width and asserts declared targets are met up to that
    ceiling, through the full DARTH fit + early-termination loop and
    through the multi-host slot-pool server.
  * **A cold bucket never stalls or lies.** Probes resolving to
    non-resident buckets are skipped with honest ndis accounting, and
    the boundary prefetcher (serve.cold) stages upcoming buckets ahead
    of their probe turn — on a drifted workload that recovers most of
    the recall a static popularity seed loses.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, engines
from repro.core.intervals import IntervalParams
from repro.index import flat, hnsw, ivf, residency
from repro.obs.metrics import MetricsRegistry
from repro.serve import DarthServer, make_cold_tier
from repro.serve.cold import split_index

K = 10
TARGETS = (0.80, 0.90, 0.95)
TOLERANCE = 0.03
#: hashed-filter widths for the n=8192 conformance dataset: N/4, N/16.
WIDTHS = (2048, 512)
#: minimum plain-search recall ceiling per width — the bounded cost of
#: false-positive skips (empirically 0.952 / 0.898 on this dataset).
CEILING_FLOOR = {2048: 0.94, 512: 0.85}


@pytest.fixture(scope="module")
def residency_ds():
    from repro.data import vectors
    # n a power-of-two multiple of the widths so WIDTHS are exactly
    # N/4 and N/16
    return vectors.make_dataset(n=8192, d=24, num_learn=512,
                                num_queries=128, clusters=32,
                                cluster_std=1.2, seed=0)


@pytest.fixture(scope="module")
def ground_truth(residency_ds):
    ds = residency_ds
    _, gt_i = flat.search(jnp.asarray(ds.queries), jnp.asarray(ds.base), K)
    return gt_i


def _recall(ids, gt_i):
    return float(np.mean(np.asarray(flat.recall_at_k(
        jnp.asarray(np.asarray(ids)), gt_i))))


# ---------------------------------------------------------------------------
# quantization + accounting primitives
# ---------------------------------------------------------------------------

def test_quantize_sq8_counts_clips():
    scale = np.full((4,), 0.1, np.float32)
    offset = np.zeros((4,), np.float32)
    x = np.zeros((8, 4), np.float32)
    x[0, 0] = 100.0      # far outside the ±12.7 representable range
    x[3, 2] = -50.0
    codes, deq, nclip = ivf.quantize_sq8(x, scale, offset)
    assert codes.dtype == np.int8
    assert nclip == 2
    assert codes[0, 0] == 127 and codes[3, 2] == -127
    # in-range values round-trip without clipping
    _, _, nclip0 = ivf.quantize_sq8(np.clip(x, -12.0, 12.0), scale, offset)
    assert nclip0 == 0


def test_quantize_views_and_resident_bytes(residency_ds):
    ds = residency_ds
    index = ivf.build(ds.base[:2048], nlist=16, seed=0)
    sq8 = residency.quantize_ivf(index)
    assert sq8.quantized and not index.quantized
    assert np.asarray(sq8.bucket_vecs).dtype == np.int8
    # dequantized sqnorms describe what the quantized search measures
    live = np.asarray(sq8.bucket_ids) >= 0
    deq = (np.asarray(sq8.bucket_vecs, np.float32)
           * np.asarray(sq8.scale) + np.asarray(sq8.offset))
    np.testing.assert_allclose(
        np.asarray(sq8.bucket_sqnorm)[live],
        (deq ** 2).sum(axis=2)[live], rtol=1e-5)
    fb = residency.resident_bytes(index)
    qb = residency.resident_bytes(sq8)
    assert fb["total"] / qb["total"] > 3.0      # d=24 payload ratio

    graph = hnsw.build(ds.base[:2048], m=8, passes=1, ef_construction=32)
    gq = residency.quantize_hnsw(graph)
    assert gq.quantized
    assert np.asarray(gq.vectors).dtype == np.int8
    gf = residency.resident_bytes(graph)
    gqb = residency.resident_bytes(gq)
    assert gf["total"] / gqb["total"] > 2.0     # adjacency stays i32


def test_hash_slot_bounds_and_spread():
    ids = jnp.arange(4096, dtype=jnp.int32)
    for width in (64, 512, 2048):
        slots = np.asarray(hnsw.hash_slot(ids, width))
        assert slots.min() >= 0 and slots.max() < width
        # multiplicative hashing must spread consecutive ids: every
        # slot of a quarter-full filter sees at most a small pile-up
        counts = np.bincount(slots, minlength=width)
        assert counts.max() <= 8 * (4096 // width + 1)


def test_rerank_store_pads_and_orders(residency_ds):
    ds = residency_ds
    store = residency.RerankStore(ds.base)
    q = np.asarray(ds.queries[0])
    ids = np.asarray([5, -1, 17, 9000000, 3], np.int64)  # pad + bogus
    d, i = store.rerank(q, ids, k=5)
    assert i[-2:].tolist() == [-1, -1] and np.isinf(d[-2:]).all()
    assert (np.diff(d[np.isfinite(d)]) >= 0).all()
    assert set(i[i >= 0].tolist()) <= {5, 17, 3}


def test_sq8_rerank_exact_id_parity_single_device(residency_ds,
                                                  ground_truth):
    """f32-exact final ids from the SQ8-resident index: the SQ8 engine
    at k'=4k scans the same centroid-ordered buckets as f32, and the
    exact re-rank restores the f32 top-k id-for-id."""
    ds = residency_ds
    index = ivf.build(ds.base, nlist=32, seed=0)
    sq8 = residency.quantize_ivf(index)
    q = jnp.asarray(ds.queries)
    _, i_f32, _ = ivf.search(index, q, k=K, nprobe=32)
    _, i_sq8, _ = ivf.search(sq8, q, k=4 * K, nprobe=32)
    rr = residency.RerankStore(ds.base).reranker(K)
    ids = np.stack([rr(np.asarray(ds.queries[j]), np.asarray(i_sq8[j]))[1]
                    for j in range(q.shape[0])])
    np.testing.assert_array_equal(ids, np.asarray(i_f32))
    assert _recall(ids, ground_truth) == _recall(i_f32, ground_truth)


# ---------------------------------------------------------------------------
# hashed-visited + SQ8 declared-recall conformance
# ---------------------------------------------------------------------------

def _fit_darth(ds, make_engine, engine):
    d = api.Darth(make_engine=make_engine, engine=engine)
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    return d


@pytest.fixture(scope="module")
def sq8_graph(residency_ds):
    return residency.quantize_hnsw(hnsw.build(
        residency_ds.base, m=16, passes=2, ef_construction=96))


@pytest.fixture(scope="module")
def sq8_ivf_darth(residency_ds):
    sq8 = residency.quantize_ivf(ivf.build(residency_ds.base, nlist=32,
                                           seed=0))
    return _fit_darth(
        residency_ds, lambda **kw: engines.ivf_engine(sq8, **kw),
        engines.ivf_engine(sq8, k=K, nprobe=32))


@pytest.mark.slow
@pytest.mark.parametrize("width", WIDTHS)
def test_hashed_visited_conformance(residency_ds, ground_truth,
                                    sq8_graph, width):
    """Declared targets through the SQ8 + hashed-visited HNSW engine.

    The filter's false-positive skips cap attainable recall below the
    exact bitmap's; the cap must stay above CEILING_FLOOR per width and
    every declared target must be met up to it (min(target, ceiling) -
    TOLERANCE), so a hashing or owner-resolution regression shows up as
    either a sunken ceiling or a missed reachable target."""
    ds = residency_ds
    d = _fit_darth(
        ds,
        lambda **kw: engines.hnsw_engine(sq8_graph, visited_width=width,
                                         **kw),
        engines.hnsw_engine(sq8_graph, k=K, ef=192, max_steps=400,
                            visited_width=width))
    q = jnp.asarray(ds.queries)
    _, _, plain = d.search_plain(q)
    ceiling = _recall(d.engine.topk_i(plain), ground_truth)
    assert ceiling >= CEILING_FLOOR[width], (width, ceiling)
    plain_ndis = float(np.asarray(plain.ndis).mean())
    for rt in TARGETS:
        _, ii, st = d.search(q, rt)
        rec = _recall(ii, ground_truth)
        assert rec >= min(rt, ceiling) - TOLERANCE, (width, rt, rec)
        assert float(np.asarray(st.inner.ndis).mean()) <= plain_ndis

@pytest.mark.slow
@pytest.mark.parametrize("hosts", [1, 2])
def test_sq8_serving_conformance_ivf(residency_ds, ground_truth,
                                     sq8_ivf_darth, hosts):
    """Declared targets served from the SQ8-resident IVF store (the
    default residency) through the slot-pool server, with the f32
    re-rank hook restoring exact final results — the shipped path."""
    ds = residency_ds
    d = sq8_ivf_darth
    n = ds.queries.shape[0]
    # the engine over-provisions (k' = 4k), the hook re-ranks to K
    eng = engines.ivf_engine(d.engine.index, k=4 * K, nprobe=32)
    server = DarthServer(eng, d.trained.predictor, d.interval_for_target,
                         num_slots=32, steps_per_sync=2, hosts=hosts,
                         rerank=residency.RerankStore(ds.base).reranker(K))
    for rt in TARGETS:
        results, stats = server.serve(ds.queries,
                                      np.full((n,), rt, np.float32))
        assert stats.completed == n, (hosts, rt, stats)
        ids = np.stack([r[1] for r in results])
        assert ids.shape == (n, K)
        rec = _recall(ids, ground_truth)
        assert rec >= rt - TOLERANCE, (hosts, rt, rec)


@pytest.mark.slow
@pytest.mark.parametrize("hosts", [1, 2])
def test_sq8_hashed_serving_conformance_hnsw(residency_ds, ground_truth,
                                             sq8_graph, hosts):
    """Declared targets served from the SQ8 + hashed-visited HNSW
    engine (width N/4) through the slot-pool server, bounded by the
    hashed ceiling exactly like the search-path conformance."""
    ds = residency_ds
    width = WIDTHS[0]
    d = _fit_darth(
        ds,
        lambda **kw: engines.hnsw_engine(sq8_graph, visited_width=width,
                                         **kw),
        engines.hnsw_engine(sq8_graph, k=K, ef=192, max_steps=400,
                            visited_width=width))
    q = jnp.asarray(ds.queries)
    _, _, plain = d.search_plain(q)
    ceiling = _recall(d.engine.topk_i(plain), ground_truth)
    n = ds.queries.shape[0]
    server = DarthServer(d.engine, d.trained.predictor,
                         d.interval_for_target, num_slots=32,
                         steps_per_sync=2, hosts=hosts)
    for rt in TARGETS:
        results, stats = server.serve(ds.queries,
                                      np.full((n,), rt, np.float32))
        assert stats.completed == n, (hosts, rt, stats)
        ids = np.stack([r[1] for r in results])
        rec = _recall(ids, ground_truth)
        assert rec >= min(rt, ceiling) - TOLERANCE, (hosts, rt, rec)


# ---------------------------------------------------------------------------
# shard-count invariance (subprocess: forced multi-device XLA)
# ---------------------------------------------------------------------------

_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

sys.path.insert(0, "src")
from repro import dist
from repro.data import vectors
from repro.index import hnsw, ivf, residency

K = 10
ds = vectors.make_dataset(n=2048, d=16, num_learn=64, num_queries=16,
                          clusters=16, cluster_std=1.0, seed=0)
q = jnp.asarray(ds.queries)
out = {"ndev": jax.device_count(), "ivf": [], "hnsw": []}

# IVF: SQ8 at k'=4K + f32 re-rank must equal the f32 engine's top-K
# ids on EVERY shard count (same centroid probe order, exact re-rank).
index = ivf.build(ds.base, nlist=16, seed=0)
sq8 = residency.quantize_ivf(index)
_, i_f32, _ = ivf.search(index, q, k=K, nprobe=16)
rr = residency.RerankStore(ds.base).reranker(K)
for nsh in (1, 2, 4):
    mesh = Mesh(np.asarray(jax.devices()[:nsh]), ("model",))
    placed = dist.place_index(sq8, mesh)
    _, i_sq8, _ = ivf.search_sharded(placed, q, k=4 * K, nprobe=16,
                                     mesh=mesh)
    ids = np.stack([rr(np.asarray(ds.queries[j]),
                       np.asarray(i_sq8[j]))[1]
                    for j in range(q.shape[0])])
    out["ivf"].append({"shards": nsh,
                       "ids_eq": bool(np.array_equal(
                           ids, np.asarray(i_f32)))})

# HNSW: the hashed visited filter must be bit-for-bit identical to the
# single-device reference on every shard count (slot ownership + the
# [B, M] seen-psum reconstruct the same global filter).
graph = residency.quantize_hnsw(hnsw.build(ds.base, m=8, passes=1,
                                           ef_construction=32, seed=0))
W = 512
d0, i0, s0 = hnsw.search(graph, q, k=K, ef=48, visited_width=W)
for nsh in (1, 2, 4):
    mesh = Mesh(np.asarray(jax.devices()[:nsh]), ("model",))
    placed = dist.place_index(graph, mesh)
    d1, i1, s1 = hnsw.search_sharded(placed, q, k=K, ef=48, mesh=mesh,
                                     visited_width=W)
    out["hnsw"].append({
        "shards": nsh,
        "ids_eq": bool(np.array_equal(np.asarray(i0), np.asarray(i1))),
        "ndis_eq": bool(np.array_equal(np.asarray(s0.ndis),
                                       np.asarray(s1.ndis))),
    })
print(json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_residency_parity_mesh_1_2_4():
    out = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 4
    assert [c["shards"] for c in res["ivf"]] == [1, 2, 4]
    for case in res["ivf"]:
        assert case["ids_eq"], case
    assert [c["shards"] for c in res["hnsw"]] == [1, 2, 4]
    for case in res["hnsw"]:
        assert case["ids_eq"] and case["ndis_eq"], case


# ---------------------------------------------------------------------------
# cold bucket tier
# ---------------------------------------------------------------------------

def _stub_predictor(feats):
    return jnp.zeros((feats.shape[0],), jnp.float32)


def _stub_intervals(rt):
    rt = np.atleast_1d(rt)
    return IntervalParams(ipi=np.full(rt.shape, 64.0, np.float32),
                          mpi=np.full(rt.shape, 8.0, np.float32))


@pytest.fixture(scope="module")
def cold_ds():
    from repro.data import vectors
    return vectors.make_dataset(n=2000, d=16, num_learn=64,
                                num_queries=64, clusters=32,
                                cluster_std=1.0, seed=0)


def test_split_index_and_skip_honesty(cold_ds):
    """A cold probe contributes nothing and lies about nothing: with
    only some buckets resident, a full sweep returns only hot-bucket
    ids and ndis counts exactly the hot rows scanned."""
    ds = cold_ds
    index = ivf.build(ds.base, nlist=16, seed=0)
    sizes = np.asarray(jax.device_get(index.bucket_sizes))
    hot = np.asarray([0, 3, 7, 11], np.int32)
    store = split_index(index, hot)
    assert store.bucket_vecs.shape[0] == 4
    hot_map = np.asarray(store.hot_map)
    assert (hot_map >= 0).sum() == 4

    bi = np.asarray(jax.device_get(index.bucket_ids))
    hot_ids = set(bi[hot][bi[hot] >= 0].tolist())
    q = jnp.asarray(ds.queries[:16])
    _, ii, st = ivf.search(store, q, k=5, nprobe=16)   # sweep all 16
    returned = set(np.asarray(ii)[np.asarray(ii) >= 0].tolist())
    assert returned <= hot_ids
    np.testing.assert_array_equal(
        np.asarray(st.ndis), np.full((16,), sizes[hot].sum(), np.int32))

    with pytest.raises(ValueError):
        split_index(index, np.asarray([1, 1], np.int32))
    with pytest.raises(ValueError):
        make_cold_tier(index, hot_slots=0)


def test_cold_tier_serve_completes_and_counts(cold_ds):
    """Serving over a cold-tiered store finishes every query, stages
    prefetches at boundaries and exports the darth_cold_* families."""
    ds = cold_ds
    index = ivf.build(ds.base, nlist=32, seed=0)
    mets = MetricsRegistry()
    tier = make_cold_tier(index, hot_slots=20, metrics=mets)
    server = DarthServer(
        engines.ivf_engine(tier.store, k=K, nprobe=12),
        _stub_predictor, _stub_intervals, num_slots=16,
        steps_per_sync=2)
    n = ds.queries.shape[0]
    results, stats = server.serve(ds.queries,
                                  np.full((n,), 0.9, np.float32),
                                  on_boundary=tier.on_boundary)
    assert stats.completed == n
    assert all(r is not None for r in results)
    assert tier.prefetches > 0
    assert tier.evictions > 0
    assert mets.counter("darth_cold_prefetch_total").value() == \
        tier.prefetches
    page = mets.to_prometheus()
    for fam in ("darth_cold_prefetch_total", "darth_cold_evictions_total",
                "darth_cold_miss_total"):
        assert fam in page


def test_cold_tier_plan_seeds_first_probes(cold_ds):
    """plan() closes the first-probe window: after re-seeding from the
    workload, every query's first probes resolve hot."""
    ds = cold_ds
    index = ivf.build(ds.base, nlist=32, seed=0)
    tier = make_cold_tier(index, hot_slots=24)
    store = tier.plan(ds.queries, nprobe=12, first=2)
    q = jnp.asarray(ds.queries)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)
    order, _ = ivf.rank_centroids(index.centroids, q, qsq, 2)
    first = np.asarray(order)
    hot_map = np.asarray(store.hot_map)
    covered = hot_map[first.reshape(-1)] >= 0
    # 24 slots, 64 queries x 2 early probes: demand-ranked seeding must
    # cover the overwhelming majority (every miss is a skipped probe)
    assert covered.mean() > 0.9, covered.mean()


@pytest.mark.slow
def test_cold_tier_prefetch_recovers_drifted_recall(cold_ds):
    """The shipped drift recipe recovers recall on queries aimed at
    LOW-popularity buckets (exactly what the static popularity seed
    leaves cold). The two mechanisms split the probe timeline the way
    serve/cold.py documents: plan() seeds the first-probe window (which
    runs before any boundary can see a slot's schedule — a cold bucket
    there is skipped for good), and the on_boundary prefetcher stages
    later probes ahead of the cursor. Each layer must earn its keep:
    plan over static, plan+prefetch over plan alone.
    (Calibrated deterministic recalls on this seed: static 0.25,
    boundary-only 0.26, plan-only 0.87, plan+prefetch 0.96.)"""
    ds = cold_ds
    index = residency.quantize_ivf(ivf.build(ds.base, nlist=64, seed=0))
    d = _fit_darth(ds, lambda **kw: engines.ivf_engine(index, **kw),
                   engines.ivf_engine(index, k=K, nprobe=12))
    q = jnp.asarray(ds.queries)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)
    order, _ = ivf.rank_centroids(index.centroids, q, qsq, 1)
    first = np.asarray(order)[:, 0]
    sizes = np.asarray(jax.device_get(index.bucket_sizes))
    lowpop = set(np.argsort(-sizes, kind="stable")[40:].tolist())
    sel = np.asarray([i for i in range(len(first))
                      if int(first[i]) in lowpop])
    assert sel.size >= 8, sel.size           # drifted slice is real
    qd = ds.queries[sel]
    _, gt_i = flat.search(jnp.asarray(qd), jnp.asarray(ds.base), K)
    rts = np.full((sel.size,), 0.9, np.float32)

    def run(plan, prefetch):
        tier = make_cold_tier(index, hot_slots=40)
        store = tier.plan(qd, nprobe=12, first=2) if plan else tier.store
        server = DarthServer(
            engines.ivf_engine(store, k=K, nprobe=12),
            d.trained.predictor, d.interval_for_target,
            num_slots=16, steps_per_sync=2)
        res, stats = server.serve(
            qd, rts, on_boundary=tier.on_boundary if prefetch else None)
        assert stats.completed == sel.size
        ids = np.stack([r[1] for r in res])
        return _recall(ids, gt_i), tier

    rec_static, _ = run(False, False)
    rec_plan, _ = run(True, False)
    rec_full, tier = run(True, True)
    assert tier.prefetches > 0
    assert rec_plan >= rec_static + 0.3, (rec_static, rec_plan)
    assert rec_full >= rec_plan + 0.05, (rec_plan, rec_full)
    # the recovered path meets the declared target within tolerance
    assert rec_full >= 0.9 - TOLERANCE, rec_full


# ---------------------------------------------------------------------------
# drift-burst clip accounting (satellite: darth_sq8_clipped_total)
# ---------------------------------------------------------------------------

def test_compaction_drift_burst_counts_clips(cold_ds):
    """An OOD delta folded into a frozen-range SQ8 index clamps codes
    and must SAY so: darth_sq8_clipped_total advances by the clip count
    and the folded store stays within the int8 code range."""
    from repro.mutate import compact

    ds = cold_ds
    index = residency.quantize_ivf(ivf.build(ds.base, nlist=16, seed=0))
    rng = np.random.default_rng(7)
    # drift burst: vectors far outside the frozen base range
    delta = rng.normal(loc=50.0, size=(64, index.dim)).astype(np.float32)
    delta_ids = np.arange(10_000, 10_064, dtype=np.int32)
    expect_clip = ivf.quantize_sq8(delta, np.asarray(index.scale),
                                   np.asarray(index.offset))[2]
    assert expect_clip > 0

    mets = MetricsRegistry()
    steps = compact.compact_ivf_steps(index, delta_ids, delta,
                                      metrics=mets)
    folded = None
    try:
        while True:
            next(steps)
    except StopIteration as stop:
        folded = stop.value
    assert folded is not None
    assert mets.counter("darth_sq8_clipped_total").value() == expect_clip
    codes = np.asarray(folded.bucket_vecs)
    assert codes.dtype == np.int8
    assert codes.max() <= 127 and codes.min() >= -127
    # the clamped rows are still present and searchable
    fi = np.asarray(folded.bucket_ids)
    assert set(delta_ids.tolist()) <= set(fi[fi >= 0].tolist())
