"""Per-arch smoke tests (deliverable f) + model-layer correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import layers, linear_attn, model_zoo
from tests.conftest import small_config


def _batch_for(cfg, b, s, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.frontend_dim)),
            jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.frontend_dim)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_arch_smoke_forward_and_decode(arch):
    """Assignment requirement: reduced config, one forward/train step on
    CPU, assert output shapes + no NaNs; plus one decode step."""
    cfg = small_config(configs.get_config(arch))
    rng = np.random.default_rng(0)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch_for(cfg, b, s, rng)
    loss, metrics = model_zoo.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)), arch
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5, arch

    cache = model_zoo.make_cache(cfg, b, s)
    logits, cache2 = model_zoo.decode_step(
        cfg, params, cache, batch["tokens"][:, :1], jnp.asarray(3, jnp.int32))
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch


@pytest.mark.parametrize("arch", ["smollm-360m", "olmo-1b"])
def test_train_step_reduces_loss(arch):
    from repro.train import step as step_lib
    cfg = small_config(configs.get_config(arch))
    init_opt, train_step = step_lib.make_train_step(cfg, peak_lr=3e-3,
                                                    warmup_steps=2,
                                                    total_steps=50)
    train_step = jax.jit(train_step)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt(params)
    rng = np.random.default_rng(0)
    # small vocab + repeated batch -> loss must fall fast
    batch = _batch_for(cfg, 4, 32, rng)
    losses = []
    for _ in range(8):
        params, opt_state, m = train_step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_prefill_decode_consistency():
    """Greedy-decode logits from the cache path must match the full
    forward at the same position (dense family)."""
    cfg = small_config(configs.get_config("glm4-9b"))
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    # full forward logits at position s-1
    x, _, _ = model_zoo.forward(cfg, params, {"tokens": toks}, remat=False)
    table = params["embed"] if cfg.tie_embeddings else params["out_head"]
    full_logits = np.asarray(
        jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                   table.astype(jnp.float32)))

    # decode path: feed tokens one by one
    cache = model_zoo.make_cache(cfg, b, s + 4)
    logits = None
    for t in range(s):
        logits, cache = model_zoo.decode_step(
            cfg, params, cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), full_logits,
                               atol=0.15, rtol=0.05)
    top_full = np.argsort(full_logits, 1)[:, -3:]
    top_dec = np.argsort(np.asarray(logits), 1)[:, -3:]
    assert (top_full[:, -1] == top_dec[:, -1]).all()


def test_rwkv_chunked_vs_step_equivalence():
    """Chunkwise parallel linear attention == sequential recurrence."""
    rng = np.random.default_rng(0)
    b, t, h, dk, dv = 2, 32, 3, 8, 8
    q = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dv)), jnp.float32)
    lw = jnp.asarray(-np.abs(rng.normal(size=(b, t, h, dk))) * 0.1,
                     jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, dk)), jnp.float32)

    y_par, s_par = linear_attn.chunked_linear_attention(q, k, v, lw, u=u,
                                                        chunk=8)
    s = jnp.zeros((b, h, dk, dv))
    ys = []
    for i in range(t):
        y, s = linear_attn.linear_attention_step(
            q[:, i], k[:, i], v[:, i], lw[:, i], s, u=u)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_par), np.asarray(s), atol=1e-4)


def test_mamba_chunked_vs_step_equivalence():
    rng = np.random.default_rng(1)
    b, t, h, dk, dv = 2, 24, 2, 4, 8
    q = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dv)), jnp.float32)
    lw = jnp.asarray(-np.abs(rng.normal(size=(b, t, h, dk))) * 0.2,
                     jnp.float32)
    y_par, s_par = linear_attn.chunked_linear_attention(q, k, v, lw, chunk=6)
    s = jnp.zeros((b, h, dk, dv))
    ys = []
    for i in range(t):
        y, s = linear_attn.linear_attention_step(
            q[:, i], k[:, i], v[:, i], lw[:, i], s)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-4)


def test_flash_attention_grads_match_naive():
    rng = np.random.default_rng(0)
    b, s, h, d = 2, 40, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)

    def naive(q, k, v):
        qf = q.transpose(0, 2, 1, 3) / np.sqrt(d)
        kf = k.transpose(0, 2, 1, 3)
        vf = v.transpose(0, 2, 1, 3)
        sc = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
        mask = jnp.asarray(np.triu(np.ones((s, s)), 1) > 0)
        sc = jnp.where(mask[None, None], -jnp.inf, sc)
        return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(sc, -1),
                          vf).transpose(0, 2, 1, 3)

    g1 = jax.grad(lambda *a: jnp.sum(jnp.sin(
        layers.flash_attention(*a, True, 0, 16))), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(jnp.sin(naive(*a))),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


def test_chunked_ce_matches_direct():
    rng = np.random.default_rng(0)
    b, s, d, v = 2, 24, 16, 50
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    chunked = float(model_zoo.chunked_ce_loss(x, table, labels, chunk=7))
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    direct = float(layers.cross_entropy(logits, labels))
    assert abs(chunked - direct) < 1e-4


def test_param_counts_sane():
    """Full configs: parameter counts are in the right ballpark."""
    expected = {
        "smollm-360m": (0.25e9, 0.6e9),
        "olmo-1b": (1.0e9, 1.5e9),
        "starcoder2-3b": (2.5e9, 3.6e9),
        "glm4-9b": (8e9, 11e9),
        "rwkv6-3b": (2.5e9, 4.5e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
    }
    for arch, (lo, hi) in expected.items():
        cfg = configs.get_config(arch)
        shapes = model_zoo.param_shapes(cfg)
        n = sum(int(np.prod(s)) for s in jax.tree.leaves(
            shapes, is_leaf=lambda x: isinstance(x, tuple)))
        assert lo <= n <= hi, (arch, n)
