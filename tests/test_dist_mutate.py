"""Mutable engine over the SHARDED engines: numeric parity with the
single-device mutable path (topk_d / topk_i / ndis / ninserts) after an
insert/delete burst AND after compaction — on the 1-device mesh
in-process, and on real (placeholder) {1, 2, 4}-shard meshes in a
subprocess. The delta tier is replicated; tombstones travel row-sharded
inside the base arrays (pad convention), so the sharded steps need no
mutation-specific code at all."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import dist, mutate
from repro.core import darth_search, engines
from repro.data import vectors
from repro.index import ivf


def _mesh1():
    return jax.make_mesh((1,), ("model",))


@pytest.fixture(scope="module")
def mutated_ivf():
    ds = vectors.make_dataset(n=2000, d=16, num_learn=64, num_queries=32,
                              clusters=16, cluster_std=1.0, seed=0)
    index = ivf.build(ds.base, nlist=16, seed=0)
    mut = mutate.MutableIndex(index, capacity=512)
    mut.apply(vectors.mutation_stream(ds, insert_pct=0.2, delete_pct=0.1,
                                      drift=0.3, steps=4, seed=3))
    return ds, mut


def test_sharded_mutable_matches_single_device(mutated_ivf):
    ds, mut = mutated_ivf
    mesh = _mesh1()
    q = jnp.asarray(ds.queries[:16])
    ref = engines.mutable_engine(
        engines.ivf_engine(mut.base, k=5, nprobe=8), mut.delta)
    view = dist.place_index(mut.view(), mesh)
    sh = engines.mutable_engine(
        engines.sharded_ivf_engine(view.base, mesh, k=5, nprobe=8),
        view.delta)
    assert sh.name == "ivf-sharded+delta"
    ws0 = darth_search.plain_search(ref, q)
    ws1 = darth_search.plain_search(sh, q)
    np.testing.assert_allclose(np.asarray(ref.topk_d(ws0)),
                               np.asarray(sh.topk_d(ws1)), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ref.topk_i(ws0)),
                                  np.asarray(sh.topk_i(ws1)))
    np.testing.assert_array_equal(np.asarray(ws0.ndis), np.asarray(ws1.ndis))
    np.testing.assert_array_equal(np.asarray(ws0.ninserts),
                                  np.asarray(ws1.ninserts))


def test_place_index_replicates_delta(mutated_ivf):
    ds, mut = mutated_ivf
    mesh = _mesh1()
    view = dist.place_index(mut.view(), mesh)
    for leaf in jax.tree.leaves(view.delta):
        assert leaf.sharding.is_fully_replicated
    # contents untouched by placement
    np.testing.assert_array_equal(np.asarray(view.delta.ids),
                                  np.asarray(mut.delta.ids))


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

sys.path.insert(0, "src")
from repro import dist, mutate
from repro.core import darth_search, engines
from repro.data import vectors
from repro.index import hnsw, ivf

ds = vectors.make_dataset(n=1501, d=16, num_learn=64, num_queries=32,
                          clusters=12, cluster_std=1.0, seed=0)
q = jnp.asarray(ds.queries[:16])
events = vectors.mutation_stream(ds, insert_pct=0.2, delete_pct=0.1,
                                 drift=0.3, steps=4, seed=3)

out = {"ndev": jax.device_count(), "cases": []}
for kind in ("ivf", "hnsw"):
    if kind == "ivf":
        base = ivf.build(ds.base, nlist=16, seed=0, cap_round=1)
        mk = lambda idx: engines.ivf_engine(idx, k=5, nprobe=8)
        mk_sh = lambda idx, mesh: engines.sharded_ivf_engine(
            idx, mesh, k=5, nprobe=8)
    else:
        base = hnsw.build(ds.base, m=8, passes=1, ef_construction=32, seed=0)
        mk = lambda idx: engines.hnsw_engine(idx, k=5, ef=24)
        mk_sh = lambda idx, mesh: engines.sharded_hnsw_engine(
            idx, mesh, k=5, ef=24)
    mut = mutate.MutableIndex(base, capacity=512)
    mut.apply(events)
    for phase in ("burst", "compacted"):
        if phase == "compacted":
            mut.compact(seed=1)
        ref = engines.mutable_engine(mk(mut.base), mut.delta)
        ws0 = darth_search.plain_search(ref, q)
        d0 = np.asarray(ref.topk_d(ws0)); i0 = np.asarray(ref.topk_i(ws0))
        nd0 = np.asarray(ws0.ndis); ni0 = np.asarray(ws0.ninserts)
        for nsh in (1, 2, 4):
            mesh = Mesh(np.asarray(jax.devices()[:nsh]), ("model",))
            view = dist.place_index(mut.view(), mesh)
            sh = engines.mutable_engine(mk_sh(view.base, mesh), view.delta)
            ws1 = darth_search.plain_search(sh, q)
            out["cases"].append({
                "kind": kind, "phase": phase, "shards": nsh,
                "delta_rep": bool(all(
                    l.sharding.is_fully_replicated
                    for l in jax.tree.leaves(view.delta))),
                "d_ok": bool(np.allclose(d0, np.asarray(sh.topk_d(ws1)),
                                         atol=1e-4)),
                "i_ok": bool(np.array_equal(i0,
                                            np.asarray(sh.topk_i(ws1)))),
                "ndis_ok": bool(np.array_equal(nd0, np.asarray(ws1.ndis))),
                "nins_ok": bool(np.array_equal(ni0,
                                               np.asarray(ws1.ninserts))),
            })
print(json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_mutable_parity_mesh_1_2_4():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 4
    assert len(res["cases"]) == 2 * 2 * 3   # {ivf,hnsw} x {burst,compacted}
    for case in res["cases"]:
        for key in ("delta_rep", "d_ok", "i_ok", "ndis_ok", "nins_ok"):
            assert case[key], case
