import numpy as np
import jax.numpy as jnp

from repro.index import flat, hnsw, ivf


def test_flat_exact(clustered_vectors):
    ds = clustered_vectors
    q = jnp.asarray(ds.queries[:16])
    x = jnp.asarray(ds.base)
    d, i = flat.search(q, x, 5)
    # brute force check on a few rows
    for r in range(4):
        full = ((ds.base - ds.queries[r]) ** 2).sum(1)
        order = np.argsort(full)[:5]
        np.testing.assert_allclose(np.asarray(d)[r], np.sort(full)[:5],
                                   rtol=1e-4)
        assert set(np.asarray(i)[r].tolist()) == set(order.tolist())


def test_recall_at_k():
    found = jnp.asarray([[1, 2, 3], [4, 5, -1]])
    true = jnp.asarray([[3, 2, 9], [7, 8, 9]])
    r = np.asarray(flat.recall_at_k(found, true))
    np.testing.assert_allclose(r, [2 / 3, 0.0])


def test_ivf_recall_and_counters(clustered_vectors):
    ds = clustered_vectors
    index = ivf.build(ds.base, nlist=32, seed=0)
    assert index.num_vectors == ds.base.shape[0]
    q = jnp.asarray(ds.queries[:64])
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    d, i, s = ivf.search(index, q, k=10, nprobe=8)
    rec = float(flat.recall_at_k(i, gt_i).mean())
    assert rec > 0.9, rec
    # counters: ndis equals the sum of probed bucket sizes
    sizes = np.asarray(index.bucket_sizes)
    order = np.asarray(s.probe_order)[:, :8]
    expect = sizes[order].sum(axis=1)
    np.testing.assert_array_equal(np.asarray(s.ndis), expect)
    # exhaustive probe = exact
    d2, i2, _ = ivf.search(index, q, k=10, nprobe=32)
    assert float(flat.recall_at_k(i2, gt_i).mean()) == 1.0


def test_hnsw_recall(clustered_vectors):
    ds = clustered_vectors
    index = hnsw.build(ds.base, m=12, passes=1, ef_construction=48)
    q = jnp.asarray(ds.queries[:64])
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    d, i, s = hnsw.search(index, q, k=10, ef=96)
    rec = float(flat.recall_at_k(i, gt_i).mean())
    assert rec > 0.85, rec
    nd = np.asarray(s.ndis)
    assert (nd > 0).all() and (nd < ds.base.shape[0]).all()
    # frontier sorted ascending
    cd = np.asarray(s.cand_d)
    assert (np.diff(cd, axis=1) >= -1e-5).all()
    # ndis accounting: the routing scan really computes R distances per
    # query, so ndis starts at R (not 1) — the same scale the fit-time
    # logs see — and each beam step adds only NEW computations, so the
    # final count is exactly R + (#visited nodes beyond the entry).
    r = int(index.route_ids.shape[0])
    s0 = hnsw.init_state(index, q, ef=96)
    np.testing.assert_array_equal(np.asarray(s0.ndis),
                                  np.full(q.shape[0], r, np.int32))
    nvisited = np.asarray(s.visited).sum(axis=1)
    np.testing.assert_array_equal(nd, r + nvisited - 1)


def test_hnsw_batch_equals_single(clustered_vectors):
    ds = clustered_vectors
    index = hnsw.build(ds.base[:2000], m=8, passes=1)
    q = jnp.asarray(ds.queries[:8])
    d_b, i_b, _ = hnsw.search(index, q, k=5, ef=32)
    for r in range(4):
        d_s, i_s, _ = hnsw.search(index, q[r:r + 1], k=5, ef=32)
        np.testing.assert_array_equal(np.asarray(i_b)[r], np.asarray(i_s)[0])


def test_ivf_sq8_quantized(clustered_vectors):
    """SQ8 storage: 4x less memory, recall within a few points of f32, and
    DARTH composes unchanged (same engine protocol)."""
    ds = clustered_vectors
    idx_f = ivf.build(ds.base, nlist=32, seed=0)
    idx_q = ivf.build(ds.base, nlist=32, seed=0, quantize=True)
    assert idx_q.quantized and not idx_f.quantized
    assert idx_q.bucket_vecs.dtype == jnp.int8

    q = jnp.asarray(ds.queries[:64])
    gt_d, gt_i = flat.search(q, jnp.asarray(ds.base), 10)
    _, i_f, _ = ivf.search(idx_f, q, k=10, nprobe=8)
    _, i_q, _ = ivf.search(idx_q, q, k=10, nprobe=8)
    rec_f = float(flat.recall_at_k(i_f, gt_i).mean())
    rec_q = float(flat.recall_at_k(i_q, gt_i).mean())
    assert rec_q > rec_f - 0.05, (rec_f, rec_q)

    # DARTH over the quantized engine still meets a target
    from repro.core import api, engines
    d = api.Darth(
        make_engine=lambda **kw: engines.ivf_engine(idx_q, **kw),
        engine=engines.ivf_engine(idx_q, k=10, nprobe=32))
    d.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), batch=256)
    _, ii, st = d.search(q, 0.9)
    rec = float(flat.recall_at_k(ii, gt_i).mean())
    assert rec >= 0.85, rec


def test_pool_prune_matches_legacy_inline_block():
    """Exact parity for the extracted candidate-pool sort/prune helper:
    the same sort+mask+RobustPrune sequence that build() and
    insert_nodes carried as duplicated inline copies, replayed here
    verbatim, must match hnsw._pool_prune bit-for-bit — including self
    hits, -1 pads, and all-invalid rows."""
    import jax.numpy as jnp2

    rng = np.random.default_rng(7)
    n, b, c, m = 200, 16, 24, 8
    x = rng.normal(size=(n, 8)).astype(np.float32)
    owners = rng.choice(n, size=b, replace=False).astype(np.int64)
    cand_i = rng.integers(-1, n, size=(b, c)).astype(np.int32)
    cand_i[:, 0] = owners                    # guaranteed self-hits
    cand_i[0] = -1                           # an all-invalid row
    cand_d = ((x[np.maximum(cand_i, 0)]
               - x[owners, None, :]) ** 2).sum(2).astype(np.float32)

    # the legacy inline block, verbatim
    cd = np.where((cand_i == owners[:, None]) | (cand_i < 0), np.inf,
                  cand_d)
    ord_ = np.argsort(cd, axis=1, kind="stable")
    ci_s = np.where(np.take_along_axis(cd, ord_, 1) < np.inf,
                    np.take_along_axis(cand_i, ord_, 1), -1)
    cd_s = np.take_along_axis(cd, ord_, axis=1)
    pd = hnsw._pairwise_sq(jnp2.asarray(x[np.maximum(ci_s, 0)]))
    legacy = np.asarray(hnsw._robust_prune(
        jnp2.asarray(ci_s), jnp2.asarray(cd_s), pd, m, 1.2 ** 2))

    got = hnsw._pool_prune(x, owners, cand_d, cand_i, m, 1.2 ** 2)
    np.testing.assert_array_equal(got, legacy)
    assert (got[0] == -1).all()              # all-invalid row -> all pad


def test_hnsw_build_deterministic_after_prune_refactor(clustered_vectors):
    """Built graphs are a pure function of (data, params, seed): two
    builds through the shared _pool_prune path are identical, and the
    streaming insert path lands every new node with forward edges."""
    x = clustered_vectors.base[:1500]
    g1 = hnsw.build(x, m=8, passes=1, ef_construction=32, seed=0)
    g2 = hnsw.build(x, m=8, passes=1, ef_construction=32, seed=0)
    np.testing.assert_array_equal(np.asarray(g1.neighbors),
                                  np.asarray(g2.neighbors))
    np.testing.assert_array_equal(np.asarray(g1.route_ids),
                                  np.asarray(g2.route_ids))

    # streaming insert: grow the arrays (the caller's job — compaction
    # does the same), then link the new rows through the shared helper
    import dataclasses
    new = clustered_vectors.base[1500:1600]
    grown = dataclasses.replace(
        g1,
        vectors=jnp.concatenate([g1.vectors, jnp.asarray(new)]),
        sqnorm=jnp.concatenate([g1.sqnorm,
                                jnp.asarray((new ** 2).sum(1))]),
        neighbors=jnp.concatenate(
            [g1.neighbors,
             jnp.full((100, g1.degree), -1, jnp.int32)]))
    linked = hnsw.insert_nodes(grown, np.arange(1500, 1600),
                               ef_construction=32)
    nbr = np.asarray(linked.neighbors)
    assert nbr.shape[0] == 1600
    assert (nbr[1500:1600] >= 0).any(axis=1).all()
