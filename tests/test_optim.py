import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, adafactor_init, adafactor_update,
                         adamw_init, adamw_update, grad_compress)


def _quad_problem():
    target = {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]]),
              "b": jnp.asarray([0.1, -0.3])}

    def loss(p):
        return (jnp.sum((p["w"] - target["w"]) ** 2)
                + jnp.sum((p["b"] - target["b"]) ** 2))
    p0 = jax.tree.map(jnp.zeros_like, target)
    return loss, p0


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_minimize_quadratic(opt):
    loss, p = _quad_problem()
    if opt == "adamw":
        state = adamw_init(p)
        update = adamw_update
    else:
        state = adafactor_init(p)
        update = adafactor_update
    l0 = float(loss(p))
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, state = update(g, state, p, jnp.asarray(0.05))
    assert float(loss(p)) < 0.05 * l0


def test_adamw_weight_decay_shrinks():
    p = {"w": jnp.ones((4,)) * 10.0}
    cfg = AdamWConfig(weight_decay=0.1)
    state = adamw_init(p, cfg)
    g = {"w": jnp.zeros((4,))}
    p2, _ = adamw_update(g, state, p, jnp.asarray(0.1), cfg)
    assert float(p2["w"][0]) < 10.0


@settings(deadline=None, max_examples=20)
@given(n=st.integers(5, 2000), scale=st.floats(1e-4, 1e3))
def test_int8_compression_error_bounded(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    y = grad_compress.compress_roundtrip(x)
    # blockwise int8: error per element <= blockmax/127 (half-step rounding)
    err = np.abs(np.asarray(x - y))
    blocks = np.asarray(jnp.pad(x, (0, (-n) % grad_compress.BLOCK))
                        ).reshape(-1, grad_compress.BLOCK)
    bound = np.repeat(np.abs(blocks).max(1) / 127.0,
                      grad_compress.BLOCK)[:n] * 0.5 + 1e-9
    assert (err <= bound + 1e-6).all()


def test_error_feedback_telescopes():
    """sum of sent values + final error == sum of true grads (per element):
    the compression never loses mass over time."""
    rng = np.random.default_rng(0)
    true = [jnp.asarray(rng.normal(size=(300,)), jnp.float32)
            for _ in range(10)]
    e = jnp.zeros((300,))
    sent_total = jnp.zeros((300,))
    for g in true:
        gf = g + e
        sent = grad_compress.compress_roundtrip(gf)
        e = gf - sent
        sent_total = sent_total + sent
    total_true = sum(true)
    np.testing.assert_allclose(np.asarray(sent_total + e),
                               np.asarray(total_true), atol=1e-4)


def test_compressed_train_step_runs():
    from repro import configs
    from repro.models import model_zoo
    from repro.train import step as step_lib
    from tests.conftest import small_config
    cfg = small_config(configs.get_config("olmo-1b"))
    init_opt, train_step = step_lib.make_train_step(cfg, compress_grads=True)
    params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32)}
    params, opt_state, m = jax.jit(train_step)(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    assert "ef" in opt_state
