"""Fused squared-L2 distance + running top-k Pallas TPU kernel.

This is DARTH's compute hot spot: >95% of search FLOPs are q·X^T distance
tiles (IVF probes, HNSW beam expansions, flat ground-truth scans).

Design (TPU-native, see DESIGN.md §6):
  * ranking identity ||q-x||^2 = ||q||^2 + ||x||^2 - 2 q.x — the ||q||^2
    term is rank-invariant, added back by the wrapper;
  * grid (query tiles [parallel], db tiles [arbitrary/sequential]); the db
    axis walks sequentially and accumulates a running per-row top-k in the
    *output* block (revisited across the db axis), so the B×N distance
    matrix never exists in HBM;
  * the MXU does `q_tile @ x_tile.T` (f32 accumulate); the top-k merge is a
    K-step masked-min extraction over [bq, K + bn] — O(K·(K+bn)) VPU work
    per tile, amortized against 2·D·bn MXU flops per row;
  * BlockSpecs keep q (bq×D), x (bn×D), running top-k (bq×K) in VMEM:
    128·1024·4 + 512·1024·4 + small ≈ 2.6 MB at D=1024.

Padding contract (enforced by ops.l2_topk): B % bq == 0, N % bn == 0,
padded db rows carry x_sqnorm=+inf so they never enter the top-k.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

_NEG_INF = float("-inf")


def _l2_topk_kernel(q_ref, x_ref, xsq_ref, outd_ref, outi_ref, *, k: int, bn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # [bq, D]
    x = x_ref[...].astype(jnp.float32)            # [bn, D]
    xsq = xsq_ref[...].astype(jnp.float32)        # [1, bn]

    # MXU: [bq, bn] partial distances (missing rank-invariant ||q||^2).
    dots = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    tile_d = xsq - 2.0 * dots                     # [bq, bn]
    base = j * bn
    tile_i = base + jax.lax.broadcasted_iota(jnp.int32, tile_d.shape, 1)

    run_d = outd_ref[...]                         # [bq, k]
    run_i = outi_ref[...]

    # Merge: K-step masked-min extraction over the concatenated candidates.
    cand_d = jnp.concatenate([run_d, tile_d], axis=1)     # [bq, k+bn]
    cand_i = jnp.concatenate([run_i, tile_i], axis=1)
    width = cand_d.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, cand_d.shape, 1)
    new_d = jnp.zeros_like(run_d)
    new_i = jnp.zeros_like(run_i)
    out_col = jax.lax.broadcasted_iota(jnp.int32, run_d.shape, 1)

    def body(t, carry):
        cand_d, cand_i, new_d, new_i = carry
        m = jnp.min(cand_d, axis=1)                        # [bq]
        am = jnp.argmin(cand_d, axis=1).astype(jnp.int32)  # [bq]
        sel = col == am[:, None]                           # [bq, k+bn]
        mi = jnp.sum(jnp.where(sel, cand_i, 0), axis=1)    # [bq]
        write = out_col == t
        new_d = jnp.where(write, m[:, None], new_d)
        new_i = jnp.where(write, mi[:, None], new_i)
        cand_d = jnp.where(sel, jnp.inf, cand_d)
        return cand_d, cand_i, new_d, new_i

    _, _, new_d, new_i = jax.lax.fori_loop(
        0, k, body, (cand_d, cand_i, new_d, new_i))
    outd_ref[...] = new_d
    outi_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret"))
def l2_topk_padded(q: jax.Array, x: jax.Array, x_sqnorm: jax.Array, *,
                   k: int, bq: int = 128, bn: int = 512,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Pre-padded fused distance+topk. See ops.l2_topk for the public API.

    q: [B, D] (B % bq == 0), x: [N, D] (N % bn == 0), x_sqnorm: [N].
    Returns (dist [B, k] ascending — WITHOUT the ||q||^2 term, idx [B, k]).
    """
    b, d = q.shape
    n = x.shape[0]
    assert b % bq == 0 and n % bn == 0, (b, bq, n, bn)
    grid = (b // bq, n // bn)
    xsq2d = x_sqnorm.reshape(1, n)

    kernel = functools.partial(_l2_topk_kernel, k=k, bn=bn)
    outd, outi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, x, xsq2d)
    return outd, outi
