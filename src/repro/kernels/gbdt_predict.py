"""Pallas TPU kernel for GBDT ensemble inference (DARTH's recall predictor).

The whole ensemble lives in VMEM (100 trees x 63 internal nodes x
(feat,thr) + 64 leaves ~= 75 KB), the batch is tiled over the grid, and the
root-to-leaf descent is *gather-free*: node positions are resolved with
level-local one-hot contractions (level d has only 2^d nodes, so the
one-hot work is tiny at the top and bounded by the leaf level).

Why a kernel at all: the paper's constraint (§3.2) is that predictor
invocation cost must not cancel early-termination savings. Keeping the
ensemble VMEM-resident and fusing the descent means one invocation for a
whole active batch costs less than a single IVF bucket probe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS


def _gbdt_kernel(x_ref, feat_ref, thr_ref, leaf_ref, out_ref, *,
                 depth: int, num_feat: int):
    x = x_ref[...].astype(jnp.float32)       # [bq, F]
    feat = feat_ref[...]                     # [T, NI] int32
    thr = thr_ref[...]                       # [T, NI] f32
    leaf = leaf_ref[...]                     # [T, NL] f32
    bq = x.shape[0]
    t = feat.shape[0]

    node = jnp.zeros((bq, t), jnp.int32)     # level-local position
    for d in range(depth):
        lo = 2**d - 1
        width = 2**d
        feat_d = jax.lax.slice(feat, (0, lo), (t, lo + width))   # [T, w]
        thr_d = jax.lax.slice(thr, (0, lo), (t, lo + width))
        pos = jax.lax.broadcasted_iota(jnp.int32, (bq, t, width), 2)
        oh = (pos == node[:, :, None]).astype(jnp.float32)       # [bq,T,w]
        f_sel = jnp.sum(oh * feat_d[None].astype(jnp.float32), axis=2)
        t_sel = jnp.sum(oh * thr_d[None], axis=2)                # [bq,T]
        fcol = jax.lax.broadcasted_iota(jnp.int32, (bq, t, num_feat), 2)
        ohf = (fcol == jnp.maximum(f_sel, 0.0).astype(jnp.int32)[:, :, None])
        xv = jnp.sum(jnp.where(ohf, x[:, None, :], 0.0), axis=2)  # [bq,T]
        go_right = (xv > t_sel) & (f_sel >= 0.0)
        node = 2 * node + go_right.astype(jnp.int32)

    n_leaf = 2**depth
    pos = jax.lax.broadcasted_iota(jnp.int32, (bq, t, n_leaf), 2)
    oh = (pos == node[:, :, None]).astype(jnp.float32)
    vals = jnp.sum(oh * leaf[None], axis=2)                      # [bq, T]
    out_ref[...] = jnp.sum(vals, axis=1, keepdims=True)          # [bq, 1]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gbdt_predict_padded(x: jax.Array, feat: jax.Array, thr: jax.Array,
                        leaf: jax.Array, *, bq: int = 64,
                        interpret: bool = False) -> jax.Array:
    """Pre-padded kernel entry. x: [B, F], B % bq == 0. Returns [B] (no base)."""
    b, num_feat = x.shape
    assert b % bq == 0, (b, bq)
    t, n_internal = feat.shape
    depth = (n_internal + 1).bit_length() - 1
    kernel = functools.partial(_gbdt_kernel, depth=depth, num_feat=num_feat)
    out = pl.pallas_call(
        kernel,
        grid=(b // bq,),
        in_specs=[
            pl.BlockSpec((bq, num_feat), lambda i: (i, 0)),
            pl.BlockSpec(feat.shape, lambda i: (0, 0)),
            pl.BlockSpec(thr.shape, lambda i: (0, 0)),
            pl.BlockSpec(leaf.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, feat, thr, leaf)
    return out[:, 0]
