"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: slow, obvious, allocation-happy.
Kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.gbdt.model import GBDTParams


def l2_topk_ref(q: jax.Array, x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Exact squared-L2 top-k. q: [B, D], x: [N, D] -> (dist [B,k], idx [B,k]).

    Distances are true squared L2 (including the ||q||^2 term), ascending.
    """
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    d2 = (jnp.sum(qf**2, 1)[:, None] + jnp.sum(xf**2, 1)[None, :]
          - 2.0 * qf @ xf.T)
    d2 = jnp.maximum(d2, 0.0)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def gbdt_predict_ref(params: GBDTParams, x: jax.Array) -> jax.Array:
    """Oracle GBDT inference: per-sample, per-tree python-level descent."""
    depth = params.depth
    b = x.shape[0]
    t = params.num_trees
    node = jnp.zeros((b, t), jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(params.feat[None].repeat(b, 0), node[..., None], 2)[..., 0]
        thr = jnp.take_along_axis(params.thresh[None].repeat(b, 0), node[..., None], 2)[..., 0]
        xv = jnp.take_along_axis(x.astype(jnp.float32), jnp.maximum(f, 0), 1)
        node = 2 * node + 1 + ((xv > thr) & (f >= 0)).astype(jnp.int32)
    leaf = node - (2**depth - 1)
    vals = jnp.take_along_axis(params.leaf[None].repeat(b, 0), leaf[..., None], 2)[..., 0]
    return params.base + vals.sum(1)


def bucket_topk_ref(q, vecs, sqn, ids, run_d, run_i):
    """Oracle for the fused IVF probe: batched bucket distances merged into
    the running top-k. q: [B,D]; vecs: [B,C,D]; sqn/ids: [B,C];
    run_d/run_i: [B,K] ascending."""
    qf = q.astype(jnp.float32)
    bias = jnp.sum(qf**2, axis=1, keepdims=True)
    d, i, _ = bucket_probe_ref(q, vecs, sqn, ids, bias, run_d[:, -1:],
                               run_d, run_i)
    return d, i


def bucket_probe_ref(q, vecs, sqn, ids, bias, kth, run_d, run_i):
    """Oracle for the biased fused probe (kernels/bucket_topk.py): returns
    (merged dist, merged ids, count of bucket dists strictly below kth)."""
    qf = q.astype(jnp.float32)
    dist = (sqn.astype(jnp.float32)
            - 2.0 * jnp.einsum("bd,bcd->bc", qf, vecs.astype(jnp.float32))
            + bias.astype(jnp.float32))
    dist = jnp.where(ids >= 0, jnp.maximum(dist, 0.0), jnp.inf)
    cnt = jnp.sum(dist < kth.astype(jnp.float32), axis=1).astype(jnp.int32)
    cand_d = jnp.concatenate([run_d, dist], axis=1)
    cand_i = jnp.concatenate([run_i, ids], axis=1)
    k = run_d.shape[1]
    neg, sel = jax.lax.top_k(-cand_d, k)
    return -neg, jnp.take_along_axis(cand_i, sel, axis=1), cnt
