"""Public jit'd wrappers for the Pallas kernels: padding, norm handling,
interpret-mode fallback (this container is CPU-only; TPU is the target).

`use_pallas` defaults to interpret-mode kernels on CPU so every caller in
the framework exercises the kernel path in tests; pure-XLA fallbacks
(`ref.py`) remain available and are what the dry-run lowers (Mosaic does not
compile for the CPU backend).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.gbdt.model import GBDTParams
from repro.kernels import ref
from repro.kernels.bucket_topk import bucket_topk_padded
from repro.kernels.gbdt_predict import gbdt_predict_padded
from repro.kernels.l2_topk import l2_topk_padded


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret"))
def l2_topk(q: jax.Array, x: jax.Array, *, k: int,
            x_sqnorm: Optional[jax.Array] = None,
            bias: Optional[jax.Array] = None,
            bq: int = 128, bn: int = 512,
            interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Fused top-k nearest (squared L2). Handles padding; returns true
    squared distances, ascending, with int32 ids; padded/invalid slots
    have dist=+inf, id=-1.

    ``bias`` [B, 1] is the per-query constant added to the kernel's
    ``x_sqnorm - 2 q.x`` partial distances; it defaults to ``||q||^2``
    (exact f32). The SQ8 asymmetric form — mirroring ``bucket_probe`` —
    passes ``q*scale`` as ``q``, int8 codes as ``x``, the DEQUANTIZED
    sqnorms, and ``bias = ||q||^2 - 2 q.offset``."""
    b, d = q.shape
    n = x.shape[0]
    if x_sqnorm is None:
        x_sqnorm = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
    if bias is None:
        bias = jnp.sum(q.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    bq_eff = min(bq, _round_up(b, 8))
    bn_eff = min(bn, _round_up(n, 128))
    bp = _round_up(b, bq_eff)
    np_ = _round_up(n, bn_eff)
    qp = jnp.pad(q, ((0, bp - b), (0, 0)))
    xp = jnp.pad(x, ((0, np_ - n), (0, 0)))
    xsqp = jnp.pad(x_sqnorm, (0, np_ - n), constant_values=jnp.inf)
    dist, idx = l2_topk_padded(qp, xp, xsqp, k=k, bq=bq_eff, bn=bn_eff,
                               interpret=interpret)
    dist = dist[:b] + bias
    idx = idx[:b]
    dist = jnp.where(idx >= 0, jnp.maximum(dist, 0.0), jnp.inf)
    return dist, idx


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gbdt_predict(params: GBDTParams, x: jax.Array, *, bq: int = 64,
                 interpret: bool = True) -> jax.Array:
    """Batched ensemble inference via the Pallas kernel. x: [B, F] -> [B]."""
    b, f = x.shape
    bq_eff = min(bq, _round_up(b, 8))
    bp = _round_up(b, bq_eff)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    out = gbdt_predict_padded(xp, params.feat, params.thresh, params.leaf,
                              bq=bq_eff, interpret=interpret)
    return params.base + out[:b]


# Pure-XLA equivalents (used in lowering paths where Mosaic is unavailable).
l2_topk_xla = jax.jit(ref.l2_topk_ref, static_argnames=("k",))
gbdt_predict_xla = jax.jit(ref.gbdt_predict_ref)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def bucket_topk(q: jax.Array, vecs: jax.Array, sqn: jax.Array,
                ids: jax.Array, run_d: jax.Array, run_i: jax.Array, *,
                bq: int = 8, interpret: bool = True):
    """Fused IVF probe step (per-query bucket + running top-k merge)."""
    bias = jnp.sum(q.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    d, i, _ = bucket_probe(q, vecs, sqn, ids, bias, run_d[:, -1:],
                           run_d, run_i, bq=bq, interpret=interpret)
    return d, i


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def bucket_probe(q: jax.Array, vecs: jax.Array, sqn: jax.Array,
                 ids: jax.Array, bias: jax.Array, kth: jax.Array,
                 run_d: jax.Array, run_i: jax.Array, *,
                 bq: int = 8, interpret: bool = True
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused probe with explicit per-query bias + insert counting.

    dist = sqn - 2 q.vecs + bias  (bias = ||q||^2 for f32 storage; the SQ8
    asymmetric form passes q*scale and bias = ||q||^2 - 2 q.offset).
    Returns (merged dist [B, K], merged ids [B, K], inserts i32[B]) where
    inserts counts bucket distances strictly below `kth` [B, 1]."""
    b = q.shape[0]
    bq_eff = min(bq, _round_up(b, 4))
    bp = _round_up(b, bq_eff)
    pad = bp - b
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        vecs = jnp.pad(vecs, ((0, pad), (0, 0), (0, 0)))
        sqn = jnp.pad(sqn, ((0, pad), (0, 0)), constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
        bias = jnp.pad(bias, ((0, pad), (0, 0)))
        kth = jnp.pad(kth, ((0, pad), (0, 0)))
        run_d = jnp.pad(run_d, ((0, pad), (0, 0)), constant_values=jnp.inf)
        run_i = jnp.pad(run_i, ((0, pad), (0, 0)), constant_values=-1)
    d, i, c = bucket_topk_padded(q, vecs, sqn, ids, bias, kth, run_d, run_i,
                                 bq=bq_eff, interpret=interpret)
    return d[:b], i[:b], c[:b, 0]
