"""Fused IVF probe step: per-query bucket distances + running top-k merge.

This is DARTH-on-IVF's hot loop (paper §3.3.2): each active query scans
its next bucket [cap, D] and merges into its running top-k. Unlike
l2_topk (one shared DB for all queries), every query here has its OWN
gathered bucket, so the distance work is a batched matvec, not a shared
matmul.

Kernel layout: grid over query tiles; per tile the kernel holds
q [bq, D], bucket vecs [bq, C, D], squared norms, ids, and the running
top-k in VMEM (bq=8, C=512, D=128 -> ~2.3 MB), computes
dist = ||x||^2 - 2 q.x + bias via an elementwise multiply-reduce on
the VPU, then runs the same K-step masked-min merge as l2_topk.

The per-query additive `bias` generalizes the ||q||^2 term so the SAME
kernel serves both storage formats (ops.py picks the inputs):
  f32:  pass q,        bias = ||q||^2
  SQ8:  pass q*scale,  bias = ||q||^2 - 2 q.offset   (asymmetric dequant:
        ||x_hat - q||^2 = sqn - 2[(q*scale).x8 + q.offset] + ||q||^2)

The kernel also emits the per-query count of bucket distances strictly
below the incoming k-th (`kth`) — the `ninserts` counter DARTH's feature
vector needs — so the sharded probe never computes distances twice.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS


def _bucket_topk_kernel(q_ref, vecs_ref, sqn_ref, ids_ref, bias_ref, kth_ref,
                        ind_ref, ini_ref, outd_ref, outi_ref, outc_ref,
                        *, k: int):
    q = q_ref[...].astype(jnp.float32)            # [bq, D]
    vecs = vecs_ref[...].astype(jnp.float32)      # [bq, C, D]
    sqn = sqn_ref[...].astype(jnp.float32)        # [bq, C]
    ids = ids_ref[...]                            # [bq, C]
    bias = bias_ref[...].astype(jnp.float32)      # [bq, 1]
    kth = kth_ref[...].astype(jnp.float32)        # [bq, 1]
    run_d = ind_ref[...].astype(jnp.float32)      # [bq, K]
    run_i = ini_ref[...]                          # [bq, K]

    dots = jnp.sum(vecs * q[:, None, :], axis=2)  # [bq, C] (VPU reduce)
    dist = sqn - 2.0 * dots + bias
    dist = jnp.where(ids >= 0, jnp.maximum(dist, 0.0), jnp.inf)
    outc_ref[...] = jnp.sum(dist < kth, axis=1,
                            keepdims=True).astype(jnp.int32)

    cand_d = jnp.concatenate([run_d, dist], axis=1)      # [bq, K+C]
    cand_i = jnp.concatenate([run_i, ids], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cand_d.shape, 1)
    out_col = jax.lax.broadcasted_iota(jnp.int32, run_d.shape, 1)
    new_d = jnp.zeros_like(run_d)
    new_i = jnp.zeros_like(run_i)

    def body(t, carry):
        cand_d, cand_i, new_d, new_i = carry
        m = jnp.min(cand_d, axis=1)
        am = jnp.argmin(cand_d, axis=1).astype(jnp.int32)
        sel = col == am[:, None]
        mi = jnp.sum(jnp.where(sel, cand_i, 0), axis=1)
        write = out_col == t
        new_d = jnp.where(write, m[:, None], new_d)
        new_i = jnp.where(write, mi[:, None], new_i)
        cand_d = jnp.where(sel, jnp.inf, cand_d)
        return cand_d, cand_i, new_d, new_i

    _, _, new_d, new_i = jax.lax.fori_loop(
        0, k, body, (cand_d, cand_i, new_d, new_i))
    outd_ref[...] = new_d
    outi_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def bucket_topk_padded(q: jax.Array, vecs: jax.Array, sqn: jax.Array,
                       ids: jax.Array, bias: jax.Array, kth: jax.Array,
                       run_d: jax.Array, run_i: jax.Array,
                       *, bq: int = 8, interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pre-padded fused probe. q: [B, D] (B % bq == 0), vecs: [B, C, D],
    sqn/ids: [B, C], bias/kth: [B, 1], run_d/run_i: [B, K]. Returns
    (merged dist [B, K], merged ids [B, K], inserts i32[B, 1])."""
    b, d = q.shape
    c = vecs.shape[1]
    k = run_d.shape[1]
    assert b % bq == 0, (b, bq)
    kernel = functools.partial(_bucket_topk_kernel, k=k)
    outd, outi, outc = pl.pallas_call(
        kernel,
        grid=(b // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, c, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, vecs, sqn, ids, bias, kth, run_d, run_i)
    return outd, outi, outc
