"""Pallas TPU kernels for DARTH's compute hot spots.

l2_topk       fused squared-L2 distance tiles + running top-k against a
              SHARED DB (flat ground truth, centroid ranking)
bucket_topk   fused IVF probe: per-query gathered bucket distances merged
              into the running top-k (DARTH-on-IVF's hot loop)
gbdt_predict  VMEM-resident GBDT ensemble inference (the recall predictor)

Each kernel has a pure-jnp oracle in ref.py and a jit'd public wrapper in
ops.py; tests sweep shapes/dtypes in interpret mode against the oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import bucket_topk, gbdt_predict, l2_topk

__all__ = ["ops", "ref", "l2_topk", "bucket_topk", "gbdt_predict"]
