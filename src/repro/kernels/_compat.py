"""jax version compatibility for the Pallas kernels (single definition
site — the three kernel modules all import from here)."""
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if COMPILER_PARAMS is None:  # pragma: no cover
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version")
