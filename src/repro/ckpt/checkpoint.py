"""Fault-tolerant checkpointing: atomic, step-tagged, elastic.

Layout: <dir>/step_<N>/  arrays.npz (flattened pytree leaves)
                         meta.msgpack (treedef paths, shapes, dtypes,
                                       mesh shape, pipeline state)
        <dir>/step_<N>.done   commit marker (atomic rename)

Elastic resharding: arrays are saved DE-SHARDED (logical form). `restore`
re-applies whatever sharding tree the *current* mesh dictates, so a run
checkpointed on (16,16) restores onto (8,16) or (2,16,16) unchanged —
tested in tests/test_ckpt.py. At real multi-host scale the same layout
becomes per-shard files + a global index; the commit protocol (write-all,
then marker) is identical.

Retention: keep the newest `keep` checkpoints (crash-safe GC: only ever
delete committed steps older than the newest committed).
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np
from jax.sharding import Mesh, NamedSharding

PyTree = Any


def _spec_entry(e) -> Any:
    return list(e) if isinstance(e, tuple) else e


def _leaf_sharding_meta(leaf) -> Optional[Dict[str, Any]]:
    """Serializable record of a leaf's NamedSharding (logical spec + mesh),
    so elastic restore can re-derive placement on a different mesh."""
    sh = getattr(leaf, "sharding", None)
    if not isinstance(sh, NamedSharding):
        return None
    return {
        "spec": [_spec_entry(e) for e in sh.spec],
        "mesh_axes": list(sh.mesh.axis_names),
        "mesh_shape": [int(sh.mesh.shape[a]) for a in sh.mesh.axis_names],
    }


def _flatten(tree: PyTree) -> Tuple[List[Tuple[str, np.ndarray]], Any,
                                    Dict[str, Any]]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    shardings: Dict[str, Any] = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        meta = _leaf_sharding_meta(leaf)
        if meta is not None:
            shardings[key] = meta
        out.append((key, np.asarray(jax.device_get(leaf))))
    return out, treedef, shardings


def save(directory: str, step: int, tree: PyTree,
         extra: Optional[Dict[str, Any]] = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves, _, shardings = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in leaves})
        meta = {
            "step": step,
            "keys": [k for k, _ in leaves],
            "dtypes": [str(v.dtype) for _, v in leaves],
            "shardings": shardings,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # commit marker
        with open(final + ".done", "w") as f:
            f.write("ok")
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _committed_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".done"):
            if os.path.exists(os.path.join(directory, name) + ".done"):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def _gc(directory: str, keep: int) -> None:
    steps = _committed_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        path = os.path.join(directory, f"step_{s:08d}")
        shutil.rmtree(path, ignore_errors=True)
        try:
            os.remove(path + ".done")
        except OSError:
            pass


def latest_step(directory: str) -> Optional[int]:
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def _respec(saved: Dict[str, Any], mesh: Mesh, shape) -> NamedSharding:
    """Re-derive a NamedSharding on a *different* mesh from the logical
    spec recorded at save time: axes the new mesh lacks, or whose size no
    longer divides the dimension, degrade to replication (elastic). The
    degrade rule is dist.sharding.spec_for — one implementation shared
    with the placement path (local import: dist pulls in the kernels)."""
    from repro.dist.sharding import spec_for
    spec = saved.get("spec", [])
    logical = [tuple(e) if isinstance(e, list) else e for e in spec]
    logical += [None] * (len(shape) - len(logical))
    return NamedSharding(mesh, spec_for(mesh, shape, logical))


def restore(directory: str, like: PyTree, step: Optional[int] = None,
            shardings: Optional[Any] = None
            ) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore into the structure of `like` (arrays or ShapeDtypeStructs).

    `shardings` places leaves for elastic restore; either
      * a tree of NamedSharding (matching `like` leaf-for-leaf), or
      * a Mesh: each leaf is re-placed using the logical PartitionSpec
        recorded at save time, re-resolved against the new mesh shape
        (the (4,2) -> (2,4) reshard path; unknown axes replicate).
    """
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no committed checkpoint in {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    z = np.load(os.path.join(path, "arrays.npz"))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    mesh = shardings if isinstance(shardings, Mesh) else None
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None and mesh is None
                    else [None] * len(leaves))
    saved_sh = meta.get("shardings") or {}
    out = []
    for (pth, leaf), shd in zip(leaves, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        arr = z[key]
        if mesh is not None:
            shd = _respec(saved_sh.get(key, {}), mesh, leaf.shape)
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [x for x in out]), meta


__all__ = ["save", "restore", "latest_step"]
