"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell with 512 placeholder host devices,
record memory_analysis / cost_analysis / collective traffic.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k [--multi-pod]           # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all \
      --out results/dryrun.json                # full sweep, both meshes
"""
# The VERY FIRST lines, before ANY other import (jax locks device count on
# first init):
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro import configs
from repro.configs.base import SHAPES, runnable
from repro.dist import sharding as sh
from repro.launch import mesh as mesh_lib
from repro.models import model_zoo
from repro.train import step as step_lib
from repro.utils import hlo as hlo_lib
from repro.utils import meshctx


def _ns_tree(spec_or_shard):
    return spec_or_shard


def lower_cell(arch: str, shape: str, *, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Lower+compile one cell; returns the roofline-input record."""
    cfg = configs.get_config(arch)
    cell = SHAPES[shape]
    ok, reason = runnable(cfg, cell)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind,
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    params_abs = model_zoo.abstract_params(cfg)
    p_shard = sh.param_shardings(params_abs, mesh)
    t0 = time.time()

    overrides = overrides or {}
    # Sequence parallelism for attention-family train/prefill (perf iter 6:
    # 2x memory term, 5.8x temp memory on glm4). NOT for ssm/hybrid: their
    # causal conv + chunked scans need the full sequence per device, and a
    # seq-sharded residual thrashes reshardings every chunk (21 TB of
    # collectives on zamba2 — iteration 6b, REFUTED for that family).
    sp = (cell.kind in ("train", "prefill")
          and cfg.family in ("dense", "moe", "vlm", "audio"))
    with mesh, meshctx.use_mesh(mesh, sp=sp):
        if cell.kind == "train":
            init_opt, train_step = step_lib.make_train_step(
                cfg, **overrides)
            opt_abs = jax.eval_shape(init_opt, params_abs)
            o_shard = sh.opt_shardings(opt_abs, params_abs, mesh)
            specs = model_zoo.input_specs(cfg, cell.seq_len,
                                          cell.global_batch, "train")
            b_shard = sh.batch_shardings(specs["batch"], mesh, "train")
            lowered = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, specs["batch"])
        elif cell.kind == "prefill":
            prefill_step = step_lib.make_prefill_step(cfg)
            specs = model_zoo.input_specs(cfg, cell.seq_len,
                                          cell.global_batch, "prefill")
            b_shard = sh.batch_shardings(specs["batch"], mesh, "prefill")
            lowered = jax.jit(
                prefill_step, in_shardings=(p_shard, b_shard),
            ).lower(params_abs, specs["batch"])
        else:  # decode
            serve_step = step_lib.make_serve_step(cfg)
            specs = model_zoo.input_specs(cfg, cell.seq_len,
                                          cell.global_batch, "decode")
            c_shard = sh.cache_shardings(specs["cache"], mesh)
            t_shard = sh.batch_shardings(specs["tokens"], mesh, "decode")
            from jax.sharding import NamedSharding, PartitionSpec as P
            pos_shard = NamedSharding(mesh, P())
            lowered = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, t_shard, pos_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ).lower(params_abs, specs["cache"], specs["tokens"],
                    jax.ShapeDtypeStruct((), np.int32))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x: one entry per program
        ca = ca[0] if ca else {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    except Exception:  # pragma: no cover
        mem = {}
    analysis = hlo_lib.analyze(compiled.as_text())
    coll = {k: analysis[k] for k in
            list(hlo_lib.COLLECTIVES) + ["num_ops", "total"]}
    rec.update(
        status="ok",
        lower_seconds=round(t_lower, 1),
        compile_seconds=round(t_compile, 1),
        # loop-weighted per-device numbers from the HLO parser (XLA's
        # cost_analysis counts while bodies once -> undercounts scans)
        hlo_flops=analysis["flops"],
        hlo_bytes=analysis["hbm_bytes"],
        # raw cost_analysis for reference
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        memory=mem,
        collectives=coll,
        num_devices=int(np.prod(list(mesh.shape.values()))),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep all arch x shape x {single,multi}-pod")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun.json")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in configs.ALL_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                if not args.single_pod_only:
                    cells.append((arch, shape, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, args.multi_pod))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch, shape, mp in cells:
        key = (arch, shape, "2x16x16" if mp else "16x16")
        if key in done:
            print(f"[skip-done] {key}")
            continue
        print(f"[dryrun] {key} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, multi_pod=mp)
        except Exception as e:  # record failures, keep sweeping
            rec = {"arch": arch, "shape": shape,
                   "mesh": key[2], "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" flops={rec['flops']:.3g}"
                     f" coll={rec['collectives']['total']:.3g}B"
                     f" compile={rec['compile_seconds']}s")
        elif status == "skipped":
            extra = f" ({rec['reason'][:60]})"
        else:
            extra = f" ({rec['error'][:120]})"
        print(f"[dryrun] {key} -> {status}{extra}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDONE: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
