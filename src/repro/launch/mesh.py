"""Production mesh factory (deliverable e).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
tests/benches must see 1 CPU device while the dry-run sees 512
placeholders)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_search_mesh(num_shards: int = 0):
    """1-D ("model",) mesh for sharded ANN search (dist/collectives.py).

    `num_shards` 0 means "all visible devices"; the database rows are
    sharded over this axis, queries replicate. On the 1-CPU test host
    this is a (1,) mesh and the search path is identical."""
    n = num_shards or jax.device_count()
    if jax.device_count() < n:
        raise ValueError(
            f"--shards {n} needs {n} devices but only "
            f"{jax.device_count()} visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} for a smoke run")
    return jax.make_mesh((n,), ("model",))


def make_serve_mesh(hosts: int = 1, shards: int = 0):
    """2-D ("hosts", "model") mesh for multi-host slot-pool serving.

    The "model" axis shards the index (dist/collectives.py fast paths,
    same as make_search_mesh); the "hosts" axis carries the slot dim of
    the serve batch (dist.sharding.batch_shardings kind="serve" /
    slot_sharding), so each host group's devices step only the slot
    slice its host loop owns and the per-chunk collectives run within a
    host group. `shards` 0 means "all remaining devices per host"."""
    if hosts < 1:
        raise ValueError(f"hosts must be >= 1, got {hosts}")
    n = shards or max(jax.device_count() // hosts, 1)
    if jax.device_count() < hosts * n:
        raise ValueError(
            f"--hosts {hosts} x --shards {n} needs {hosts * n} devices "
            f"but only {jax.device_count()} visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={hosts * n} for a "
            f"smoke run")
    return jax.make_mesh((hosts, n), ("hosts", "model"))


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.shape.values())} axes={mesh.axis_names}"
