"""Production mesh factory (deliverable e).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
tests/benches must see 1 CPU device while the dry-run sees 512
placeholders)."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.shape.values())} axes={mesh.axis_names}"
