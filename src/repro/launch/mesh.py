"""Production mesh factory (deliverable e).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
tests/benches must see 1 CPU device while the dry-run sees 512
placeholders)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_search_mesh(num_shards: int = 0):
    """1-D ("model",) mesh for sharded ANN search (dist/collectives.py).

    `num_shards` 0 means "all visible devices"; the database rows are
    sharded over this axis, queries replicate. On the 1-CPU test host
    this is a (1,) mesh and the search path is identical."""
    n = num_shards or jax.device_count()
    if jax.device_count() < n:
        raise ValueError(
            f"--shards {n} needs {n} devices but only "
            f"{jax.device_count()} visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} for a smoke run")
    return jax.make_mesh((n,), ("model",))


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.shape.values())} axes={mesh.axis_names}"
