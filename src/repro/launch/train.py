"""Mesh-aware training launcher: the production version of train.loop.

On real hardware this runs under the 16x16 / 2x16x16 mesh with the same
shardings the dry-run compiles; on this CPU container it runs the identical
code path on a (1,1) mesh (the logic — shardings, checkpoint/restart,
restart-exact data — is shared with `repro.train.loop`, which the
fault-tolerance tests exercise).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 100 --global-batch 8 --seq-len 128 --scale 0.1
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import ckpt, configs
from repro.data.synthetic import PipelineConfig, TokenPipeline
from repro.dist import sharding as sh
from repro.launch import mesh as mesh_lib
from repro.models import model_zoo
from repro.train import step as step_lib
from repro.utils import meshctx


def reduced(cfg, scale: float):
    """Width/depth-scaled variant for CPU-sized runs (scale=1 -> full)."""
    if scale >= 1.0:
        return cfg
    def r(v, m=1):
        return max(m, int(v * scale))
    return cfg.scaled(
        num_layers=r(cfg.num_layers, 2),
        d_model=r(cfg.d_model // 64, 1) * 64,
        num_heads=r(cfg.num_heads, 2),
        num_kv_heads=max(1, min(r(cfg.num_kv_heads, 1), r(cfg.num_heads, 2))),
        d_ff=r(cfg.d_ff // 64, 2) * 64,
        vocab_size=min(cfg.vocab_size, 8192),
        num_experts=r(cfg.num_experts, 4) if cfg.num_experts else 0,
        moe_d_ff=r(cfg.moe_d_ff // 32, 2) * 32 if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        encoder_layers=r(cfg.encoder_layers, 1) if cfg.encoder_layers else 0,
        frontend_len=min(cfg.frontend_len, 16) if cfg.frontend_len else 0,
        frontend_dim=min(cfg.frontend_dim, 64) if cfg.frontend_dim else 0,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--scale", type=float, default=0.1,
                    help="model width/depth scale (1.0 = full config)")
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--peak-lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = reduced(configs.get_config(args.arch), args.scale)
    mesh = mesh_lib.make_host_mesh() if jax.device_count() == 1 else \
        mesh_lib.make_production_mesh()
    print(f"[train] {cfg.name} scale={args.scale} on "
          f"{mesh_lib.describe(mesh)}")

    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch))
    init_opt, train_step = step_lib.make_train_step(cfg,
                                                    peak_lr=args.peak_lr)

    with mesh, meshctx.use_mesh(mesh, sp=True):
        params = model_zoo.init_params(cfg, jax.random.PRNGKey(0))
        p_sh = sh.param_shardings(params, mesh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = init_opt(params)
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        start = 0
        if ckpt.latest_step(args.ckpt_dir) is not None:
            (params, opt_state), meta = ckpt.restore(
                args.ckpt_dir, (params, opt_state))
            start = int(meta["extra"]["next_step"])
            print(f"[train] resumed from step {start}")

        t0 = time.time()
        for s in range(start, args.steps):
            batch = pipe.get_batch(s)
            params, opt_state, m = step_fn(params, opt_state, batch)
            if s % 10 == 0 or s == args.steps - 1:
                print(f"step {s:5d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"({(time.time()-t0)/max(s-start+1,1):.2f}s/step)",
                      flush=True)
            if (s + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, s + 1, (params, opt_state),
                          extra={"next_step": s + 1})
    print("[train] done")


if __name__ == "__main__":
    main()
