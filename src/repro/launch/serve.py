"""Declarative-recall serving launcher: builds (or loads) an index, fits
DARTH once, then serves a stream of queries with per-request recall
targets through the compaction engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --n 30000 --queries 512 \
      --targets 0.8,0.9,0.95

Sharded serving (--shards N places the index over a ("model",) mesh and
searches through the shard_map fast paths — IVF: every bucket's cap dim
split, per-shard fused bucket_topk + one [B, k] all-gather merge; HNSW
(--engine hnsw): graph rows split, per-shard neighbor resolution + one
[B, M] psum/all-gather frontier merge; DARTH fit ground truth is
sharded the same way. N=0 uses every visible device — on a multi-chip
host, or under XLA_FLAGS=--xla_force_host_platform_device_count=8 for a
smoke run):
  PYTHONPATH=src python -m repro.launch.serve --shards 0
  PYTHONPATH=src python -m repro.launch.serve --shards 0 --engine hnsw
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro import dist
from repro.core import api, engines, intervals
from repro.data import vectors
from repro.index import flat, hnsw, ivf
from repro.launch import mesh as mesh_lib
from repro.serve import DarthServer
from repro.utils import hlo as hlo_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engine", choices=("ivf", "hnsw"), default="ivf")
    ap.add_argument("--nlist", type=int, default=128)
    ap.add_argument("--m", type=int, default=16,
                    help="HNSW graph degree (--engine hnsw)")
    ap.add_argument("--ef", type=int, default=128,
                    help="HNSW frontier size (--engine hnsw)")
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--targets", type=str, default="0.8,0.9,0.95")
    ap.add_argument("--shards", type=int, default=None,
                    help="place the index over a ('model',) mesh and "
                         "search via the shard_map fast path (IVF: cap "
                         "dim split; HNSW: graph rows split); 0 = all "
                         "visible devices (default: unsharded)")
    args = ap.parse_args()

    targets = [float(t) for t in args.targets.split(",")]
    ds = vectors.make_dataset(n=args.n, d=args.dim, num_learn=2000,
                              num_queries=args.queries,
                              clusters=max(32, args.nlist), seed=0)
    t0 = time.time()
    if args.engine == "hnsw":
        index = hnsw.build(ds.base, m=args.m, seed=0)
    else:
        index = ivf.build(ds.base, nlist=args.nlist, seed=0)
    print(f"[serve] {args.engine} index built: {index.num_vectors} vecs "
          f"({time.time()-t0:.1f}s)")

    mesh = None
    if args.shards is not None:
        mesh = mesh_lib.make_search_mesh(args.shards)
        index = dist.place_index(index, mesh)
        what = (f"{index.num_vectors} graph rows" if args.engine == "hnsw"
                else f"cap {index.cap}")
        print(f"[serve] index placed on {mesh_lib.describe(mesh)} "
              f"({what} split over 'model')")
        if args.engine == "hnsw":
            make_engine = lambda **kw: engines.sharded_hnsw_engine(  # noqa: E731
                index, mesh, **kw)
        else:
            make_engine = lambda **kw: engines.sharded_ivf_engine(  # noqa: E731
                index, mesh, **kw)
    elif args.engine == "hnsw":
        make_engine = lambda **kw: engines.hnsw_engine(index, **kw)  # noqa: E731
    else:
        make_engine = lambda **kw: engines.ivf_engine(index, **kw)  # noqa: E731

    engine_kw = (dict(k=args.k, ef=args.ef) if args.engine == "hnsw"
                 else dict(k=args.k, nprobe=args.nlist))
    darth = api.Darth(
        make_engine=make_engine,
        engine=make_engine(**engine_kw))
    t0 = time.time()
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), mesh=mesh)
    print(f"[serve] DARTH fit ({time.time()-t0:.1f}s) "
          f"mse={darth.trained.metrics['mse']:.5f}")

    def interval_for_target(rt):
        ps = [darth.interval_params(float(r)) for r in np.atleast_1d(rt)]
        return intervals.IntervalParams(
            ipi=np.array([p.ipi for p in ps], np.float32),
            mpi=np.array([p.mpi for p in ps], np.float32))

    rng = np.random.default_rng(0)
    r_targets = rng.choice(targets, size=args.queries).astype(np.float32)
    server = DarthServer(darth.engine, darth.trained.predictor,
                         interval_for_target, num_slots=args.slots,
                         mesh=mesh)
    t0 = time.time()
    results, stats = server.serve(ds.queries, r_targets)
    dt = time.time() - t0
    print(f"[serve] {stats.completed} queries in {dt:.1f}s "
          f"({stats.completed/dt:.0f} qps host-side; "
          f"{stats.engine_steps} engine steps, {stats.refills} refills)")

    if mesh is not None:
        sfn = dist.make_sharded_flat_search(mesh, args.k)
        q_dev, x_dev = jnp.asarray(ds.queries), jnp.asarray(ds.base)
        compiled = sfn.lower(q_dev, x_dev).compile()  # one compile: run+HLO
        gt_d, gt_i = compiled(q_dev, x_dev)
        coll = hlo_lib.collective_bytes(compiled.as_text())
        print(f"[serve] sharded ground truth: "
              f"{coll['total']/1e3:.1f} kB collectives "
              f"({coll['num_ops']:.0f} ops) per batch")
    else:
        gt_d, gt_i = flat.search(jnp.asarray(ds.queries),
                                 jnp.asarray(ds.base), args.k)
    # A step-budget truncation can leave never-admitted queries at None
    # (DarthServer contract) — report recall over the returned ones.
    done = np.array([i for i, r in enumerate(results) if r is not None])
    if stats.truncated or len(done) < len(results):
        print(f"[serve] step budget hit: {stats.truncated} truncated, "
              f"{len(results) - len(done)} never admitted")
    ids = np.stack([results[i][1] for i in done])
    rec = np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i[done]))
    for t in targets:
        sel = r_targets[done] == np.float32(t)
        print(f"[serve] target {t:.2f}: mean recall "
              f"{rec[sel].mean():.4f} over {int(sel.sum())} queries")


if __name__ == "__main__":
    main()
