"""Declarative-recall serving launcher: builds (or loads) an index, fits
DARTH once, then serves a stream of queries with per-request recall
targets through the compaction engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --n 30000 --queries 512 \
      --targets 0.8,0.9,0.95

Sharded serving (--shards N splits every bucket's cap dim over a
("model",) mesh and probes through the shard_map fast path — per-shard
fused bucket_topk + one [B, k] all-gather merge; DARTH fit ground truth
is sharded the same way. N=0 uses every visible device — on a multi-chip
host, or under XLA_FLAGS=--xla_force_host_platform_device_count=8 for a
smoke run):
  PYTHONPATH=src python -m repro.launch.serve --shards 0
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro import dist
from repro.core import api, engines, intervals
from repro.data import vectors
from repro.index import flat, ivf
from repro.launch import mesh as mesh_lib
from repro.serve import DarthServer
from repro.utils import hlo as hlo_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--nlist", type=int, default=128)
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--targets", type=str, default="0.8,0.9,0.95")
    ap.add_argument("--shards", type=int, default=None,
                    help="split every bucket's cap dim over a ('model',) "
                         "mesh and probe via the shard_map fast path; "
                         "0 = all visible devices (default: unsharded)")
    args = ap.parse_args()

    targets = [float(t) for t in args.targets.split(",")]
    ds = vectors.make_dataset(n=args.n, d=args.dim, num_learn=2000,
                              num_queries=args.queries,
                              clusters=max(32, args.nlist), seed=0)
    t0 = time.time()
    index = ivf.build(ds.base, nlist=args.nlist, seed=0)
    print(f"[serve] index built: {index.num_vectors} vecs "
          f"({time.time()-t0:.1f}s)")

    mesh = None
    if args.shards is not None:
        mesh = mesh_lib.make_search_mesh(args.shards)
        index = dist.place_index(index, mesh)
        print(f"[serve] index placed on {mesh_lib.describe(mesh)} "
              f"(cap {index.cap} split over 'model')")
        make_engine = lambda **kw: engines.sharded_ivf_engine(  # noqa: E731
            index, mesh, **kw)
    else:
        make_engine = lambda **kw: engines.ivf_engine(index, **kw)  # noqa: E731

    darth = api.Darth(
        make_engine=make_engine,
        engine=make_engine(k=args.k, nprobe=args.nlist))
    t0 = time.time()
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), mesh=mesh)
    print(f"[serve] DARTH fit ({time.time()-t0:.1f}s) "
          f"mse={darth.trained.metrics['mse']:.5f}")

    def interval_for_target(rt):
        ps = [darth.interval_params(float(r)) for r in np.atleast_1d(rt)]
        return intervals.IntervalParams(
            ipi=np.array([p.ipi for p in ps], np.float32),
            mpi=np.array([p.mpi for p in ps], np.float32))

    rng = np.random.default_rng(0)
    r_targets = rng.choice(targets, size=args.queries).astype(np.float32)
    server = DarthServer(darth.engine, darth.trained.predictor,
                         interval_for_target, num_slots=args.slots,
                         mesh=mesh)
    t0 = time.time()
    results, stats = server.serve(ds.queries, r_targets)
    dt = time.time() - t0
    print(f"[serve] {stats.completed} queries in {dt:.1f}s "
          f"({stats.completed/dt:.0f} qps host-side; "
          f"{stats.engine_steps} engine steps, {stats.refills} refills)")

    if mesh is not None:
        sfn = dist.make_sharded_flat_search(mesh, args.k)
        q_dev, x_dev = jnp.asarray(ds.queries), jnp.asarray(ds.base)
        compiled = sfn.lower(q_dev, x_dev).compile()  # one compile: run+HLO
        gt_d, gt_i = compiled(q_dev, x_dev)
        coll = hlo_lib.collective_bytes(compiled.as_text())
        print(f"[serve] sharded ground truth: "
              f"{coll['total']/1e3:.1f} kB collectives "
              f"({coll['num_ops']:.0f} ops) per batch")
    else:
        gt_d, gt_i = flat.search(jnp.asarray(ds.queries),
                                 jnp.asarray(ds.base), args.k)
    ids = np.stack([r[1] for r in results])
    rec = np.asarray(flat.recall_at_k(jnp.asarray(ids), gt_i))
    for t in targets:
        sel = r_targets == np.float32(t)
        print(f"[serve] target {t:.2f}: mean recall "
              f"{rec[sel].mean():.4f} over {int(sel.sum())} queries")


if __name__ == "__main__":
    main()
