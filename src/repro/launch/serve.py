"""Declarative-recall serving launcher: builds (or loads) an index, fits
DARTH once, then serves a stream of queries with per-request recall
targets through the compaction engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --n 30000 --queries 512 \
      --targets 0.8,0.9,0.95

Sharded serving (--shards N places the index over a ("model",) mesh and
searches through the shard_map fast paths — IVF: every bucket's cap dim
split, per-shard fused bucket_topk + one [B, k] all-gather merge; HNSW
(--engine hnsw): graph rows split, per-shard neighbor resolution + one
[B, M] psum/all-gather frontier merge; DARTH fit ground truth is
sharded the same way. N=0 uses every visible device — on a multi-chip
host, or under XLA_FLAGS=--xla_force_host_platform_device_count=8 for a
smoke run):
  PYTHONPATH=src python -m repro.launch.serve --shards 0
  PYTHONPATH=src python -m repro.launch.serve --shards 0 --engine hnsw

Streaming mutations (--mutations INS,DEL applies an insert/delete burst
mid-serve through the repro.mutate subsystem: delta ring + tombstones,
drift monitor, predictor recalibration hot-swap, compaction):
  PYTHONPATH=src python -m repro.launch.serve --mutations 0.2,0.1 \
      --drift 0.3

Multi-host slot pool (--hosts N splits the slot pool into N per-host
slices, each with its own admission/refill/compaction loop — simulated
multi-host on one process, like the multidevice lane; combined with
--shards and enough devices, the mesh gains a "hosts" axis and the slot
dim is placed over host groups):
  PYTHONPATH=src python -m repro.launch.serve --hosts 4
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve --hosts 2 --shards 4

Difficulty-aware serving (--tiers classifies queries at admission from
the routing scan and gives the hard tier reserved slots, a boosted
effective target, hedged duplicates on idle capacity, and bounded
admission under overload; per-tier p50/p99 recall and latency are
reported after each phase — see docs/architecture.md):
  PYTHONPATH=src python -m repro.launch.serve --tiers --boost 0.05 \
      --hedge --max-queue 64 --overload degrade

Observability (--trace DIR writes every phase's per-query lifecycle
spans to DIR/trace.jsonl and prints one termination story; --metrics
exports the Prometheus page + event log — see docs/observability.md):
  PYTHONPATH=src python -m repro.launch.serve --trace /tmp/tr --metrics
  python -m repro.obs.explain /tmp/tr/trace.jsonl --qid 7
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro import dist, mutate
from repro.core import api, engines, training
from repro.data import vectors
from repro.index import flat, hnsw, ivf
from repro.launch import mesh as mesh_lib
from repro.serve import DarthServer
from repro.utils import hlo as hlo_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--learn", type=int, default=2000,
                    help="DARTH training-query pool size")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engine", choices=("ivf", "hnsw"), default="ivf")
    ap.add_argument("--nlist", type=int, default=128)
    ap.add_argument("--m", type=int, default=16,
                    help="HNSW graph degree (--engine hnsw)")
    ap.add_argument("--ef", type=int, default=128,
                    help="HNSW frontier size (--engine hnsw)")
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--targets", type=str, default="0.8,0.9,0.95")
    ap.add_argument("--shards", type=int, default=None,
                    help="place the index over a ('model',) mesh and "
                         "search via the shard_map fast path (IVF: cap "
                         "dim split; HNSW: graph rows split); 0 = all "
                         "visible devices (default: unsharded)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="split the slot pool into N per-host loops "
                         "(admission/refill/compaction run per host); "
                         "with --shards and N*shards devices the mesh "
                         "gains a 'hosts' axis and the slot dim is "
                         "placed over host groups")
    ap.add_argument("--mutations", type=str, default=None,
                    metavar="INS,DEL",
                    help="streaming-mutation workload: apply an "
                         "insert_pct,delete_pct burst (of --n) between "
                         "serve phases, with drift monitoring, "
                         "predictor recalibration and compaction")
    ap.add_argument("--drift", type=float, default=0.0,
                    help="fraction of burst inserts drawn OOD "
                         "(mutation_stream)")
    ap.add_argument("--mutation-steps", type=int, default=4)
    ap.add_argument("--online-compact", action="store_true",
                    help="with --mutations: stream the events INTO a "
                         "live serve phase (one per chunk boundary, "
                         "contents-only delta refreshes), then run "
                         "compaction as a background incremental "
                         "rebuild ticked at boundaries and hot-swap "
                         "the folded base atomically at a drained "
                         "boundary — no stop-the-world pause")
    ap.add_argument("--delta-cap", type=int, default=0,
                    help="delta ring capacity (0 = sized to the burst)")
    ap.add_argument("--recal-threshold", type=float, default=0.02,
                    help="recall drift that triggers a predictor refit")
    ap.add_argument("--tiers", action="store_true",
                    help="difficulty-aware admission: classify queries "
                         "at admission (serve.difficulty) and partition "
                         "slots between easy/hard tiers")
    ap.add_argument("--hard-quantile", type=float, default=0.75,
                    help="difficulty-score quantile above which a query "
                         "is hard (--tiers)")
    ap.add_argument("--hard-slots", type=float, default=0.25,
                    help="fraction of each host's slots reserved for "
                         "the hard tier (--tiers)")
    ap.add_argument("--boost", type=float, default=0.0,
                    help="extra recall target for hard queries, clipped "
                         "to 0.99 (--tiers)")
    ap.add_argument("--hedge", action="store_true",
                    help="launch hedged duplicates of in-flight hard "
                         "queries into idle hard slots (--tiers)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="per-host admission bound; overflow is shed or "
                         "degraded per --overload (--tiers)")
    ap.add_argument("--overload", choices=("degrade", "shed"),
                    default="degrade",
                    help="overload policy beyond --max-queue (--tiers)")
    ap.add_argument("--degrade-target", type=float, default=0.80,
                    help="lowered target for --overload degrade")
    ap.add_argument("--rebalance", action="store_true",
                    help="steal queued queries from backlogged hosts "
                         "into idle hosts at refill boundaries (--tiers)")
    ap.add_argument("--trace", type=str, default=None, metavar="DIR",
                    help="per-query tracing (repro.obs): write every "
                         "serve phase's lifecycle spans to DIR/"
                         "trace.jsonl and print one explain() story; "
                         "replay any query later with python -m "
                         "repro.obs.explain DIR/trace.jsonl --qid N")
    ap.add_argument("--metrics", action="store_true",
                    help="aggregate serving metrics (repro.obs) and "
                         "write the Prometheus exposition page + JSONL "
                         "event log to --trace DIR (or results/)")
    args = ap.parse_args()

    targets = [float(t) for t in args.targets.split(",")]
    ds = vectors.make_dataset(n=args.n, d=args.dim, num_learn=args.learn,
                              num_queries=args.queries,
                              clusters=max(32, args.nlist), seed=0)
    t0 = time.time()
    if args.engine == "hnsw":
        index = hnsw.build(ds.base, m=args.m, seed=0)
    else:
        index = ivf.build(ds.base, nlist=args.nlist, seed=0)
    print(f"[serve] {args.engine} index built: {index.num_vectors} vecs "
          f"({time.time()-t0:.1f}s)")

    mesh = None
    if args.shards is not None:
        import jax
        shards = args.shards or jax.device_count()
        if args.hosts > 1 and jax.device_count() >= args.hosts * shards:
            mesh = mesh_lib.make_serve_mesh(args.hosts, shards)
        else:
            mesh = mesh_lib.make_search_mesh(args.shards)
        print(f"[serve] serving on {mesh_lib.describe(mesh)}")
    if args.hosts > 1:
        print(f"[serve] multi-host slot pool: {args.hosts} host loops x "
              f"{args.slots // args.hosts} slots")

    engine_kw = (dict(k=args.k, ef=args.ef) if args.engine == "hnsw"
                 else dict(k=args.k, nprobe=args.nlist))

    mutable = None
    if args.mutations is not None:
        ins_pct, del_pct = (float(v) for v in args.mutations.split(","))
        cap = args.delta_cap or max(
            args.k, -(-int(round(ins_pct * args.n)) // 128) * 128)
        mutable = mutate.MutableIndex(index, capacity=cap)
        print(f"[serve] mutable index: delta capacity {cap}")

    def family_engine(idx, **kw):
        """Engine over an (already-placed, when sharded) index."""
        if mesh is not None:
            if args.engine == "hnsw":
                return engines.sharded_hnsw_engine(idx, mesh, **kw)
            return engines.sharded_ivf_engine(idx, mesh, **kw)
        if args.engine == "hnsw":
            return engines.hnsw_engine(idx, **kw)
        return engines.ivf_engine(idx, **kw)

    def build_engine(**kw):
        if mutable is None:
            idx = dist.place_index(index, mesh) if mesh is not None else index
            return family_engine(idx, **kw)
        base_idx, delta = mutable.base, mutable.delta
        if mesh is not None:
            view = dist.place_index(mutable.view(), mesh)
            base_idx, delta = view.base, view.delta
        return engines.mutable_engine(family_engine(base_idx, **kw), delta)

    darth = api.Darth(make_engine=build_engine, engine=build_engine(**engine_kw))
    t0 = time.time()
    darth.fit(jnp.asarray(ds.learn), jnp.asarray(ds.base), mesh=mesh)
    print(f"[serve] DARTH fit ({time.time()-t0:.1f}s) "
          f"mse={darth.trained.metrics['mse']:.5f}")

    rng = np.random.default_rng(0)
    r_targets = rng.choice(targets, size=args.queries).astype(np.float32)
    tiers = None
    if args.tiers:
        from repro.serve import TierConfig
        tiers = TierConfig(hard_quantile=args.hard_quantile,
                           hard_slot_fraction=args.hard_slots,
                           boost=args.boost, hedge=args.hedge,
                           max_queue=args.max_queue,
                           overload=args.overload,
                           degrade_target=args.degrade_target,
                           rebalance=args.rebalance)
        print(f"[serve] difficulty tiers: hard q>{args.hard_quantile:.2f}, "
              f"{args.hard_slots:.0%} hard slots, boost {args.boost:+.2f}"
              + (", hedging" if args.hedge else "")
              + (f", max_queue {args.max_queue} ({args.overload})"
                 if args.max_queue is not None else "")
              + (", rebalance" if args.rebalance else ""))
    tracer = None
    if args.trace is not None:
        import os
        from repro.obs import Tracer
        os.makedirs(args.trace, exist_ok=True)
        trace_path = os.path.join(args.trace, "trace.jsonl")
        open(trace_path, "w").close()     # fresh file per run
        tracer = Tracer(path=trace_path)
        print(f"[serve] tracing -> {trace_path}")
    registry = None
    if args.metrics:
        from repro.obs import MetricsRegistry
        registry = MetricsRegistry()
    server = DarthServer(darth.engine, darth.trained.predictor,
                         darth.interval_for_target, num_slots=args.slots,
                         mesh=mesh, hosts=args.hosts, tiers=tiers,
                         tracer=tracer, metrics=registry)
    monitor = None
    if mutable is not None:
        monitor = mutate.RecalibrationMonitor(
            mutable, darth, targets=targets,
            threshold=args.recal_threshold, mesh=mesh, metrics=registry)
        if registry is not None:
            mutable.attach_metrics(registry)

    gt_cache = {}

    def ground_truth():
        """Fresh exact top-k as GLOBAL ids over the current live set.
        The mutable path memoizes on the mutation epoch INSIDE
        MutableIndex.live_ground_truth (next to `version`, where the
        epoch lives) — consecutive phases over an unchanged live set
        (e.g. post-burst then post-recalibration) reuse one scan."""
        if mutable is not None:
            return mutable.live_ground_truth(ds.queries, args.k, mesh=mesh)
        if "frozen" not in gt_cache:
            _, gt_i = training.ground_truth(
                jnp.asarray(ds.queries), jnp.asarray(ds.base),
                args.k, mesh=mesh)
            gt_cache["frozen"] = np.asarray(gt_i).astype(np.int32)
        return gt_cache["frozen"]

    def serve_phase(label: str, on_boundary=None):
        t0 = time.time()
        if tracer is not None:
            tracer.label = label       # spans carry the phase name
        results, stats = server.serve(ds.queries, r_targets,
                                      on_boundary=on_boundary)
        dt = time.time() - t0
        print(f"[serve] {label}: {stats.completed} queries in {dt:.1f}s "
              f"({stats.completed/max(dt, 1e-9):.0f} qps host-side; "
              f"{stats.engine_steps} engine steps, {stats.refills} refills)")
        if server.hosts > 1:
            print(f"[serve] {label}: per-host completed "
                  + "/".join(str(h.completed) for h in stats.hosts))
        for tier, ts in stats.tiers.items():
            extra = ""
            if ts.shed or ts.degraded:
                extra += f", {ts.shed} shed / {ts.degraded} degraded"
            if ts.hedged:
                extra += (f", {ts.hedged} hedged "
                          f"({ts.hedge_upgrades} upgrades)")
            print(f"[serve] {label}: tier {tier}: {ts.count} queries, "
                  f"recall p50/p99 {ts.recall_p50:.3f}/{ts.recall_p99:.3f}"
                  f" (predicted), latency p50/p99 {ts.latency_p50:.0f}/"
                  f"{ts.latency_p99:.0f} steps{extra}")
        if stats.tiers:
            print(f"[serve] {label}: chunk wall p50/p99 "
                  f"{stats.chunk_ms_p50:.1f}/{stats.chunk_ms_p99:.1f} ms")
        done = np.array([i for i, r in enumerate(results) if r is not None])
        if stats.truncated or len(done) < len(results):
            print(f"[serve] {label}: step budget hit: {stats.truncated} "
                  f"truncated, {len(results) - len(done)} never admitted")
        if done.size == 0:
            print(f"[serve] {label}: no queries completed — skipping "
                  f"recall report")
            return stats
        ids = np.stack([results[i][1] for i in done])
        gt_i = ground_truth()
        rec = np.asarray(flat.recall_at_k(jnp.asarray(ids),
                                          jnp.asarray(gt_i[done])))
        if monitor is not None:
            monitor.observe(ds.queries[done], r_targets[done], ids)
        for t in targets:
            sel = r_targets[done] == np.float32(t)
            if sel.any():
                print(f"[serve] {label}: target {t:.2f}: mean recall "
                      f"{rec[sel].mean():.4f} over {int(sel.sum())} queries")
            else:
                print(f"[serve] {label}: target {t:.2f}: no completed "
                      f"queries")
        return stats

    serve_phase("pre-mutation" if mutable is not None else "steady-state")

    if mutable is not None and args.online_compact:
        events = list(vectors.mutation_stream(
            ds, ins_pct, del_pct, drift=args.drift,
            steps=args.mutation_steps, seed=1))
        print(f"[serve] online mutation stream: {len(events)} events, "
              f"applied one per chunk boundary")

        def push_contents(update_base: bool) -> None:
            """Contents-only view refresh into the live server: delta
            always, base only when tombstones changed. Reuses the
            wrapper closures (and every jit cache); on a mesh the
            replacement components are re-placed with the committed
            shardings first."""
            if mesh is not None:
                view = dist.refresh_placed_view(
                    server.engine.index, mesh,
                    base=mutable.base if update_base else None,
                    delta=mutable.delta)
                eng = server.engine._replace(index=view)
            else:
                eng = mutate.refresh_view(
                    server.engine,
                    base=mutable.base if update_base else None,
                    delta=mutable.delta)
            darth.engine = eng
            server.set_engine(eng, contents_only=True)

        state = {"swapped": False, "ticks": 0}

        def trace_event(srv, kind: str, **attrs) -> None:
            """Server-level compaction span, stamped at the boundary."""
            if srv.tracer is not None:
                srv.tracer.event(kind, step=srv.boundary_step,
                                 epoch=srv.engine_epoch, **attrs)

        def on_boundary(srv) -> None:
            # one unit of mutation work per boundary; once a swap is
            # staged, do nothing until the pool drains and applies it
            if srv.swap_pending or state["swapped"]:
                return
            if events:
                ev = events.pop(0)
                mutable.apply([ev])
                push_contents(update_base=(ev.kind == "delete"))
            elif not mutable.compacting:
                mutable.begin_compaction()
                trace_event(srv, "compact_begin")
            elif mutable.compact_tick():
                state["ticks"] = mutable.compaction_ticks
                trace_event(srv, "compact_tick",
                            tick=mutable.compaction_ticks, done=True)
                mutable.swap_compaction()
                trace_event(srv, "compact_swap")
                eng = build_engine(**engine_kw)
                srv.request_swap(eng, contents_only=True)
                darth.engine = eng
                state["swapped"] = True
            else:
                trace_event(srv, "compact_tick",
                            tick=mutable.compaction_ticks, done=False)

        stats = serve_phase("online-mutation", on_boundary=on_boundary)
        if not state["swapped"]:
            # the serve phase finished before the stream / rebuild did:
            # drain the leftovers synchronously (same generator code
            # path — background and sync produce the identical shadow)
            if events:
                mutable.apply(events)
                events.clear()
            if mutable.compacting:
                while not mutable.compact_tick():
                    pass
                mutable.swap_compaction()
            else:
                mutable.compact()
            darth.engine = build_engine(**engine_kw)
            server.set_engine(darth.engine, contents_only=True)
        print(f"[serve] online compaction: {stats.swaps} atomic "
              f"swap(s) mid-serve ({state['ticks']} background ticks), "
              f"{stats.hedge_epoch_dropped} hedges dropped across "
              f"epochs; {mutable.num_live} live vectors, delta empty")
        serve_phase("post-swap")

    elif mutable is not None:
        events = vectors.mutation_stream(
            ds, ins_pct, del_pct, drift=args.drift,
            steps=args.mutation_steps, seed=1)
        mutable.apply(events)
        print(f"[serve] mutation burst applied: {mutable.num_delta} delta "
              f"inserts live, {len(mutable.deleted_ids)} tombstones, "
              f"{mutable.num_live} live vectors")
        darth.engine = build_engine(**engine_kw)
        server.set_engine(darth.engine, contents_only=True)
        serve_phase("post-burst")

        rep = monitor.drift()
        print(f"[serve] drift check over {rep.num_queries} replayed "
              f"queries: worst gap {rep.worst_gap:.4f} "
              f"({'RECALIBRATING' if rep.drifted else 'within threshold'})")
        if rep.drifted:
            t0 = time.time()
            monitor.recalibrate(ds.learn, server=server)
            print(f"[serve] predictor refit + hot-swap "
                  f"({time.time()-t0:.1f}s) "
                  f"mse={darth.trained.metrics['mse']:.5f}")
            serve_phase("post-recalibration")

        t0 = time.time()
        mutable.compact()
        darth.engine = build_engine(**engine_kw)
        server.set_engine(darth.engine, contents_only=True)
        print(f"[serve] compaction folded delta into base "
              f"({time.time()-t0:.1f}s): {mutable.num_live} live vectors, "
              f"delta empty")
        serve_phase("post-compaction")

    if tracer is not None:
        from repro.obs import explain as explain_lib
        print(f"[serve] trace: {len(tracer.last_spans)} spans in the "
              f"last phase; story of its worst-served query:")
        for line in explain_lib.explain(tracer.last_spans).splitlines():
            print(f"[serve]   {line}")
    if registry is not None:
        import os
        out_dir = args.trace if args.trace is not None else "results"
        os.makedirs(out_dir, exist_ok=True)
        prom = os.path.join(out_dir, "metrics.prom")
        events_path = os.path.join(out_dir, "events.jsonl")
        registry.write_prometheus(prom)
        registry.write_events(events_path, append=False)
        served = registry.counter("darth_queries_total")
        print(f"[serve] metrics -> {prom} (+ {events_path}): "
              f"{int(sum(served.values.values()))} query outcomes, "
              f"{len(registry.events)} events")

    if mesh is not None:
        # HLO collective-traffic report only — compile, don't execute
        # (the ground-truth scans above already ran through the cached
        # sharded path).
        sfn = dist.make_sharded_flat_search(mesh, args.k)
        q_dev, x_dev = jnp.asarray(ds.queries), jnp.asarray(ds.base)
        compiled = sfn.lower(q_dev, x_dev).compile()
        coll = hlo_lib.collective_bytes(compiled.as_text())
        print(f"[serve] sharded ground truth: "
              f"{coll['total']/1e3:.1f} kB collectives "
              f"({coll['num_ops']:.0f} ops) per batch")


if __name__ == "__main__":
    main()
