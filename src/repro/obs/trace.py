"""Per-query trace spans + the device-side recall-trajectory ring.

Every query admitted into the slot-pool server leaves a story: where it
was admitted (host / slot / epoch / tier), which scheduling events it
crossed (refill splices, hedge launches, queue steals, hot-swaps), how
its predicted recall evolved per engine step, and WHY it terminated.
This module is the host half of that story:

  * ``Span`` — one structured record. Event spans mark lifecycle edges
    (``admit``, ``hedge_launch``, ``steal``, ``swap_staged``,
    ``swap_applied``, ``compact_begin``/``compact_swap``, ...);
    terminal spans (kind ``"terminal"``) close a query exactly once
    with a ``reason`` from TERMINATION_REASONS and the per-step
    predicted-recall trajectory.
  * ``Tracer`` — the in-memory span sink a DarthServer writes through
    (serve.engine threads it through admission / harvest / swap /
    steal), flushed as JSONL at the end of each serve call.
  * ``traj_init`` / ``traj_record`` — the DEVICE side: a fixed-shape
    ``f32[slots, traj_cap]`` ring carried through the serving chunk
    jits. Each engine step writes every slot's current predicted recall
    at column ``(step - 1) % traj_cap``; the host drains the ring only
    at chunk boundaries (where serve() already syncs for the active
    mask), so tracing adds ZERO extra device<->host sync points and the
    ring's fixed shape adds no retraces. The slot dim leads, so
    dist.sharding.constrain_slots pins it host-local exactly like the
    rest of the chunk carry.

Termination-reason taxonomy (docs/observability.md):

  * ``interval_met``      — the predictor's recall estimate reached the
                            declared (effective) target: DARTH stopped
                            the slot early (DarthState.early).
  * ``engine_exhausted``  — the engine hit its natural step limit
                            (nprobe / beam budget) before the interval
                            fired; the result is still a full top-k.
  * ``budget_truncated``  — serve()'s max_engine_steps ran out with the
                            query in flight; partial top-k harvested.
  * ``host_killed``       — fault injection killed the owning host; the
                            in-flight partial top-k was harvested.
  * ``shed``              — refused at admission control (overload
                            policy "shed"); never held a slot.
  * ``abandoned``         — queued but never admitted (its host died,
                            or the step budget ended first).

``degraded`` admission (overload policy "degrade") is NOT a terminal
reason — a degraded query still terminates through one of the reasons
above, at a lowered target; its terminal span carries
``degraded: true`` so the lowered contract stays attributable.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

TERMINATION_REASONS = ("interval_met", "engine_exhausted",
                       "budget_truncated", "host_killed", "shed",
                       "abandoned")

#: trajectory entries before the predictor's first firing (r_pred's
#: "never called" sentinel; mirrors DarthState.r_pred's init value)
NO_PREDICTION = -1.0


# ---------------------------------------------------------------------------
# Device side: the per-slot predicted-recall ring
# ---------------------------------------------------------------------------

def traj_init(num_slots: int, traj_cap: int) -> jnp.ndarray:
    """Fresh trajectory ring f32[num_slots, traj_cap], NO_PREDICTION
    everywhere (jit-safe: shape is static, contents constant-folded)."""
    return jnp.full((num_slots, traj_cap), NO_PREDICTION, jnp.float32)


def traj_record(traj: jnp.ndarray, steps: jnp.ndarray,
                r_pred: jnp.ndarray) -> jnp.ndarray:
    """Record every slot's current predicted recall after chunk step
    ``steps`` (the scalar step counter AFTER the step ran, so step g
    lands at column (g-1) % cap). Fixed-shape dynamic-index write: no
    retrace across steps, no host sync."""
    col = (steps - 1) % traj.shape[1]
    return traj.at[:, col].set(r_pred)


def traj_window(row: np.ndarray, admit_step: int, harvest_step: int,
                base: int) -> Tuple[List[float], bool]:
    """Host-side drain: one slot's trajectory between its admission and
    harvest, unrolled by the ring cursor so values come out oldest
    first regardless of how many times the ring wrapped. ``base`` is
    the engine-step count when the ring's chunk state was
    (re)initialized (ring columns count from there).

    Returns ``(values, truncated)``. Windows longer than the ring keep
    only the most recent ``cap`` entries — the ring overwrote the older
    prefix in place — and report ``truncated=True`` so consumers (the
    explain sparkline, the trajectory-final == harvested ``r_pred``
    invariant checks) know the series is a suffix, not the full life
    of the query."""
    cap = row.shape[0]
    lo = admit_step - base
    hi = harvest_step - base
    truncated = (hi - lo) > cap
    lo = max(lo, hi - cap)
    if hi <= lo:
        return [], False
    cols = np.arange(lo, hi) % cap
    return [float(v) for v in row[cols]], truncated


# ---------------------------------------------------------------------------
# Host side: spans + tracer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Span:
    """One trace record (event edge or terminal close-out).

    ``qid`` is the query id (-1 for server-level events: swaps,
    compaction lifecycle). ``seq`` is the tracer's monotonic order —
    wall clocks never enter spans, so traces are deterministic and
    replayable. ``step`` is the global engine-step count at emission;
    ``epoch`` the server's engine/predictor version."""
    seq: int
    serve: int
    kind: str
    qid: int = -1
    host: int = -1
    step: int = 0
    epoch: int = 0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        """Flat JSONL payload (attrs inlined, stable field order)."""
        out = {"seq": self.seq, "serve": self.serve, "kind": self.kind,
               "qid": self.qid, "host": self.host, "step": self.step,
               "epoch": self.epoch}
        out.update(self.attrs)
        return out


class Tracer:
    """Span sink for one DarthServer (one serve call at a time).

    Construction-time ``traj_cap`` sizes the device ring — it is part
    of the chunk jits' shapes, so it is fixed per server (the server
    builds its traced chunks against it). ``path``, when set, appends
    every finished serve's spans as JSONL; spans also stay available
    in-memory (``last_spans``) for programmatic access and tests.

    Exactly-once terminal contract: ``terminal()`` raises on a second
    terminal for the same qid; the one sanctioned mutation is
    ``upgrade_terminal`` (a hedge's deeper result replacing its
    primary's — still one terminal span, now marked upgraded)."""

    def __init__(self, path: Optional[str] = None, *, traj_cap: int = 64,
                 label: str = ""):
        if traj_cap < 1:
            raise ValueError(f"traj_cap must be >= 1, got {traj_cap}")
        self.path = path
        self.traj_cap = int(traj_cap)
        self.label = label
        self.serve_id = 0
        self._seq = 0
        self._events: List[Span] = []
        self._terminal: Dict[int, Span] = {}
        self.last_spans: List[Span] = []

    # -- lifecycle ---------------------------------------------------------
    def begin(self, label: Optional[str] = None) -> None:
        """Start a new serve's trace (serve.engine calls this at the top
        of every serve(); the previous serve's spans stay in
        ``last_spans`` until the next finish)."""
        self.serve_id += 1
        if label is not None:
            self.label = label
        self._events = []
        self._terminal = {}

    def finish(self) -> List[Span]:
        """Close the serve: order spans, append to ``path`` (JSONL) when
        set, return them (also kept as ``last_spans``)."""
        spans = sorted(self._events + list(self._terminal.values()),
                       key=lambda s: s.seq)
        self.last_spans = spans
        if self.path is not None:
            with open(self.path, "a") as f:
                for s in spans:
                    f.write(json.dumps(s.to_dict(), default=float) + "\n")
        return spans

    # -- span emission -----------------------------------------------------
    def _next(self) -> int:
        self._seq += 1
        return self._seq

    def event(self, kind: str, *, qid: int = -1, host: int = -1,
              step: int = 0, epoch: int = 0, **attrs) -> Span:
        """Emit one lifecycle-edge span."""
        if self.label:
            attrs.setdefault("label", self.label)
        sp = Span(seq=self._next(), serve=self.serve_id, kind=kind,
                  qid=qid, host=host, step=step, epoch=epoch, attrs=attrs)
        self._events.append(sp)
        return sp

    def terminal(self, qid: int, reason: str, *, host: int = -1,
                 step: int = 0, epoch: int = 0, **attrs) -> Span:
        """Close query ``qid`` with a terminal span (exactly once)."""
        if reason not in TERMINATION_REASONS:
            raise ValueError(f"unknown termination reason {reason!r} "
                             f"(taxonomy: {TERMINATION_REASONS})")
        if qid in self._terminal:
            raise RuntimeError(
                f"query {qid} already has a terminal span "
                f"({self._terminal[qid].attrs.get('reason')!r}); a second "
                f"termination ({reason!r}) breaks the exactly-once trace "
                f"contract")
        if self.label:
            attrs.setdefault("label", self.label)
        attrs["reason"] = reason
        sp = Span(seq=self._next(), serve=self.serve_id, kind="terminal",
                  qid=qid, host=host, step=step, epoch=epoch, attrs=attrs)
        self._terminal[qid] = sp
        return sp

    def upgrade_terminal(self, qid: int, *, step: int, **attrs) -> Span:
        """Replace qid's terminal payload with a hedge's deeper result
        (the one sanctioned terminal mutation; marks ``upgraded``)."""
        sp = self._terminal[qid]
        sp.attrs.update(attrs)
        sp.attrs["upgraded"] = True
        sp.step = step
        return sp

    # -- introspection (tests / explain) -----------------------------------
    def terminals(self) -> Dict[int, Span]:
        """qid -> terminal span for the serve in progress (or just
        finished, before the next begin)."""
        return dict(self._terminal)


def load_trace(path: str, serve: Optional[int] = None) -> List[Dict]:
    """Read a JSONL trace file back into span dicts; ``serve`` filters
    to one serve call's spans (default: the LAST serve in the file)."""
    spans: List[Dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                spans.append(json.loads(line))
    if not spans:
        return spans
    if serve is None:
        serve = max(s.get("serve", 0) for s in spans)
    return [s for s in spans if s.get("serve", 0) == serve]


__all__ = ["Span", "Tracer", "TERMINATION_REASONS", "NO_PREDICTION",
           "traj_init", "traj_record", "traj_window", "load_trace"]
