"""Metrics registry: counters / gauges / histograms + two exporters.

Host-side aggregation for the serving stack (DarthServer, the drift
monitor, the compaction lifecycle). Metrics are named following the
Prometheus conventions (``darth_<noun>_<unit>`` with ``_total`` counter
suffixes) and label sets are free-form keyword arguments; every metric
family is exported two ways:

  * ``to_prometheus()`` — the text exposition format (one scrapeable
    page: ``# HELP`` / ``# TYPE`` headers, ``name{labels} value``
    samples, histogram ``_bucket``/``_sum``/``_count`` series with
    fixed, pre-declared bucket edges so series never churn);
  * ``events`` + ``write_events()`` — an append-only JSONL event log
    for discrete occurrences (drift checks, recalibrations, compaction
    begin/tick/swap, hot-swaps) that a histogram would flatten.

Histograms keep fixed bucket edges (cumulative ``le`` counts) AND the
raw samples, so percentile summaries go through the one shared helper
(obs.stats) instead of bucket interpolation. Registries are cheap and
in-process; there is no global default — each server / monitor /
launcher owns the instance it is handed.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import stats as stats_lib

# Fixed default edges (milliseconds / engine steps / recall). Fixed at
# declaration so the exported bucket series are stable across runs —
# the overhead contract (docs/observability.md) depends on bucket
# bounds never being data-derived.
LATENCY_MS_EDGES = (0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                    500.0, 1000.0, 2500.0)
STEP_EDGES = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0)
RECALL_EDGES = (0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 0.99, 1.0)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(key: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


@dataclasses.dataclass
class Counter:
    """Monotonic counter family (one value per label set)."""
    name: str
    help: str
    values: Dict[Tuple, float] = dataclasses.field(default_factory=dict)

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add ``amount`` (must be >= 0) to the labelled series."""
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        key = _label_key(labels)
        self.values[key] = self.values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        """Current value of the labelled series (0 if never touched)."""
        return self.values.get(_label_key(labels), 0.0)


@dataclasses.dataclass
class Gauge:
    """Set-to-current-value family (one value per label set)."""
    name: str
    help: str
    values: Dict[Tuple, float] = dataclasses.field(default_factory=dict)

    def set(self, value: float, **labels) -> None:
        """Set the labelled series to ``value``."""
        self.values[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        """Current value of the labelled series (NaN if never set)."""
        return self.values.get(_label_key(labels), float("nan"))


@dataclasses.dataclass
class Histogram:
    """Fixed-bucket histogram family.

    ``edges`` are the upper bounds of the cumulative ``le`` buckets (a
    final +Inf bucket is implicit). Raw samples are retained per label
    set so p50/p99 summaries use obs.stats — bucket interpolation would
    re-introduce exactly the small-sample tail bias that helper fixes.
    """
    name: str
    help: str
    edges: Tuple[float, ...]
    samples: Dict[Tuple, List[float]] = dataclasses.field(
        default_factory=dict)

    def observe(self, value: float, **labels) -> None:
        """Record one sample into the labelled series."""
        self.samples.setdefault(_label_key(labels), []).append(float(value))

    def count(self, **labels) -> int:
        """Number of samples observed by the labelled series."""
        return len(self.samples.get(_label_key(labels), ()))

    def summary(self, **labels) -> Tuple[float, float]:
        """(p50, p99) of the raw samples via the shared helper."""
        return stats_lib.summarize(self.samples.get(_label_key(labels), ()))


class MetricsRegistry:
    """One process-local metrics surface: typed families + event log."""

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}
        #: discrete occurrences, in order (drift checks, swaps, ...)
        self.events: List[Dict] = []
        self._clock = 0

    def _declare(self, cls, name: str, help_: str, **kw):
        cur = self._metrics.get(name)
        if cur is not None:
            if not isinstance(cur, cls):
                raise TypeError(
                    f"metric {name!r} already declared as "
                    f"{type(cur).__name__}, not {cls.__name__}")
            return cur
        m = cls(name=name, help=help_, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        """Get or declare a counter family."""
        return self._declare(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get or declare a gauge family."""
        return self._declare(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  edges: Sequence[float] = LATENCY_MS_EDGES) -> Histogram:
        """Get or declare a fixed-bucket histogram family."""
        h = self._declare(Histogram, name, help,
                          edges=tuple(float(e) for e in edges))
        return h

    def event(self, kind: str, **fields) -> Dict:
        """Append one discrete occurrence to the JSONL event log."""
        self._clock += 1
        ev = {"seq": self._clock, "kind": kind, **fields}
        self.events.append(ev)
        return ev

    # -- export ------------------------------------------------------------
    def to_prometheus(self) -> str:
        """Text exposition format (the scrape page)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(m)]
            lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, Histogram):
                for key, xs in sorted(m.samples.items()):
                    total = 0
                    for edge in m.edges + (float("inf"),):
                        total = sum(1 for x in xs if x <= edge)
                        le = 'le="' + _fmt_value(edge) + '"'
                        lines.append(
                            f"{name}_bucket"
                            f"{_fmt_labels(key, le)} {total}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(key)} "
                        f"{_fmt_value(sum(xs))}")
                    lines.append(
                        f"{name}_count{_fmt_labels(key)} {len(xs)}")
            else:
                for key, v in sorted(m.values.items()):
                    lines.append(f"{name}{_fmt_labels(key)} {_fmt_value(v)}")
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path: str) -> None:
        """Write the exposition page to ``path``."""
        with open(path, "w") as f:
            f.write(self.to_prometheus())

    def write_events(self, path: str, append: bool = True) -> None:
        """Write the event log as JSONL (one event per line)."""
        with open(path, "a" if append else "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev, default=float) + "\n")


def serve_metrics(registry: Optional[MetricsRegistry]
                  ) -> Optional[MetricsRegistry]:
    """Pre-declare the serving metric families on ``registry`` (no-op on
    None) so exposition pages show every family even before traffic.

    The naming contract (docs/observability.md): queries are counted
    once per terminal outcome under ``darth_queries_total{outcome=..}``,
    chunk round-trips land in ``darth_chunk_latency_ms``, harvest-time
    predicted recall in ``darth_harvest_recall`` and admission→harvest
    service time in ``darth_service_steps``.
    """
    if registry is None:
        return None
    registry.counter("darth_queries_total",
                     "queries by terminal outcome (termination reason)")
    registry.counter("darth_refills_total", "refill splices per host")
    registry.counter("darth_hedges_total", "hedge duplicates launched")
    registry.counter("darth_swaps_total",
                     "drained atomic hot-swaps applied mid-serve")
    registry.counter("darth_steals_total",
                     "queue entries stolen between hosts")
    registry.counter("darth_sq8_clipped_total",
                     "SQ8 values clamped to the frozen base range "
                     "during delta re-quantization")
    registry.counter("darth_cold_prefetch_total",
                     "cold IVF buckets staged into device slots ahead "
                     "of their probe turn")
    registry.counter("darth_cold_evictions_total",
                     "resident buckets evicted to make room for "
                     "prefetched cold buckets")
    registry.counter("darth_cold_miss_total",
                     "probes that resolved cold and were skipped "
                     "(bucket not resident in time)")
    registry.histogram("darth_chunk_latency_ms",
                       "per-chunk device round-trip wall time",
                       edges=LATENCY_MS_EDGES)
    registry.histogram("darth_harvest_recall",
                       "predicted recall at harvest",
                       edges=RECALL_EDGES)
    registry.histogram("darth_service_steps",
                       "engine steps from admission to harvest",
                       edges=STEP_EDGES)
    registry.gauge("darth_engine_epoch",
                   "engine/predictor version of the serving view")
    return registry


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "serve_metrics", "LATENCY_MS_EDGES", "STEP_EDGES",
           "RECALL_EDGES"]
