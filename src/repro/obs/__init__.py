"""repro.obs — tracing, metrics export and termination explainability.

The observability layer of the serving stack (docs/observability.md):

  * ``obs.trace``   — per-query lifecycle spans + the device-side
                      predicted-recall trajectory ring the serve chunk
                      jits carry (zero extra syncs, no retraces);
  * ``obs.metrics`` — counters / gauges / fixed-bucket histograms with
                      Prometheus text exposition and a JSONL event log;
  * ``obs.explain`` — reconstruct any query's story from a trace
                      (``python -m repro.obs.explain``);
  * ``obs.stats``   — the one shared p50/p99 percentile helper
                      (conservative tails, NaN on empty).
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               serve_metrics)
from repro.obs.stats import p01, p50, p99, percentile, summarize
from repro.obs.trace import (NO_PREDICTION, TERMINATION_REASONS, Span,
                             Tracer, load_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "serve_metrics",
    "p01", "p50", "p99", "percentile", "summarize",
    "NO_PREDICTION", "TERMINATION_REASONS", "Span", "Tracer", "load_trace",
]
