"""Shared percentile math for every serving-stats surface.

One definition of "p50/p99" used by ServeStats, TierStats, the obs
histograms and the benchmark gates, fixing two edge cases the ad-hoc
``np.percentile`` calls had:

  * empty sample sets returned an exception path (or were guarded
    inconsistently at each call site) — here they are NaN, always;
  * small samples were linearly interpolated, which is the WRONG
    direction for an SLO tail: with 2 chunk latencies, linear p99 sits
    just under the max, under-reporting the tail, and the 1st-percentile
    recall sits just above the min, over-reporting the worst query.
    Tail percentiles here round conservatively — away from the median —
    so a single sample IS its own p99 and a 2-sample p99 is the max.

Interior percentiles (the median) keep linear interpolation: there is
no conservative direction for a central tendency.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def percentile(xs: Sequence[float], q: float) -> float:
    """Percentile with NaN-on-empty and conservative tail rounding.

    ``q`` is in [0, 100]. Above the median the value rounds UP to an
    observed sample ("higher"), below the median it rounds DOWN
    ("lower"), so tail estimates never interpolate past the worst
    observation toward the center. q == 50 is the linearly interpolated
    median. Empty input returns NaN instead of raising.
    """
    xs = np.asarray(xs, np.float64).reshape(-1)
    xs = xs[np.isfinite(xs)]
    if xs.size == 0:
        return float("nan")
    method = "higher" if q > 50 else ("lower" if q < 50 else "linear")
    return float(np.percentile(xs, q, method=method))


def p50(xs: Sequence[float]) -> float:
    """Median (linear interpolation; NaN on empty)."""
    return percentile(xs, 50)


def p99(xs: Sequence[float]) -> float:
    """Conservative upper-tail p99: rounds up to an observed sample, so
    1 sample is its own p99 and 2 samples give the max (NaN on empty)."""
    return percentile(xs, 99)


def p01(xs: Sequence[float]) -> float:
    """Conservative lower-tail 1st percentile (the "worst 1%" recall
    convention): rounds DOWN to an observed sample (NaN on empty)."""
    return percentile(xs, 1)


def summarize(xs: Sequence[float]) -> tuple:
    """(p50, p99) with the shared conventions — the pair every stats
    surface reports."""
    return p50(xs), p99(xs)


__all__ = ["percentile", "p50", "p99", "p01", "summarize"]
