"""Termination explainability: reconstruct one query's story.

``explain()`` folds a query's spans — admission, scheduling events it
crossed (hedges, steals, hot-swaps, compaction), the per-step
predicted-recall trajectory and the terminal reason — into a short
human-readable narrative, answering the question coarse aggregates
cannot: "why did query 714 terminate at step 12 with predicted recall
0.91?".

CLI::

    python -m repro.obs.explain TRACE.jsonl --qid 714
    python -m repro.obs.explain TRACE.jsonl --summary
    python -m repro.obs.explain TRACE.jsonl            # worst query

Input is the JSONL trace a ``Tracer(path=...)`` appends per serve call
(the last serve in the file by default; ``--serve N`` selects another).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence, Union

from repro.obs import stats as stats_lib
from repro.obs import trace as trace_lib

_SERVER_EVENT_KINDS = ("swap_staged", "swap_applied", "compact_begin",
                       "compact_tick", "compact_swap", "drift", "recal")


def _as_dicts(spans: Sequence) -> List[Dict]:
    return [s.to_dict() if hasattr(s, "to_dict") else dict(s)
            for s in spans]


def _sparkline(traj: Sequence[float]) -> str:
    """Unicode mini-plot of a recall trajectory (pre-prediction steps
    render as '.')."""
    blocks = "▁▂▃▄▅▆▇█"
    out = []
    for v in traj:
        if v < 0:
            out.append(".")
        else:
            out.append(blocks[min(int(v * len(blocks)), len(blocks) - 1)])
    return "".join(out)


def query_story(spans: Sequence, qid: int) -> Dict:
    """Structured story for one query: its spans split into admission /
    events / terminal, plus the server-level events that overlapped its
    flight window. Raises KeyError when the trace holds no terminal
    span for ``qid`` (an un-traced or unknown query)."""
    spans = _as_dicts(spans)
    mine = [s for s in spans if s.get("qid") == qid]
    term = next((s for s in mine if s.get("kind") == "terminal"), None)
    if term is None:
        raise KeyError(f"query {qid}: no terminal span in trace "
                       f"({len(mine)} event spans)")
    admit = [s for s in mine if s.get("kind") == "admit"]
    events = [s for s in mine if s.get("kind") not in ("terminal",)]
    lo = min((s["step"] for s in admit), default=0)
    hi = term.get("step", lo)
    crossed = [s for s in spans
               if s.get("qid", -1) < 0
               and s.get("kind") in _SERVER_EVENT_KINDS
               and lo <= s.get("step", -1) <= hi]
    return {"qid": qid, "terminal": term, "admissions": admit,
            "events": events, "crossed": crossed}


def explain(trace: Union[str, Sequence], qid: Optional[int] = None,
            serve: Optional[int] = None) -> str:
    """Human-readable story for one query (default: the worst-served
    query — lowest final predicted recall among terminals). ``trace``
    is a JSONL path or an in-memory span sequence."""
    spans = (trace_lib.load_trace(trace, serve=serve)
             if isinstance(trace, str) else _as_dicts(trace))
    terms = [s for s in spans if s.get("kind") == "terminal"
             and s.get("qid", -1) >= 0]
    if not terms:
        return "trace holds no terminal spans (nothing was served?)"
    if qid is None:
        served = [t for t in terms if t.get("r_pred") is not None]
        pick = min(served or terms,
                   key=lambda t: t.get("r_pred", float("inf")))
        qid = pick["qid"]
    story = query_story(spans, qid)
    term = story["terminal"]
    reason = term.get("reason", "?")
    lines = [f"query {qid}: {reason}"]

    for s in story["admissions"]:
        tgt = s.get("target", float("nan"))
        eff = s.get("effective_target", tgt)
        what = "hedge duplicate" if s.get("hedge") else "admitted"
        boost = (f" (boosted to {eff:.2f})"
                 if eff is not None and tgt is not None and eff > tgt
                 else "")
        lines.append(
            f"  step {s['step']:>4}: {what} on host {s['host']} "
            f"slot {s.get('slot', '?')} epoch {s['epoch']}, declared "
            f"target {tgt:.2f}{boost}"
            + (f" [tier {s['tier']}]" if s.get("tier") else ""))
    for s in story["events"]:
        if s["kind"] in ("admit",):
            continue
        lines.append(f"  step {s['step']:>4}: {s['kind']}"
                     + (f" ({s.get('cause')})" if s.get("cause") else ""))
    for s in story["crossed"]:
        lines.append(f"  step {s['step']:>4}: [server] {s['kind']} "
                     f"(epoch {s['epoch']})")

    traj = term.get("trajectory") or []
    if traj:
        fired = sum(1 for i in range(1, len(traj))
                    if traj[i] != traj[i - 1]) + (1 if traj[0] >= 0 else 0)
        # A query that outlived the ring keeps only the newest cap
        # steps — a leading "…" marks the overwritten prefix so the
        # sparkline is never mistaken for the query's full life.
        trunc = "…" if term.get("trajectory_truncated") else ""
        total = term.get("step", 0) - term.get("admit_step", 0)
        label = (f"last {len(traj)} of {total} steps" if trunc
                 else f"{len(traj)} steps")
        lines.append(
            f"  trajectory ({label}, predictor fired on "
            f"{term.get('npred', fired)} of them): {trunc}{_sparkline(traj)}")
    rp = term.get("r_pred")
    eff = term.get("effective_target", term.get("target"))
    if reason == "interval_met" and rp is not None and eff is not None:
        lines.append(
            f"  step {term['step']:>4}: predicted recall {rp:.3f} "
            f"crossed the effective target {eff:.2f} -> early stop "
            f"(interval #{term.get('npred', '?')} fired, "
            f"ndis={term.get('ndis', '?')})")
    elif rp is not None:
        lines.append(
            f"  step {term['step']:>4}: terminal predicted recall "
            f"{rp:.3f}"
            + (f" vs target {eff:.2f}" if eff is not None else "")
            + f" (reason: {reason}, ndis={term.get('ndis', '?')})")
    else:
        lines.append(f"  closed without holding a slot (reason: {reason})")
    if term.get("upgraded"):
        lines.append("  result was UPGRADED by a deeper hedge duplicate")
    if term.get("degraded"):
        lines.append("  target was DEGRADED at admission (overload)")
    return "\n".join(lines)


def summary(trace: Union[str, Sequence],
            serve: Optional[int] = None) -> str:
    """One-paragraph rollup: terminal-reason counts + final predicted
    recall and service-step percentiles through the shared helper."""
    spans = (trace_lib.load_trace(trace, serve=serve)
             if isinstance(trace, str) else _as_dicts(trace))
    terms = [s for s in spans if s.get("kind") == "terminal"
             and s.get("qid", -1) >= 0]
    by_reason: Dict[str, int] = {}
    for t in terms:
        by_reason[t.get("reason", "?")] = by_reason.get(
            t.get("reason", "?"), 0) + 1
    rp = [t["r_pred"] for t in terms if t.get("r_pred") is not None]
    svc = [t["step"] - t["admit_step"] for t in terms
           if t.get("admit_step") is not None]
    lines = [f"{len(terms)} queries, "
             + ", ".join(f"{k}={v}" for k, v in sorted(by_reason.items()))]
    if rp:
        lines.append(f"final predicted recall p50/p99 "
                     f"{stats_lib.p50(rp):.3f}/{stats_lib.p01(rp):.3f} "
                     f"(p99 = worst 1%)")
    if svc:
        lines.append(f"service steps p50/p99 "
                     f"{stats_lib.p50(svc):.0f}/{stats_lib.p99(svc):.0f}")
    nevents = sum(1 for s in spans if s.get("kind") != "terminal")
    lines.append(f"{nevents} event spans "
                 f"({sum(1 for s in spans if s.get('qid', -1) < 0)} "
                 f"server-level)")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    """CLI entry point (``python -m repro.obs.explain``)."""
    ap = argparse.ArgumentParser(
        description="Reconstruct a query's story from a serve trace")
    ap.add_argument("trace", help="JSONL trace file (Tracer path=...)")
    ap.add_argument("--qid", type=int, default=None,
                    help="query id to explain (default: worst final "
                         "predicted recall)")
    ap.add_argument("--serve", type=int, default=None,
                    help="serve call to read (default: last in file)")
    ap.add_argument("--summary", action="store_true",
                    help="print the whole serve's rollup instead")
    args = ap.parse_args(argv)
    if args.summary:
        print(summary(args.trace, serve=args.serve))
    else:
        print(explain(args.trace, qid=args.qid, serve=args.serve))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
