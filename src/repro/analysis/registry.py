"""Entry-point registry: what the HLO passes get to look at.

An entry point is a builder that fabricates a small, self-contained
instance of one of the repo's jitted programs (an engine step, a fused
kernel, the DarthServer chunk jits) at a requested size, so the gate
can lower + compile the REAL code paths without datasets or trained
models — trace-time analysis only needs the program structure.

Builders register with the @register decorator (repro.analysis.manifest
holds them all); the runner skips entries whose `min_devices` exceeds
the visible device count, so the same manifest serves the 1-device
tier-1 fixture and the forced-multidevice CI gate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

#: size label -> (num index rows, dim). The pair varies N ONLY: pass 3
#: asserts collective bytes do not scale with the database size. D is
#: held fixed because one-time init collectives legitimately move
#: vector-sized (D-scaled) payloads — route/entry resolution — and
#: that is not the bug class; index rows crossing the interconnect is.
SIZES: Dict[str, Tuple[int, int]] = {
    "small": (2048, 16),
    "large": (8192, 16),
}


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One registered jitted program.

    `build(size)` returns (jitted_fn, args) ready for
    `jitted_fn.lower(*args)` — built under the entry's own mesh, which
    the builder derives from the CURRENT visible device count.
    `check`, when set instead, is an executable pass (the retrace
    audit) returning Findings directly; such entries skip the HLO
    passes.

    `resident_sq8` marks entries whose builders serve the compact
    SQ8-resident index format: the resident-bytes pass then asserts
    every N-scaled vector payload entering the compiled program is
    int8-width (and that at least one int8 payload exists), so a
    manifest regression back to f32 residency fails the gate."""
    name: str
    build: Optional[Callable[[str], Tuple[Any, tuple]]] = None
    check: Optional[Callable[[], List[Any]]] = None
    min_devices: int = 1
    resident_sq8: bool = False


_REGISTRY: Dict[str, EntryPoint] = {}


def register(name: str, *, min_devices: int = 1, check: bool = False,
             resident_sq8: bool = False):
    """Decorator: register a builder (or, with check=True, an
    executable audit) under `name`."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"duplicate entry point {name!r}")
        _REGISTRY[name] = (EntryPoint(name, check=fn,
                                      min_devices=min_devices)
                           if check else
                           EntryPoint(name, build=fn,
                                      min_devices=min_devices,
                                      resident_sq8=resident_sq8))
        return fn
    return deco


def entry_points() -> List[EntryPoint]:
    """All registered entries (manifest import populates the registry)."""
    from repro.analysis import manifest  # noqa: F401  (registration)
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]
