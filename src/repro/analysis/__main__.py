"""CLI: `python -m repro.analysis --gate [--selftest] [--json PATH]`.

Forces a multidevice CPU (XLA_FLAGS) BEFORE jax initialises — the
sharding passes are vacuous at 1 device — then runs the gate and exits
non-zero on any finding. --selftest additionally loads the known-bad
corpus (tests/analysis_corpus) and fails unless every historical bug
repro is DETECTED, so a pass regression cannot silently turn the gate
green.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys
from typing import List


def _force_devices(n: int) -> None:
    flag = f"--xla_force_host_platform_device_count={n}"
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in prev:
        os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()


def _corpus_dir() -> str:
    from repro.analysis.runner import SRC_ROOT
    return os.path.join(os.path.dirname(SRC_ROOT), "tests",
                        "analysis_corpus")


def _load_corpus_module(path: str):
    name = "analysis_corpus_" + os.path.basename(path)[:-3]
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def run_selftest() -> List[str]:
    """Compile each corpus repro and demand its expected pass fires
    with a file:line finding inside the corpus file. Returns error
    strings (empty = all detected)."""
    import jax

    from repro.analysis import hlo_passes

    # Two-HLO detectors compare a small and a large build (the corpus
    # module then also defines build_bad_large()); the rest see one.
    detectors = {
        "replicated-constant": hlo_passes.replicated_constants,
        "unpartitionable-topk": hlo_passes.unpartitionable_topk,
        "resident-bytes": hlo_passes.resident_bytes,
    }
    two_hlo = {"resident-bytes"}
    errors: List[str] = []
    corpus = _corpus_dir()
    if not os.path.isdir(corpus):
        return [f"corpus directory missing: {corpus}"]
    names = [n for n in sorted(os.listdir(corpus))
             if n.endswith(".py") and not n.startswith("_")]
    if not names:
        return [f"no corpus modules under {corpus}"]
    for name in names:
        path = os.path.join(corpus, name)
        mod = _load_corpus_module(path)
        if getattr(mod, "MIN_DEVICES", 1) > jax.device_count():
            print(f"selftest SKIP {name} (needs >= {mod.MIN_DEVICES} "
                  f"devices)")
            continue
        fn, args = mod.build_bad()
        hlo = fn.lower(*args).compile().as_text()
        if mod.EXPECT_PASS in two_hlo:
            fn_l, args_l = mod.build_bad_large()
            hlo_l = fn_l.lower(*args_l).compile().as_text()
            found = detectors[mod.EXPECT_PASS](f"corpus/{name}", hlo,
                                               hlo_l)
        else:
            found = detectors[mod.EXPECT_PASS](f"corpus/{name}", hlo)
        located = [f for f in found
                   if f.file and os.path.basename(f.file) == name
                   and f.line]
        if not found:
            errors.append(f"{name}: {mod.EXPECT_PASS} did NOT fire on "
                          f"the known-bad repro")
        elif not located:
            errors.append(f"{name}: {mod.EXPECT_PASS} fired but "
                          f"without a file:line anchor into the repro")
        else:
            print(f"selftest ok: {name} -> {located[0].location()}")
    return errors


def main(argv=None) -> int:
    """Parse args, pin the device count, run gate and/or selftest."""
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="trace-time SPMD lint gate (docs/static_analysis.md)")
    p.add_argument("--gate", action="store_true",
                   help="run all passes over the registered entry points")
    p.add_argument("--selftest", action="store_true",
                   help="require the known-bad corpus to be detected")
    p.add_argument("--devices", type=int, default=4,
                   help="forced CPU device count (before jax init; "
                        "default 4, no-op if XLA_FLAGS already forces)")
    p.add_argument("--json", metavar="PATH",
                   help="also write findings + selftest errors as JSON")
    args = p.parse_args(argv)
    if not (args.gate or args.selftest):
        p.error("nothing to do: pass --gate and/or --selftest")

    if args.devices > 0:
        _force_devices(args.devices)

    from repro.analysis.findings import format_findings
    from repro.analysis.runner import run_gate

    findings = run_gate() if args.gate else []
    selftest_errors = run_selftest() if args.selftest else []

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"findings": [x.to_dict() for x in findings],
                       "selftest_errors": selftest_errors}, f, indent=2)

    if findings:
        print(format_findings(findings))
    for e in selftest_errors:
        print(f"selftest FAIL: {e}")
    ok = not findings and not selftest_errors
    if args.gate:
        print(f"gate: {len(findings)} finding(s)")
    if ok:
        print("analysis gate: OK")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
