"""Finding: one gate failure, with a file:line anchor when the pass
recovered one (HLO op metadata or an AST node)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding. `entry` is the registered entry-point name for
    program-level passes and "tree" for source-level ones; file/line
    point at the offending source when the pass could recover them
    (HLO `metadata={source_file= source_line=}` or the AST node)."""
    pass_name: str
    entry: str
    message: str
    file: Optional[str] = None
    line: Optional[int] = None

    def location(self) -> str:
        """`file:line` when known, else the entry-point name."""
        if self.file:
            return f"{self.file}:{self.line}" if self.line else self.file
        return self.entry

    def to_dict(self) -> dict:
        """JSON-friendly form (the --json report)."""
        return dataclasses.asdict(self)


def format_findings(findings: List[Finding]) -> str:
    """Render findings one per line, `location: [pass/entry] message`."""
    lines = []
    for f in findings:
        lines.append(f"{f.location()}: [{f.pass_name}/{f.entry}] "
                     f"{f.message}")
    return "\n".join(lines)
