"""Passes 1-3 — compiled-HLO text analysis (pure regex, no jax).

Built on repro.utils.hlo's parsing machinery (shape-bytes, computation
splitting, trip-count-weighted collective accounting). Each pass takes
compiled HLO text (`jit(f).lower(*args).compile().as_text()`) and
returns Findings anchored at the `metadata={source_file= source_line=}`
XLA carries for every instruction, so a gate failure points at the
Python line that built the bad op.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding
from repro.utils.hlo import (COLLECTIVES, _find_entry, _shape_bytes,
                             _split_computations, collective_bytes)

#: Constants smaller than this are assumed deliberate (iota tables,
#: interval clamps, gbdt thresholds); a closure-captured index shard is
#: megabytes. 64 KiB sits two orders of magnitude between the classes.
CONST_BYTES_THRESHOLD = 64 * 1024

_CONST_RE = re.compile(
    r"=\s*([a-z0-9]+\[[\d,]*\]\S*)\s+constant\(")
_PARAM_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\]\S*\s+parameter\((\d+)\)")
_META_FILE_RE = re.compile(r'source_file="([^"]+)"')
_META_LINE_RE = re.compile(r"source_line=(\d+)")
_DEF_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _source_loc(line: str) -> Tuple[Optional[str], Optional[int]]:
    fm = _META_FILE_RE.search(line)
    lm = _META_LINE_RE.search(line)
    return (fm.group(1) if fm else None,
            int(lm.group(1)) if lm else None)


def replicated_constants(entry: str, hlo: str,
                         threshold: int = CONST_BYTES_THRESHOLD
                         ) -> List[Finding]:
    """Pass 1: array constants above `threshold` baked into the program.

    A jax.Array captured by closure instead of passed as an argument
    compiles to a `constant(...)` instruction — replicated onto every
    device, silently undoing dist.place_index (the PR 3 bug class; see
    the Engine protocol docstring). Everything index-sized must cross
    the jit boundary as an argument.
    """
    out: List[Finding] = []
    for line in hlo.splitlines():
        m = _CONST_RE.search(line)
        if not m:
            continue
        nbytes = _shape_bytes(m.group(1))
        if nbytes < threshold:
            continue
        f, ln = _source_loc(line)
        out.append(Finding(
            "replicated-constant", entry,
            f"{m.group(1)} ({nbytes} bytes) baked into the compiled "
            f"program as a constant — a closure-captured array "
            f"replicates onto every device; pass it as a jit argument",
            f, ln))
    return out


def _def_map(lines: List[str]) -> Dict[str, str]:
    defs: Dict[str, str] = {}
    for line in lines:
        m = _DEF_NAME_RE.match(line)
        if m:
            defs[m.group(1)] = line
    return defs


def _operands(line: str) -> List[str]:
    # operand list = everything after the opcode's '('; the leading
    # `%name = type` is cut off by splitting at the first '('
    return _OPERAND_RE.findall(line.split("(", 1)[-1])


def unpartitionable_topk(entry: str, hlo: str, *, max_hops: int = 6
                         ) -> List[Finding]:
    """Pass 2: TopK/sort custom-calls fed by a dim-0 all-gather.

    When GSPMD cannot partition a TopK custom-call whose operand
    carries a sharded dim, it materialises the full operand with an
    `all-gather` over the sharded (leading) dim right in front of it —
    the PR 6 bug class (`pin_merge=False`). Deliberate [B, k] merges
    gather dim 1 inside the shard_map and never match. The back-walk
    is bounded to `max_hops` def-use hops within one computation, so a
    dim-0 gather far upstream of an unrelated sort stays quiet.
    """
    out: List[Finding] = []
    for lines in _split_computations(hlo).values():
        defs = _def_map(lines)
        gathers = {name for name, line in defs.items()
                   if ("all-gather" in line.split("=", 1)[-1][:64]
                       and "dimensions={0}" in line)}
        if not gathers:
            continue
        for name, line in defs.items():
            body = line.split("=", 1)[-1]
            is_topk = 'custom_call_target="TopK"' in body
            is_sort = re.search(r"\bsort(?:\.\d+)?\(", body) is not None
            if not (is_topk or is_sort):
                continue
            frontier = _operands(line)
            seen = set(frontier)
            for _ in range(max_hops):
                hit = [n for n in frontier if n in gathers]
                if hit:
                    f, ln = _source_loc(line)
                    out.append(Finding(
                        "unpartitionable-topk", entry,
                        f"{'TopK custom-call' if is_topk else 'sort'} "
                        f"fed by a dim-0 all-gather (%{hit[0]}): the "
                        f"merge's operand carries a sharded dim GSPMD "
                        f"cannot partition — keep the top-k inside the "
                        f"shard_map (pin_merge)",
                        f, ln))
                    break
                nxt = []
                for n in frontier:
                    for op in _operands(defs.get(n, "")):
                        if op not in seen:
                            seen.add(op)
                            nxt.append(op)
                frontier = nxt
                if not frontier:
                    break
    return out


def collective_n_independence(entry: str, hlo_small: str, hlo_large: str,
                              *, rel_tol: float = 1e-6) -> List[Finding]:
    """Pass 3: per-collective bytes must match across two index sizes.

    The sharded search steps move [B, k] candidate merges and [B, M]
    frontiers across shards — batch- and k-sized, never index-sized.
    If any collective kind's trip-count-weighted bytes differ between
    the small and large builds of the same entry, index rows are
    crossing the interconnect and the scan will not scale out.
    """
    small = collective_bytes(hlo_small)
    large = collective_bytes(hlo_large)
    out: List[Finding] = []
    for kind in COLLECTIVES:
        a, b = small.get(kind, 0.0), large.get(kind, 0.0)
        if abs(a - b) > rel_tol * max(a, b, 1.0):
            out.append(Finding(
                "collective-n-independence", entry,
                f"{kind} bytes scale with the index size "
                f"({a:.0f} -> {b:.0f} between the small and large "
                f"builds): collectives must move candidates, not "
                f"index rows"))
    return out


def _entry_params(hlo: str) -> Dict[int, Tuple[str, str, Tuple[int, ...]]]:
    """parameter number -> (instr name, dtype, dims) for the ENTRY
    computation's `parameter(n)` instructions (post-SPMD: per-device
    shapes)."""
    lines = _split_computations(hlo).get(_find_entry(hlo), [])
    params: Dict[int, Tuple[str, str, Tuple[int, ...]]] = {}
    for line in lines:
        m = _PARAM_RE.search(line)
        if not m:
            continue
        nm = _DEF_NAME_RE.match(line)
        dims = (tuple(int(x) for x in m.group(2).split(","))
                if m.group(2) else ())
        params[int(m.group(3))] = (nm.group(1) if nm else "",
                                   m.group(1), dims)
    return params


def _param_use_loc(hlo: str, name: str
                   ) -> Tuple[Optional[str], Optional[int]]:
    """Source anchor for a parameter: the first instruction CONSUMING
    `%name` that carries metadata. Parameter instructions themselves
    have no source location — the array was built in Python, not by an
    op — so the finding points at the code that reads it."""
    if not name:
        return None, None
    pat = re.compile(r"%" + re.escape(name) + r"\b")
    for line in hlo.splitlines():
        if " parameter(" in line:
            continue
        if not pat.search(line.split("=", 1)[-1]):
            continue
        f, ln = _source_loc(line)
        if f:
            return f, ln
    return None, None


def _nelems(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def resident_bytes(entry: str, hlo_small: str, hlo_large: str,
                   *, dim: int = 16) -> List[Finding]:
    """Pass 6: SQ8-resident entries must hold vector payloads as int8.

    Entries registered `resident_sq8=True` serve the compact residency
    format (index.residency): every N-scaled vector-payload parameter —
    one whose per-device element count GROWS between the small and
    large builds and whose trailing dim is the vector dim — must enter
    the compiled program at int8 width, and at least one such int8
    payload must exist. A float payload that scales with N means the
    manifest (or an engine refactor behind it) silently regressed to
    f32 residency: the program still computes the right answer, at
    4-4.4x the device bytes the residency contract budgets for.
    Batch-sized state (q, top-k buffers) and non-payload index arrays
    (ids, sqnorm, neighbor lists, the hashed visited filter) never
    match the payload test and stay unconstrained.
    """
    ps = _entry_params(hlo_small)
    pl = _entry_params(hlo_large)
    out: List[Finding] = []
    has_sq8 = False
    for num, (name, dt, dims) in sorted(ps.items()):
        other = pl.get(num)
        if other is None:
            continue
        _, dt_l, dims_l = other
        if dt != dt_l or len(dims) != len(dims_l):
            continue
        if _nelems(dims_l) <= _nelems(dims):
            continue                       # not N-scaled
        if len(dims) < 2 or dims[-1] != dim:
            continue                       # not a vector payload
        if dt == "s8":
            has_sq8 = True
            continue
        if dt not in ("f32", "f64", "bf16", "f16"):
            continue
        f, ln = _param_use_loc(hlo_large, name)
        out.append(Finding(
            "resident-bytes", entry,
            f"N-scaled vector payload parameter({num}) is device-"
            f"resident as {dt}[{','.join(map(str, dims_l))}]: "
            f"SQ8-resident entries must search int8 codes "
            f"(index.residency.quantize_*) and re-rank the final "
            f"top-k from the f32 store",
            f, ln))
    if not has_sq8:
        out.append(Finding(
            "resident-bytes", entry,
            "no N-scaled int8 vector-payload parameter reaches the "
            "program: the entry is registered resident_sq8 but is "
            "not serving the SQ8 view"))
    return out
