"""repro.analysis — a trace-time SPMD lint suite ("shardlint").

Static analysis over the jaxprs / compiled HLO of the repo's registered
jitted entry points (the DarthServer chunk jits, both sharded engine
steps, the fused kernels) plus the source tree itself, turning the
sharding bug classes this repo has actually shipped into CI-gated
checks:

  replicated-constant      a large array constant baked into a compiled
                           program (a closure-captured index replicates
                           onto every device, silently undoing
                           dist.place_index)
  unpartitionable-topk     a TopK/sort custom-call fed by a dim-0
                           all-gather (GSPMD could not partition the
                           merge, so it gathered the sharded operand)
  collective-n-independence  per-collective bytes must not scale with
                           the database size (merges move [B, k], never
                           index rows)
  retrace-hazard           one trace per chunk signature across a
                           serving loop with mixed targets, refills and
                           contents-only mutations
  pad-convention           raw -1 / inf pad literals outside
                           repro.core.padding

Run `python -m repro.analysis --gate` (see docs/static_analysis.md).
This module stays import-light (no jax) so the CLI can force a device
count before jax initialises.
"""
from repro.analysis.findings import Finding, format_findings

__all__ = ["Finding", "format_findings"]
