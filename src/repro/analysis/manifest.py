"""The registered entry points: every jitted program the gate inspects.

Each builder fabricates a small instance of a REAL code path — the
fused kernels, both sharded engine steps, the sharded flat search and
the DarthServer chunk jits — from random data (trace-time analysis
only needs the program structure, not recall), lowers + compiles it,
and returns the compiled HLO text per artifact tag. Builders derive
their mesh from the visible device count, so the same manifest runs
degraded on the 1-device tier-1 host and fully sharded under the CI
gate's forced multidevice CPU.

Import cost note: this module imports jax (via the libraries it
registers) — the CLI only imports it AFTER pinning XLA_FLAGS.
"""
from __future__ import annotations

import contextlib
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.registry import SIZES, register
from repro.core import engines as engines_lib
from repro.core.intervals import IntervalParams
from repro.core.padding import pad_dists, pad_ids
from repro.core.predictor import RecallPredictor
from repro.dist import collectives as dist_collectives
from repro.dist import sharding as sharding_lib
from repro.gbdt import model as gbdt_model
from repro.index import hnsw as hnsw_lib
from repro.index import ivf as ivf_lib
from repro.index import residency as residency_lib
from repro.kernels import ops as kernel_ops
from repro.launch import mesh as mesh_lib
from repro.obs import trace as obs_trace
from repro.serve.engine import DarthServer
from repro.utils import meshctx

K = 10          # top-k of every fabricated program
NPROBE = 8      # IVF probes / HNSW ef-equivalent step budget
BATCH = 8       # query/slot batch


def _hlo(fn, *args, mesh=None, **kw) -> str:
    ctx = (meshctx.use_mesh(mesh) if mesh is not None
           else contextlib.nullcontext())
    with ctx:
        return fn.lower(*args, **kw).compile().as_text()


def _make_ivf(n: int, d: int, *, nlist: int = 32, seed: int = 0,
              sq8: bool = False) -> ivf_lib.IVFIndex:
    """Fabricated IVF index: random vectors, random (balanced-ish)
    bucket assignment through the real pack_buckets layout. sq8=True
    runs the real residency quantizer over it."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    assign = rng.integers(0, nlist, size=n)
    bv, bi, bsq, sizes = ivf_lib.pack_buckets(
        x, x, np.arange(n, dtype=np.int32), assign, nlist)
    index = ivf_lib.IVFIndex(
        centroids=jnp.asarray(rng.normal(size=(nlist, d)).astype(
            np.float32)),
        bucket_vecs=jnp.asarray(bv), bucket_ids=jnp.asarray(bi),
        bucket_sqnorm=jnp.asarray(bsq), bucket_sizes=jnp.asarray(sizes),
        scale=jnp.ones((d,), jnp.float32),
        offset=jnp.zeros((d,), jnp.float32))
    return residency_lib.quantize_ivf(index) if sq8 else index


def _make_hnsw(n: int, d: int, *, m: int = 8, seed: int = 0,
               sq8: bool = False) -> hnsw_lib.HNSWIndex:
    """Fabricated HNSW graph: random vectors + random adjacency (graph
    quality is irrelevant at trace time). sq8=True runs the real
    residency quantizer over it."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    nbr = rng.integers(0, n, size=(n, m)).astype(np.int32)
    index = hnsw_lib.HNSWIndex(
        vectors=jnp.asarray(x),
        sqnorm=jnp.asarray((x ** 2).sum(axis=1)),
        neighbors=jnp.asarray(nbr),
        entry=jnp.asarray(0, jnp.int32),
        route_ids=jnp.asarray(np.arange(64, dtype=np.int32)))
    return residency_lib.quantize_hnsw(index) if sq8 else index


def _queries(d: int, *, b: int = BATCH, seed: int = 1) -> jax.Array:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))


def _search_mesh():
    """All visible devices on the 1-D ("model",) search mesh."""
    return mesh_lib.make_search_mesh(0)


def _serve_mesh():
    """("hosts", "model") serve mesh: 2 host groups when >= 4 devices
    are visible (the CI gate), else single-host (tier-1)."""
    dc = jax.device_count()
    hosts = 2 if dc >= 4 and dc % 2 == 0 else 1
    return mesh_lib.make_serve_mesh(hosts=hosts), hosts


def _interval_for_target(r_t) -> IntervalParams:
    """Fixed intervals: the gate needs interval plumbing, not tuning."""
    r_t = np.asarray(r_t, np.float32)
    return IntervalParams(ipi=np.full(r_t.shape, 24.0, np.float32),
                          mpi=np.full(r_t.shape, 4.0, np.float32))


def _predictor() -> RecallPredictor:
    """Untrained GBDT (empty params): full inference program, zero fit
    cost; r_pred stays 0 so fabricated serves drain by engine
    exhaustion, exercising refill."""
    return RecallPredictor(params=gbdt_model.empty_params(4, 3))


# ---------------------------------------------------------------------------
# Fused kernels
# ---------------------------------------------------------------------------

@register("kernels/l2_topk", resident_sq8=True)
def l2_topk(size: str) -> Dict[str, str]:
    """The fused flat top-k kernel wrapper (interpret mode on CPU),
    called in the SQ8 asymmetric form: int8 codes, dequantized sqnorms
    and an explicit per-query bias."""
    n, d = SIZES[size]
    rng = np.random.default_rng(2)
    codes = jnp.asarray(rng.integers(-127, 128, size=(n, d)).astype(
        np.int8))
    xsq = jnp.sum(codes.astype(jnp.float32) ** 2, axis=1)
    q = _queries(d)
    bias = jnp.sum(q * q, axis=1, keepdims=True)
    return {"l2_topk": _hlo(kernel_ops.l2_topk, q, codes, k=K,
                            x_sqnorm=xsq, bias=bias, interpret=True)}


@register("kernels/bucket_topk", resident_sq8=True)
def bucket_topk(size: str) -> Dict[str, str]:
    """The fused IVF probe kernel wrapper (interpret mode on CPU) over
    int8 bucket codes (the SQ8-resident store's gathered rows)."""
    n, d = SIZES[size]
    cap = n // 32
    rng = np.random.default_rng(3)
    vecs = jnp.asarray(rng.integers(-127, 128, size=(BATCH, cap, d))
                       .astype(np.int8))
    sqn = jnp.sum(vecs.astype(jnp.float32) ** 2, axis=2)
    ids = jnp.asarray(rng.integers(0, n, size=(BATCH, cap)).astype(
        np.int32))
    return {"bucket_topk": _hlo(
        kernel_ops.bucket_topk, _queries(d), vecs, sqn, ids,
        pad_dists((BATCH, K)), pad_ids((BATCH, K)), interpret=True)}


# ---------------------------------------------------------------------------
# Sharded search steps
# ---------------------------------------------------------------------------

@register("dist/flat_search")
def flat_search(size: str) -> Dict[str, str]:
    """Sharded exact flat k-NN over a row-sharded database."""
    n, d = SIZES[size]
    mesh = _search_mesh()
    fn = dist_collectives.make_sharded_flat_search(mesh, K)
    rng = np.random.default_rng(4)
    x = jax.device_put(
        jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)),
        sharding_lib.database_sharding(mesh, n))
    return {"search": _hlo(fn, _queries(d), x, mesh=mesh)}


@register("dist/ivf_probe_step", resident_sq8=True)
def ivf_probe_step(size: str) -> Dict[str, str]:
    """One sharded IVF probe step over a cap-sharded SQ8 bucket store
    (the default serving residency — PR 10)."""
    n, d = SIZES[size]
    mesh = _search_mesh()
    index = sharding_lib.place_index(_make_ivf(n, d, sq8=True), mesh)
    eng = engines_lib.sharded_ivf_engine(index, mesh, k=K, nprobe=NPROBE)
    st = eng.init(index, _queries(d))
    return {"step": _hlo(eng.step, index, st, mesh=mesh)}


#: Fixed hashed-visited width for the beam-step entry: N-independent by
#: construction (the point of the hashed filter), a power of two, and
#: divisible by every shard count the gate meshes use.
VISITED_W = 512


@register("dist/hnsw_beam_step", resident_sq8=True)
def hnsw_beam_step(size: str) -> Dict[str, str]:
    """One sharded HNSW beam expansion over a row-sharded SQ8 graph
    with the fixed-width hashed visited filter."""
    n, d = SIZES[size]
    mesh = _search_mesh()
    index = sharding_lib.place_index(_make_hnsw(n, d, sq8=True), mesh)
    step = dist_collectives.make_sharded_beam_step(mesh)
    st = hnsw_lib.init_state(index, _queries(d), ef=16,
                             visited_width=VISITED_W)
    return {"step": _hlo(step, index, st, mesh=mesh, k=K)}


# ---------------------------------------------------------------------------
# DarthServer chunk jits
# ---------------------------------------------------------------------------

def _serve_chunks(kind: str, size: str, *, traced: bool = False
                  ) -> Dict[str, str]:
    n, d = SIZES[size]
    mesh, hosts = _serve_mesh()
    if kind == "ivf":
        index = sharding_lib.place_index(_make_ivf(n, d), mesh)
        eng = engines_lib.sharded_ivf_engine(index, mesh, k=K,
                                             nprobe=NPROBE)
    else:
        index = sharding_lib.place_index(_make_hnsw(n, d), mesh)
        eng = engines_lib.sharded_hnsw_engine(index, mesh, k=K, ef=16,
                                              max_steps=32)
    tracer = obs_trace.Tracer(traj_cap=16) if traced else None
    server = DarthServer(eng, _predictor(), _interval_for_target,
                         num_slots=BATCH, steps_per_sync=2, mesh=mesh,
                         hosts=hosts, tracer=tracer)
    rt = np.full((BATCH,), 0.9, np.float32)
    p = _interval_for_target(rt)
    with meshctx.use_mesh(mesh):
        q_dev = server._put(np.asarray(_queries(d)))
        rt_dev = server._put(rt)
        ipi_dev = server._put(p.ipi)
        mpi_dev = server._put(p.mpi)
        # AOT-compile init once, run it to get a REAL chunk state (with
        # the state sharding serve() actually produces), then compile
        # the step chunk against that state.
        init_comp = server._init_chunk.lower(index, q_dev, ipi_dev,
                                             mpi_dev).compile()
        if traced:
            st, traj = init_comp(index, q_dev, ipi_dev, mpi_dev)
            run_comp = server._run_chunk.lower(index, st, traj, rt_dev,
                                               ipi_dev, mpi_dev).compile()
        else:
            st = init_comp(index, q_dev, ipi_dev, mpi_dev)
            run_comp = server._run_chunk.lower(index, st, rt_dev, ipi_dev,
                                               mpi_dev).compile()
    return {"init_chunk": init_comp.as_text(),
            "run_chunk": run_comp.as_text()}


@register("serve/chunks_ivf")
def serve_chunks_ivf(size: str) -> Dict[str, str]:
    """DarthServer init/run chunk jits around the sharded IVF engine."""
    return _serve_chunks("ivf", size)


@register("serve/chunks_hnsw")
def serve_chunks_hnsw(size: str) -> Dict[str, str]:
    """DarthServer init/run chunk jits around the sharded HNSW engine."""
    return _serve_chunks("hnsw", size)


@register("serve/chunks_traced")
def serve_chunks_traced(size: str) -> Dict[str, str]:
    """The TRACED chunk jits (repro.obs trajectory ring riding the
    carry): same programs as serve/chunks_ivf plus the fixed-shape
    [slots, traj_cap] ring, so the sharding lints check the ring stays
    split over host groups like the rest of the chunk state."""
    return _serve_chunks("ivf", size, traced=True)


# ---------------------------------------------------------------------------
# Pass 4: retrace audit (executable, not lowered)
# ---------------------------------------------------------------------------

@register("serve/retrace_loop", check=True)
def retrace_loop() -> List[Finding]:
    """Serve a mixed workload and assert one trace per chunk signature.

    The loop mixes recall targets, forces refills (3x more queries than
    slots) and pushes a contents-only engine swap from on_boundary —
    every input class serve() varies at runtime. A second serve with
    different target VALUES (same shapes) must also stay on the first
    trace: weak types or Python scalars leaking into the chunk
    signatures would show up as extra cache entries here.
    """
    n, d = SIZES["small"]
    index = _make_ivf(n, d)
    eng = engines_lib.ivf_engine(index, k=K, nprobe=NPROBE)
    server = DarthServer(eng, _predictor(), _interval_for_target,
                         num_slots=BATCH, steps_per_sync=2)
    rng = np.random.default_rng(5)
    q = rng.normal(size=(3 * BATCH, d)).astype(np.float32)
    rt = np.tile(np.asarray([0.8, 0.9, 0.95], np.float32),
                 BATCH)[:3 * BATCH]

    def make_mutator():
        done = []

        def mutate_once(srv):
            if not done:
                done.append(True)
                srv.set_engine(engines_lib.ivf_engine(index, k=K,
                                                      nprobe=NPROBE),
                               contents_only=True)
        return mutate_once

    server.serve(q, rt, on_boundary=make_mutator())
    server.serve(q[:BATCH], np.full((BATCH,), 0.85, np.float32))

    # The TRACED server runs the same mixed workload: the trajectory
    # ring rides the chunk carry with a fixed shape, so it must not add
    # cache entries either (a data-dependent ring shape, or the span
    # bookkeeping leaking host values into the jit signature, would).
    traced = DarthServer(eng, _predictor(), _interval_for_target,
                         num_slots=BATCH, steps_per_sync=2,
                         tracer=obs_trace.Tracer(traj_cap=16))
    traced.serve(q, rt, on_boundary=make_mutator())
    traced.serve(q[:BATCH], np.full((BATCH,), 0.85, np.float32))

    out: List[Finding] = []
    for tag, fn, limit in (("run_chunk", server._run_chunk, 1),
                           ("init_chunk", server._init_chunk, 1),
                           ("splice", server._splice, 1),
                           ("run_chunk[traced]", traced._run_chunk, 1),
                           ("init_chunk[traced]", traced._init_chunk, 1),
                           ("splice[traced]", traced._splice, 1)):
        traces = fn._cache_size()
        if traces > limit:
            out.append(Finding(
                "retrace-hazard", "serve/retrace_loop",
                f"{tag} traced {traces}x across a serving loop with "
                f"mixed targets, refills and a contents-only engine "
                f"swap (expected {limit}): a weak type or Python "
                f"scalar is leaking into the chunk signature"))
    return out
