"""Pass 5 — pad-convention lint (AST, no jax).

The repo-wide pad/tombstone convention lives in repro.core.padding
(PAD_ID = -1, PAD_SQNORM = +inf); before it existed, three hand-rolled
`jnp.full(..., jnp.inf)` sentinels had drifted to subtly different
dtypes (strong f32 vs weak float — a retrace hazard AND a merge-dtype
hazard). This pass flags raw `-1` / `inf` literals used AS PAD VALUES
inside the modules that share the convention, so every new sentinel
goes through the dtype-pinned helpers.

Scope: src/repro/{index,mutate,dist} only. kernels/ is deliberately
out of scope — its in-kernel masking literals are an internal contract
below the index layout, and routing them through repro.core.padding
would close the fragile kernels -> core.__init__ -> predictor ->
kernels import cycle.

Flagged contexts (direct arguments only — `x < np.inf` comparisons and
arithmetic like `.add(-1)` never match):

  jnp/np.full(shape, -1) / full_like(x, inf)     the fill value
  jnp.pad(..., constant_values=inf)              the pad value
  arr.at[idx].set(-1)                            tombstone writes
  jnp.where(mask, -1, x) / where(mask, x, inf)   pad selection

A literal is `-1` (int, not bool, not -1.0 — float -1 is a legitimate
recall-prediction sentinel) or a top-level `<mod>.inf` attribute
(`-jnp.inf` mask floors are NOT flagged: -inf is never a pad value
here). Waive a deliberate non-pad use with a `# padlint: ok` comment
on the same or the preceding line.
"""
from __future__ import annotations

import ast
import os
from typing import List

from repro.analysis.findings import Finding

PASS_NAME = "pad-convention"

#: src/repro subpackages that share the pad convention (see module
#: docstring for why kernels/ is excluded).
SCOPE = ("index", "mutate", "dist")

WAIVER = "padlint: ok"

_FILL_FUNCS = {"full", "full_like"}


def _is_pad_literal(node: ast.expr) -> str:
    """'' if not a pad literal, else a short description of it."""
    if (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
            and isinstance(node.operand, ast.Constant)):
        v = node.operand.value
        if isinstance(v, int) and not isinstance(v, bool) and v == 1:
            return "-1"
    if isinstance(node, ast.Attribute) and node.attr == "inf":
        return "inf"
    return ""


def _basename(func: ast.expr) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _flag_args(call: ast.Call) -> List[ast.expr]:
    """The argument positions of `call` where a raw literal means "this
    is a pad value" (see module docstring)."""
    name = _basename(call.func)
    if name in _FILL_FUNCS:
        return call.args[1:2]
    if name == "pad":
        return [kw.value for kw in call.keywords
                if kw.arg == "constant_values"]
    if name == "set" and isinstance(call.func, ast.Attribute):
        return list(call.args)
    if name == "where":
        return call.args[1:3]
    return []


def lint_source(path: str, text: str) -> List[Finding]:
    """Lint one module's source text; `path` is only used for reporting
    and waiver lookup (tests feed synthetic sources directly)."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding(PASS_NAME, "tree", f"unparseable: {e}", path,
                        e.lineno)]
    lines = text.splitlines()

    def waived(lineno: int) -> bool:
        for ln in (lineno - 1, lineno - 2):
            if 0 <= ln < len(lines) and WAIVER in lines[ln]:
                return True
        return False

    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for arg in _flag_args(node):
            lit = _is_pad_literal(arg)
            if lit and not waived(arg.lineno):
                out.append(Finding(
                    PASS_NAME, "tree",
                    f"raw pad literal {lit} in "
                    f"{_basename(node.func)}(...) — use repro.core."
                    f"padding (PAD_ID / PAD_SQNORM / pad_ids / "
                    f"pad_dists), or waive with `# {WAIVER}`",
                    path, arg.lineno))
    return out


def lint_tree(src_root: str) -> List[Finding]:
    """Lint every .py under src_root/repro/{index,mutate,dist}."""
    out: List[Finding] = []
    for sub in SCOPE:
        root = os.path.join(src_root, "repro", sub)
        for dirpath, _, names in sorted(os.walk(root)):
            for name in sorted(names):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                with open(path, "r") as f:
                    out.extend(lint_source(path, f.read()))
    return out
