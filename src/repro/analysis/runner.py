"""Gate runner: padlint over the tree + the HLO/retrace passes over
every registered entry point.

`run_gate()` is the programmatic entry (the pytest fixture calls it
in-process at 1 device); `python -m repro.analysis --gate` wraps it
with device forcing and exit codes.
"""
from __future__ import annotations

import os
from typing import List

from repro.analysis import hlo_passes, padlint
from repro.analysis.findings import Finding
from repro.analysis.registry import SIZES, entry_points

#: src root, derived from this file (src/repro/analysis/runner.py).
SRC_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def run_entry(ep) -> List[Finding]:
    """All program-level passes over one registered entry point."""
    import jax

    if ep.min_devices > jax.device_count():
        return []
    if ep.check is not None:
        return list(ep.check())
    small = ep.build("small")
    large = ep.build("large")
    out: List[Finding] = []
    for tag, hlo in small.items():
        name = f"{ep.name}:{tag}"
        out.extend(hlo_passes.replicated_constants(name, hlo))
        out.extend(hlo_passes.unpartitionable_topk(name, hlo))
        if tag in large:
            out.extend(hlo_passes.collective_n_independence(
                name, hlo, large[tag]))
            if ep.resident_sq8:
                out.extend(hlo_passes.resident_bytes(
                    name, hlo, large[tag], dim=SIZES["small"][1]))
    return out


def run_gate(*, tree_only: bool = False) -> List[Finding]:
    """The full gate: source-tree lint, then every entry point.

    tree_only skips the jax-dependent passes (used by lint tooling
    that must not initialise a device backend).
    """
    findings = padlint.lint_tree(SRC_ROOT)
    if tree_only:
        return findings
    for ep in entry_points():
        findings.extend(run_entry(ep))
    return findings
