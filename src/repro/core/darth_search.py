"""DARTH early-termination search driver (paper Algorithm 1, batched).

The driver wraps any `Engine` (IVF probe loop / HNSW beam loop) and runs it
under `lax.while_loop` with:

  * per-query `idis` counters (distance calcs since last predictor call),
  * per-query adaptive prediction intervals `pi` (Eq. 1),
  * batched GBDT recall prediction, fired only when >= 1 query is due
    (`lax.cond` skips the predictor entirely otherwise),
  * per-query early termination: predicted recall >= declared target.

TPU adaptation notes (DESIGN.md §2): termination granularity is one engine
step (a bucket probe / beam expansion) rather than a single distance calc;
per-query targets are a vector, so one batch can mix declared recalls.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

from repro.core import engines as engines_lib
from repro.core import features as features_lib
from repro.core.intervals import IntervalParams, next_interval

PredictorFn = Callable[[jax.Array], jax.Array]  # f32[B,11] -> f32[B]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DarthState:
    inner: Any
    idis: jax.Array      # i32[B] distance calcs since last predictor call
    pi: jax.Array        # f32[B] current prediction interval
    r_pred: jax.Array    # f32[B] last predicted recall (-1 = never called)
    npred: jax.Array     # i32[B] #predictor invocations
    early: jax.Array     # bool[B] terminated by DARTH (vs natural/budget)
    steps: jax.Array     # i32[] loop steps executed


def _features(engine: engines_lib.Engine, inner: Any) -> jax.Array:
    return features_lib.extract(
        engine.nstep(inner), inner.ndis, inner.ninserts, inner.first_nn,
        engine.topk_d(inner))


def init_darth_state(engine: engines_lib.Engine, q: jax.Array,
                     params: IntervalParams) -> DarthState:
    b = q.shape[0]
    return DarthState(
        inner=engine.init(engine.index, q),
        idis=jnp.zeros((b,), jnp.int32),
        pi=jnp.broadcast_to(jnp.asarray(params.ipi, jnp.float32), (b,)),
        r_pred=jnp.full((b,), -1.0, jnp.float32),
        npred=jnp.zeros((b,), jnp.int32),
        early=jnp.zeros((b,), bool),
        steps=jnp.zeros((), jnp.int32),
    )


def make_darth_body(engine: engines_lib.Engine, predictor: PredictorFn,
                    params: IntervalParams, r_t: jax.Array):
    """One Algorithm-1 iteration as a reusable jittable body (the serving
    engine drives this directly; darth_search wraps it in a while_loop)."""
    def body(st: DarthState) -> DarthState:
        prev_ndis = st.inner.ndis
        inner = engine.step(engine.index, st.inner)
        idis = st.idis + (inner.ndis - prev_ndis)
        due = inner.active & (idis.astype(jnp.float32) >= st.pi)

        def with_pred(args):
            inner, idis, st_pi, st_rp, st_npred, st_early = args
            feats = _features(engine, inner)
            rp = jnp.clip(predictor(feats), 0.0, 1.0)
            rp = jnp.where(due, rp, st_rp)
            stop = due & (rp >= r_t)
            new_inner = engines_lib.set_active(inner, inner.active & ~stop)
            pi = jnp.where(due, next_interval(params, r_t, rp), st_pi)
            idis2 = jnp.where(due, 0, idis)
            return (new_inner, idis2, pi, rp, st_npred + due.astype(jnp.int32),
                    st_early | stop)

        def without_pred(args):
            inner, idis, st_pi, st_rp, st_npred, st_early = args
            return (inner, idis, st_pi, st_rp, st_npred, st_early)

        inner, idis, pi, rp, npred, early = jax.lax.cond(
            due.any(), with_pred, without_pred,
            (inner, idis, st.pi, st.r_pred, st.npred, st.early))
        return DarthState(inner=inner, idis=idis, pi=pi, r_pred=rp,
                          npred=npred, early=early, steps=st.steps + 1)

    return body


def darth_search(engine: engines_lib.Engine, q: jax.Array,
                 r_target: Union[float, jax.Array],
                 predictor: PredictorFn,
                 params: IntervalParams) -> DarthState:
    """Run declarative-recall search to completion. Returns final state."""
    b = q.shape[0]
    r_t = jnp.broadcast_to(jnp.asarray(r_target, jnp.float32), (b,))
    st0 = init_darth_state(engine, q, params)
    body = make_darth_body(engine, predictor, params, r_t)

    def cond(st: DarthState):
        return st.inner.active.any() & (st.steps < engine.max_steps)

    return jax.lax.while_loop(cond, body, st0)


def plain_search(engine: engines_lib.Engine, q: jax.Array) -> Any:
    """Run the engine to natural termination (no early termination)."""
    inner0 = engine.init(engine.index, q)

    def cond(carry):
        inner, t = carry
        return inner.active.any() & (t < engine.max_steps)

    def body(carry):
        inner, t = carry
        return engine.step(engine.index, inner), t + 1

    inner, _ = jax.lax.while_loop(cond, body,
                                  (inner0, jnp.zeros((), jnp.int32)))
    return inner


def budget_search(engine: engines_lib.Engine, q: jax.Array,
                  budget: Union[float, jax.Array]) -> Any:
    """Fixed distance-calculation budget per query (the paper's 'Baseline'
    competitor §3.2.2 and LAET's termination primitive)."""
    b = q.shape[0]
    budget = jnp.broadcast_to(jnp.asarray(budget, jnp.float32), (b,))
    inner0 = engine.init(engine.index, q)

    def cond(carry):
        inner, t = carry
        return inner.active.any() & (t < engine.max_steps)

    def body(carry):
        inner, t = carry
        inner = engine.step(engine.index, inner)
        over = inner.ndis.astype(jnp.float32) >= budget
        inner = engines_lib.set_active(inner, inner.active & ~over)
        return inner, t + 1

    inner, _ = jax.lax.while_loop(cond, body,
                                  (inner0, jnp.zeros((), jnp.int32)))
    return inner
