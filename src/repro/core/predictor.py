"""Recall predictor wrapper: GBDT params + prediction paths.

Two inference paths, numerically identical (tests assert it):
  * XLA path (gbdt.infer.predict_efficient) — used on CPU and inside
    lowered dry-run graphs,
  * Pallas path (kernels.gbdt_predict) — VMEM-resident ensemble, the TPU
    target; validated in interpret mode.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro import gbdt
from repro.gbdt.model import GBDTParams
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass
class RecallPredictor:
    params: GBDTParams
    use_kernel: bool = False

    def __call__(self, feats: jax.Array) -> jax.Array:
        if self.use_kernel:
            return kernel_ops.gbdt_predict(self.params, feats)
        return gbdt.predict_efficient(self.params, feats)

    def save(self, path: str) -> None:
        sd = gbdt.to_state_dict(self.params)
        np.savez(path, **sd)

    @classmethod
    def load(cls, path: str, use_kernel: bool = False) -> "RecallPredictor":
        with np.load(path) as z:
            sd = {k: z[k] for k in z.files}
        return cls(params=gbdt.from_state_dict(sd), use_kernel=use_kernel)


def regression_metrics(pred: np.ndarray, true: np.ndarray) -> dict:
    pred = np.asarray(pred, np.float64)
    true = np.asarray(true, np.float64)
    mse = float(np.mean((pred - true) ** 2))
    mae = float(np.mean(np.abs(pred - true)))
    ss_res = float(np.sum((pred - true) ** 2))
    ss_tot = float(np.sum((true - true.mean()) ** 2)) + 1e-12
    return {"mse": mse, "mae": mae, "r2": 1.0 - ss_res / ss_tot}
