"""Training-data generation + recall-predictor fitting (paper §3.1.3, §4.1).

One `lax.scan` over the engine runs ALL training queries in parallel and
logs (features, true recall, ndis, valid) at every engine step — the TPU
equivalent of the paper's "log every distance calculation" (our logging
cadence is one engine step = one probe / beam expansion; the paper itself
uses coarser cadences for IVF, §4.2.10).

Byproducts used elsewhere (all free, as the paper notes):
  * dists_Rt per target  -> heuristic ipi/mpi + the 'Baseline' competitor,
  * per-query oracle termination points -> the optimality experiment (Fig 8).
"""
from __future__ import annotations

import time
from typing import Dict, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import gbdt
from repro.core import engines as engines_lib
from repro.core import features as features_lib
from repro.core import intervals as intervals_lib
from repro.core.predictor import RecallPredictor, regression_metrics
from repro.index import flat


class TrainLog(NamedTuple):
    features: np.ndarray  # f32[T, B, 11]
    recall: np.ndarray    # f32[T, B]
    ndis: np.ndarray      # i32[T, B]
    valid: np.ndarray     # bool[T, B] (query was active going into step)
    gen_seconds: float


def ground_truth(q: jax.Array, x: jax.Array, k: int, mesh=None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Exact k-NN ground truth for training-data generation.

    With a mesh, the database rows are sharded over the "model" axis and
    each shard runs the fused l2_topk kernel on its slice
    (dist.make_sharded_flat_search) — DARTH fit scales with the mesh
    instead of scanning all N rows per device."""
    if mesh is not None:
        from repro.dist import collectives
        return collectives.sharded_flat_search(q, x, k, mesh)
    return flat.search(q, x, k)


def generate_observations(engine: engines_lib.Engine, q: jax.Array,
                          gt_i: jax.Array,
                          batch: int = 256) -> TrainLog:
    """Run training queries through the engine, logging every step."""
    t0 = time.time()
    outs = []
    for lo in range(0, q.shape[0], batch):
        qb = q[lo:lo + batch]
        gb = gt_i[lo:lo + batch]
        if qb.shape[0] < batch:  # pad tail batch to keep one compiled shape
            pad = batch - qb.shape[0]
            qb = jnp.pad(qb, ((0, pad), (0, 0)))
            gb = jnp.pad(gb, ((0, pad), (0, 0)), constant_values=-2)
        outs.append(_scan_log(engine, qb, gb))
    feats = np.concatenate([o[0] for o in outs], axis=1)[:, :q.shape[0]]
    rec = np.concatenate([o[1] for o in outs], axis=1)[:, :q.shape[0]]
    nd = np.concatenate([o[2] for o in outs], axis=1)[:, :q.shape[0]]
    va = np.concatenate([o[3] for o in outs], axis=1)[:, :q.shape[0]]
    return TrainLog(feats, rec, nd, va, time.time() - t0)


def _scan_log(engine: engines_lib.Engine, q: jax.Array, gt_i: jax.Array):
    def step_fn(inner, _):
        was_active = inner.active
        inner = engine.step(engine.index, inner)
        feats = features_lib.extract(
            engine.nstep(inner), inner.ndis, inner.ninserts, inner.first_nn,
            engine.topk_d(inner))
        rec = flat.recall_at_k(engine.topk_i(inner), gt_i)
        return inner, (feats, rec, inner.ndis, was_active)

    inner0 = engine.init(engine.index, q)
    _, (f, r, nd, v) = jax.lax.scan(step_fn, inner0, None,
                                    length=engine.max_steps)
    return (np.asarray(f), np.asarray(r), np.asarray(nd), np.asarray(v))


class TrainedDarth(NamedTuple):
    predictor: RecallPredictor
    dists_rt: Dict[float, float]       # target recall -> mean oracle dists
    metrics: dict                      # fit metrics on held-out split
    train_seconds: float
    num_samples: int


def fit_predictor(log: TrainLog, *, cfg: gbdt.GBDTConfig = gbdt.GBDTConfig(),
                  targets: Sequence[float] = (0.8, 0.85, 0.9, 0.95, 0.99),
                  max_samples: int = 2_000_000, holdout: float = 0.1,
                  seed: int = 0) -> TrainedDarth:
    """Fit the GBDT recall predictor from step logs."""
    t0 = time.time()
    mask = log.valid.reshape(-1)
    x = log.features.reshape(-1, features_lib.NUM_FEATURES)[mask]
    y = log.recall.reshape(-1)[mask]
    rng = np.random.default_rng(seed)
    if x.shape[0] > max_samples:
        sel = rng.choice(x.shape[0], max_samples, replace=False)
        x, y = x[sel], y[sel]
    n_hold = max(1, int(holdout * x.shape[0]))
    perm = rng.permutation(x.shape[0])
    x, y = x[perm], y[perm]
    x_tr, y_tr = x[n_hold:], y[n_hold:]
    x_ho, y_ho = x[:n_hold], y[:n_hold]

    params = gbdt.fit(x_tr, y_tr, cfg)
    pred = RecallPredictor(params=params)
    m = regression_metrics(np.asarray(pred(jnp.asarray(x_ho))), y_ho)

    dists_rt = {
        float(rt): float(np.mean(intervals_lib.dists_to_target(
            log.recall, log.ndis, log.valid, rt)))
        for rt in targets
    }
    return TrainedDarth(predictor=pred, dists_rt=dists_rt, metrics=m,
                        train_seconds=time.time() - t0,
                        num_samples=int(x_tr.shape[0]))
