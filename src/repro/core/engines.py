"""Uniform engine adapters: one step-wise protocol over IVF and HNSW.

DARTH's driver (darth_search.py) is engine-agnostic: anything that exposes
init/step plus the counters the features need can be driven to a declarative
recall target. This mirrors the paper's claim (§3.3) that Algorithm 1
generalizes across ANNS methods whose search is iterative.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax

from repro.index import hnsw as hnsw_lib
from repro.index import ivf as ivf_lib


class Engine(NamedTuple):
    """Step-wise search engine protocol.

    All state objects must carry: .active bool[B], .ndis i32[B],
    .ninserts i32[B], .first_nn f32[B].

    init/step take the index EXPLICITLY (drivers call
    `engine.init(engine.index, q)` / `engine.step(engine.index, s)`),
    never through the closure: a sharded index closure-captured inside
    an outer jit (e.g. the slot-pool server's chunk functions) would be
    baked in as a fully REPLICATED constant, silently undoing
    dist.place_index. Passing it as an argument keeps its committed
    sharding on every jit path.
    """
    index: Any
    init: Callable[[Any, jax.Array], Any]
    step: Callable[[Any, Any], Any]
    topk_d: Callable[[Any], jax.Array]   # f32[B, K] squared, ascending
    topk_i: Callable[[Any], jax.Array]   # i32[B, K]
    nstep: Callable[[Any], jax.Array]    # i32[B]
    max_steps: int
    name: str
    k: int


def set_active(state: Any, mask: jax.Array) -> Any:
    return dataclasses.replace(state, active=mask)


def ivf_engine(index: ivf_lib.IVFIndex, *, k: int, nprobe: int) -> Engine:
    return Engine(
        index=index,
        init=lambda idx, q: ivf_lib.init_state(idx, q, k=k, nprobe=nprobe),
        step=ivf_lib.probe_step,
        topk_d=lambda s: s.topk_d,
        topk_i=lambda s: s.topk_i,
        nstep=lambda s: s.probe_pos,
        max_steps=nprobe,
        name="ivf",
        k=k,
    )


def sharded_ivf_engine(index: ivf_lib.IVFIndex, mesh, *, k: int, nprobe: int,
                       use_kernel: bool = True, interpret: bool = True,
                       pin_merge: bool = True) -> Engine:
    """ShardedIVFEngine: the IVF probe loop over a cap-sharded bucket
    store (dist.place_index + dist.collectives.make_sharded_probe_step).

    Same Engine protocol and the same IVFSearchState as ivf_engine, so
    darth_search / budget_search / the slot-pool server drive it
    unchanged; only the probe step's data movement differs (per-shard
    bucket_topk + one [B, k] all-gather merge instead of a GSPMD bucket
    gather). `index` must have been placed with dist.place_index(index,
    mesh) so its bucket cap divides the shard count."""
    from repro.dist import collectives as dist_collectives

    # make_sharded_probe_step returns a jitted step(index, state): the
    # index goes through every jit boundary as an argument so its
    # committed cap-axis sharding is respected (a closure const would
    # replicate — see the Engine docstring).
    # pin_merge keeps the candidate top-k merge inside the shard_map so
    # a hosts-split slot dim never feeds the unpartitionable TopK
    # custom-call (see make_sharded_probe_step); False is the pre-fix
    # behavior, kept for collective-traffic benchmarking.
    step = dist_collectives.make_sharded_probe_step(
        mesh, use_kernel=use_kernel, interpret=interpret,
        pin_merge=pin_merge)
    # The init's centroid-ranking top_k is pinned the same way (plain
    # ivf.init_state inside the server's init chunk would all-gather
    # the hosts-split slot rows to feed the TopK custom-call).
    init = dist_collectives.make_sharded_ivf_init(mesh)
    return Engine(
        index=index,
        init=lambda idx, q: init(idx, q, k=k, nprobe=nprobe),
        step=step,
        topk_d=lambda s: s.topk_d,
        topk_i=lambda s: s.topk_i,
        nstep=lambda s: s.probe_pos,
        max_steps=nprobe,
        name="ivf-sharded",
        k=k,
    )


def hnsw_engine(index: hnsw_lib.HNSWIndex, *, k: int, ef: int,
                max_steps: int = 0, visited_width: int = 0) -> Engine:
    """`visited_width` > 0 swaps the exact [B, N] visited bitmap for a
    fixed-width hashed filter [B, visited_width] (power of two < N; see
    hnsw.init_state) so the per-query state stops scaling with N."""
    limit = max_steps or 8 * ef
    return Engine(
        index=index,
        init=lambda idx, q: hnsw_lib.init_state(
            idx, q, ef=ef, visited_width=visited_width),
        step=lambda idx, s: hnsw_lib.beam_step(idx, s, k=k),
        topk_d=lambda s: s.cand_d[:, :k],
        topk_i=lambda s: s.cand_i[:, :k],
        nstep=lambda s: s.nstep,
        max_steps=limit,
        name="hnsw",
        k=k,
    )


def mutable_engine(base_engine: Engine, delta, *,
                   interpret: bool = True) -> Engine:
    """MutableEngine: wrap ANY engine (single-device or sharded) with a
    delta tier — init adds one brute-force delta scan (fused l2_topk),
    step is the base probe/beam step, and the top-k getters merge the
    delta candidates via merge_topk. Tombstoned slots carry sqnorm +inf
    / ids -1 (the shard-pad convention) in base and delta alike, so
    deletes are invisible to every driver. See repro.mutate."""
    from repro.mutate import engine as mutate_engine_lib

    return mutate_engine_lib.mutable_engine(base_engine, delta,
                                            interpret=interpret)


def sharded_hnsw_engine(index: hnsw_lib.HNSWIndex, mesh, *, k: int, ef: int,
                        max_steps: int = 0, pin_merge: bool = True,
                        visited_width: int = 0) -> Engine:
    """ShardedHNSWEngine: the beam loop over a row-sharded graph
    (dist.place_index + dist.collectives.make_sharded_beam_step).

    Same Engine protocol and the same HNSWSearchState as hnsw_engine, so
    darth_search / budget_search / the slot-pool server drive it
    unchanged; only the beam step's data movement differs (per-shard
    neighbor resolution + one [B, M] psum/all-gather frontier merge
    instead of a GSPMD gather of neighbor lists and vectors). `index`
    must have been placed with dist.place_index(index, mesh) so its node
    count divides the shard count. `visited_width` > 0 selects the
    hashed visited filter (must also divide the shard count — the
    filter splits over "model" inside the step)."""
    from repro.dist import collectives as dist_collectives

    # make_sharded_beam_step returns a jitted step(index, state, k=..):
    # the index goes through every jit boundary as an argument so its
    # committed row sharding is respected (a closure const would
    # replicate — see the Engine docstring).
    # pin_merge: frontier top-k runs inside the shard_map (the TopK
    # custom-call cannot be partitioned over a hosts-split slot dim —
    # see make_sharded_beam_step); False is the pre-fix behavior.
    step = dist_collectives.make_sharded_beam_step(mesh,
                                                   pin_merge=pin_merge)
    limit = max_steps or 8 * ef
    return Engine(
        index=index,
        init=lambda idx, q: hnsw_lib.init_state(
            idx, q, ef=ef, visited_width=visited_width),
        step=lambda idx, s: step(idx, s, k=k),
        topk_d=lambda s: s.cand_d[:, :k],
        topk_i=lambda s: s.cand_i[:, :k],
        nstep=lambda s: s.nstep,
        max_steps=limit,
        name="hnsw-sharded",
        k=k,
    )
