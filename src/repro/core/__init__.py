"""DARTH core: declarative recall through early termination (the paper's
primary contribution), engine-agnostic over the ANN index substrate."""
from repro.core import (api, baselines, darth_search, engines, features,
                        intervals, predictor, training)
from repro.core.api import Darth
from repro.core.darth_search import budget_search, plain_search
from repro.core.engines import Engine, hnsw_engine, ivf_engine
from repro.core.intervals import IntervalParams, heuristic_params
from repro.core.predictor import RecallPredictor

__all__ = [
    "api", "baselines", "darth_search", "engines", "features", "intervals",
    "predictor", "training", "Darth", "Engine", "RecallPredictor",
    "budget_search", "plain_search", "hnsw_engine", "ivf_engine",
    "IntervalParams", "heuristic_params",
]
