"""Adaptive prediction intervals (paper §3.2, Eq. 1) + the heuristic
hyperparameter selection that makes DARTH tuning-free (§3.2.2)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class IntervalParams(NamedTuple):
    ipi: float  # initial (max) prediction interval, in distance calcs
    mpi: float  # minimum prediction interval


def next_interval(p: IntervalParams, r_target: jax.Array,
                  r_pred: jax.Array) -> jax.Array:
    """Eq. 1: pi = mpi + (ipi - mpi) * (R_t - R_p), clipped to [mpi, ipi]."""
    pi = p.mpi + (p.ipi - p.mpi) * (r_target - r_pred)
    return jnp.clip(pi, p.mpi, p.ipi)


def heuristic_params(dists_rt) -> IntervalParams:
    """ipi = dists_Rt / 2, mpi = dists_Rt / 10 (§3.2.2).

    dists_Rt is the mean #distance calcs the *training* queries needed to
    reach the target recall — a free byproduct of training-data generation.
    Accepts a scalar (returns float fields, as every fit-time caller
    expects) or an array of per-query dists_Rt (returns float32 array
    fields — the serving path's per-slot IntervalParams); both shapes
    share this one definition of the §3.2.2 constants."""
    d = np.maximum(np.asarray(dists_rt, np.float64), 1.0)
    ipi = np.maximum(d / 2.0, 1.0)
    mpi = np.maximum(d / 10.0, 1.0)
    if d.ndim == 0:
        return IntervalParams(ipi=float(ipi), mpi=float(mpi))
    return IntervalParams(ipi=ipi.astype(np.float32),
                          mpi=mpi.astype(np.float32))


def static_params(dists_rt: float, divisor: float = 4.0) -> IntervalParams:
    """Ablation variant (§4.1.6 'Adaptive-Static'): fixed pi = dists_Rt/4."""
    v = max(float(dists_rt) / divisor, 1.0)
    return IntervalParams(ipi=v, mpi=v)


def dists_to_target(recall_log: np.ndarray, ndis_log: np.ndarray,
                    valid: np.ndarray, r_target: float) -> np.ndarray:
    """Per-query oracle: #distance calcs at the first step reaching R_t.

    recall_log/ndis_log/valid: [T, B] per-step logs from training-data
    generation. Queries that never reach R_t get their final ndis.
    Returns float64[B].
    """
    hit = (recall_log >= r_target - 1e-9) & valid
    t_idx = np.where(hit.any(0), hit.argmax(0), -1)
    last_valid = np.maximum(valid.astype(np.int64).cumsum(0).argmax(0), 0)
    t_eff = np.where(t_idx >= 0, t_idx, last_valid)
    return ndis_log[t_eff, np.arange(ndis_log.shape[1])].astype(np.float64)
