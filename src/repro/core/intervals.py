"""Adaptive prediction intervals (paper §3.2, Eq. 1) + the heuristic
hyperparameter selection that makes DARTH tuning-free (§3.2.2)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class IntervalParams(NamedTuple):
    ipi: float  # initial (max) prediction interval, in distance calcs
    mpi: float  # minimum prediction interval


def next_interval(p: IntervalParams, r_target: jax.Array,
                  r_pred: jax.Array) -> jax.Array:
    """Eq. 1: pi = mpi + (ipi - mpi) * (R_t - R_p), clipped to [mpi, ipi]."""
    pi = p.mpi + (p.ipi - p.mpi) * (r_target - r_pred)
    return jnp.clip(pi, p.mpi, p.ipi)


def heuristic_params(dists_rt: float) -> IntervalParams:
    """ipi = dists_Rt / 2, mpi = dists_Rt / 10 (§3.2.2).

    dists_Rt is the mean #distance calcs the *training* queries needed to
    reach the target recall — a free byproduct of training-data generation.
    """
    dists_rt = float(max(dists_rt, 1.0))
    return IntervalParams(ipi=max(dists_rt / 2.0, 1.0),
                          mpi=max(dists_rt / 10.0, 1.0))


def static_params(dists_rt: float, divisor: float = 4.0) -> IntervalParams:
    """Ablation variant (§4.1.6 'Adaptive-Static'): fixed pi = dists_Rt/4."""
    v = max(float(dists_rt) / divisor, 1.0)
    return IntervalParams(ipi=v, mpi=v)


def dists_to_target(recall_log: np.ndarray, ndis_log: np.ndarray,
                    valid: np.ndarray, r_target: float) -> np.ndarray:
    """Per-query oracle: #distance calcs at the first step reaching R_t.

    recall_log/ndis_log/valid: [T, B] per-step logs from training-data
    generation. Queries that never reach R_t get their final ndis.
    Returns float64[B].
    """
    hit = (recall_log >= r_target - 1e-9) & valid
    t_idx = np.where(hit.any(0), hit.argmax(0), -1)
    last_valid = np.maximum(valid.astype(np.int64).cumsum(0).argmax(0), 0)
    t_eff = np.where(t_idx >= 0, t_idx, last_valid)
    return ndis_log[t_eff, np.arange(ndis_log.shape[1])].astype(np.float64)
