"""The repo-wide shard-pad / tombstone convention, in one place.

Every fixed-shape layout in this codebase — IVF bucket slots, HNSW
adjacency rows, shard padding (dist.place_index), delta-ring slots and
tombstones (mutate.delta), candidate merges — marks an empty slot the
same way:

    vecs 0, ids PAD_ID (-1), sqnorm / distance PAD_SQNORM (+inf)

+inf sqnorms can never win a top-k and -1 ids are dropped by every
consumer (recall, merges, scatters route them out of bounds), so a pad
slot can never surface in a result set through ANY engine. Before this
module the two literals were hand-rolled at ~40 call sites with subtly
different dtypes (f32 vs weak float); the pad-convention lint
(repro.analysis.padlint) now flags raw ``-1`` / ``inf`` pad literals in
the contract packages (``index``, ``mutate``, ``dist``) so the
convention has exactly one definition.

This module is intentionally dependency-free inside ``repro`` (jax/numpy
only): ``index``, ``mutate`` and ``dist`` import it during the
``repro.core`` package cycle, and a self-contained module is always safe
to import from a partially initialized package.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Empty / tombstoned id slot (bucket_ids, neighbors, delta ids, merges).
PAD_ID = -1
# Empty / tombstoned sqnorm: +inf can never enter a top-k.
PAD_SQNORM = jnp.inf
# Masked candidate distance (same value; named for call-site clarity).
PAD_DIST = PAD_SQNORM


def _pin(dtype, kind) -> np.dtype:
    """Resolve + assert the dtype class (the satellite-2 pinning: pad
    sentinels must never be weak-typed or land in the wrong family,
    which would split the jit cache or round +inf into a finite max)."""
    dt = np.dtype(dtype)
    assert np.issubdtype(dt, kind), (
        f"pad sentinel dtype {dt} is not {kind.__name__}")
    return dt


def pad_ids(shape, dtype=jnp.int32) -> jax.Array:
    """A strongly-typed integer array full of PAD_ID."""
    return jnp.full(shape, PAD_ID, _pin(dtype, np.integer))


def pad_dists(shape, dtype=jnp.float32) -> jax.Array:
    """A strongly-typed float array full of PAD_SQNORM (+inf)."""
    return jnp.full(shape, PAD_SQNORM, _pin(dtype, np.floating))


def pad_id_scalar(dtype=jnp.int32) -> jax.Array:
    """Dtype-pinned PAD_ID scalar for ``.at[...].set()`` tombstones."""
    return jnp.asarray(PAD_ID, _pin(dtype, np.integer))


def pad_sqnorm_scalar(dtype=jnp.float32) -> jax.Array:
    """Dtype-pinned +inf scalar for ``.at[...].set()`` tombstones."""
    return jnp.asarray(PAD_SQNORM, _pin(dtype, np.floating))


__all__ = ["PAD_ID", "PAD_SQNORM", "PAD_DIST", "pad_ids", "pad_dists",
           "pad_id_scalar", "pad_sqnorm_scalar"]
