"""DARTH's 11 search-state features (paper Table 1), fully vectorized.

Feature vector layout (fixed order, float32[B, 11]):
  0 nstep      search step (HNSW: beam expansions; IVF: probe number §3.3.2)
  1 ndis       #distance calculations so far
  2 ninserts   #updates to the NN result set
  3 firstNN    distance of the first base-layer NN found
               (IVF: distance to the nearest centroid §3.3.2)
  4 closestNN  current closest NN distance
  5 furthestNN current k-th NN distance
  6 avg        mean of the k NN distances found
  7 var        variance of the NN distances
  8 med        median
  9 perc25     25th percentile
 10 perc75     75th percentile

The result set is kept *sorted ascending* by every engine in this repo, so
the median/percentile features are O(1) indexed reads (DESIGN.md §2) — no
per-invocation sort, which is what keeps predictor-call overhead below one
probe/beam step.

Distances are metric (sqrt of the squared-L2 the engines carry), matching
the paper's feature scale. Partially-filled result sets (+inf tail) are
handled with masked statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NUM_FEATURES = 11
FEATURE_NAMES = (
    "nstep", "ndis", "ninserts", "firstNN", "closestNN", "furthestNN",
    "avg", "var", "med", "perc25", "perc75",
)


def extract(nstep: jax.Array, ndis: jax.Array, ninserts: jax.Array,
            first_nn: jax.Array, topk_sqd: jax.Array) -> jax.Array:
    """Build the feature matrix.

    Args:
      nstep, ndis, ninserts: int32[B]
      first_nn: float32[B] (already metric distance)
      topk_sqd: float32[B, K] squared distances, ascending, +inf = empty.
    Returns:
      float32[B, NUM_FEATURES]
    """
    b, k = topk_sqd.shape
    finite = jnp.isfinite(topk_sqd)
    cnt = finite.sum(axis=1)
    cnt_safe = jnp.maximum(cnt, 1)
    d = jnp.sqrt(jnp.where(finite, jnp.maximum(topk_sqd, 0.0), 0.0))

    closest = d[:, 0]
    furthest_idx = jnp.maximum(cnt - 1, 0)
    furthest = jnp.take_along_axis(d, furthest_idx[:, None], 1)[:, 0]
    avg = d.sum(axis=1) / cnt_safe
    var = (d**2).sum(axis=1) / cnt_safe - avg**2

    def pct(p: float) -> jax.Array:
        idx = jnp.clip((p * (cnt - 1)).astype(jnp.int32), 0, k - 1)
        return jnp.take_along_axis(d, idx[:, None], 1)[:, 0]

    feats = jnp.stack([
        nstep.astype(jnp.float32),
        ndis.astype(jnp.float32),
        ninserts.astype(jnp.float32),
        first_nn.astype(jnp.float32),
        closest, furthest, avg, jnp.maximum(var, 0.0),
        pct(0.5), pct(0.25), pct(0.75),
    ], axis=1)
    return jnp.where(cnt[:, None] > 0, feats, 0.0)
