"""Public declarative-recall API: ANNS(q, index, k, R_t) (paper §2.3).

`Darth` bundles an index, its engine factory, a trained recall predictor,
and per-target heuristic interval parameters. After `Darth.fit()` (one
training-data generation + GBDT fit), any attainable recall target can be
declared per query with NO further tuning — the paper's headline property.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import darth_search, engines as engines_lib
from repro.core import intervals as intervals_lib
from repro.core import training as training_lib


def validate_targets(r_target: Union[float, jax.Array, np.ndarray],
                     batch: int) -> np.ndarray:
    """Reject malformed declared-recall targets BEFORE they broadcast.

    A scalar or a [batch] vector is accepted; anything else (a wrong
    length — e.g. targets for last batch's size — or a 2-D array) would
    silently broadcast garbage against per-query state. Targets must be
    finite and in (0, 1]: recall is a fraction, and a target of 0 or a
    NaN would make the termination test vacuous. Returns the validated
    float32 array."""
    rt = np.asarray(r_target, np.float32)
    if rt.ndim > 1 or (rt.ndim == 1 and rt.shape[0] != batch):
        raise ValueError(
            f"r_target shape {rt.shape} does not match query batch "
            f"{batch}: pass a scalar or a [{batch}] vector of per-query "
            f"declared recall targets")
    if rt.size == 0 or not np.all(np.isfinite(rt)) or \
            float(rt.min()) <= 0.0 or float(rt.max()) > 1.0:
        raise ValueError(
            f"declared recall targets must be finite and in (0, 1], got "
            f"range [{rt.min() if rt.size else 'empty'}, "
            f"{rt.max() if rt.size else 'empty'}]")
    return rt


@dataclasses.dataclass
class Darth:
    """Declarative-recall searcher over one index + one k."""
    make_engine: Callable[..., engines_lib.Engine]
    engine: engines_lib.Engine
    trained: Optional[training_lib.TrainedDarth] = None

    # -- training ----------------------------------------------------------
    def fit(self, q_train: jax.Array, x: jax.Array, *,
            targets: Sequence[float] = (0.8, 0.85, 0.9, 0.95, 0.99),
            max_samples: int = 2_000_000, batch: int = 256,
            seed: int = 0, mesh=None,
            ids: Optional[np.ndarray] = None) -> training_lib.TrainedDarth:
        """One-time fit. With `mesh`, ground-truth generation row-shards
        the database over the mesh (training.ground_truth). With `ids`,
        x's rows are mapped to GLOBAL ids (ids[row]) before recall is
        measured — the mutable-index refit path, where the engine
        returns stable global ids rather than row positions."""
        k = self.engine.k
        _, gt_i = training_lib.ground_truth(q_train, x, k, mesh=mesh)
        if ids is not None:
            id_map = jnp.asarray(np.asarray(ids, np.int64).astype(np.int32))
            gt_i = jnp.where(gt_i >= 0, id_map[jnp.maximum(gt_i, 0)], -1)
        log = training_lib.generate_observations(self.engine, q_train, gt_i,
                                                 batch=batch)
        self.trained = training_lib.fit_predictor(
            log, targets=targets, max_samples=max_samples, seed=seed)
        self._last_log = log
        return self.trained

    # -- search ------------------------------------------------------------
    def interval_params(self, r_target: float) -> intervals_lib.IntervalParams:
        assert self.trained is not None, "call fit() first"
        # nearest trained target's dists_Rt; interpolate if between
        keys = sorted(self.trained.dists_rt)
        arr = np.array(keys)
        dists = np.array([self.trained.dists_rt[t] for t in keys])
        d = float(np.interp(r_target, arr, dists))
        return intervals_lib.heuristic_params(d)

    def interval_for_target(self, r_target) -> intervals_lib.IntervalParams:
        """Per-query IntervalParams for a scalar or [B] vector of
        declared targets — the ONE builder every serving call site
        (DarthServer, launch/serve, benchmarks) passes as its
        `interval_for_target`. Element j of the returned ipi/mpi arrays
        equals `interval_params(r_target[j])` exactly, so mixed-target
        slot pools stay per-slot consistent."""
        assert self.trained is not None, "call fit() first"
        keys = sorted(self.trained.dists_rt)
        arr = np.array(keys)
        dists = np.array([self.trained.dists_rt[t] for t in keys])
        rt = np.atleast_1d(np.asarray(r_target, np.float32))
        d = np.interp(rt.astype(np.float64), arr, dists)
        return intervals_lib.heuristic_params(d)

    def search(self, q: jax.Array, r_target: Union[float, jax.Array],
               ) -> Tuple[jax.Array, jax.Array, darth_search.DarthState]:
        """ANNS(q, G, k, R_t): returns (dists, ids, diagnostics state)."""
        assert self.trained is not None, "call fit() first"
        r_target = validate_targets(r_target, q.shape[0])
        rt_scalar = float(np.mean(r_target))
        params = self.interval_params(rt_scalar)
        st = darth_search.darth_search(self.engine, q, r_target,
                                       self.trained.predictor, params)
        return (self.engine.topk_d(st.inner), self.engine.topk_i(st.inner), st)

    def search_plain(self, q: jax.Array):
        inner = darth_search.plain_search(self.engine, q)
        return self.engine.topk_d(inner), self.engine.topk_i(inner), inner
