"""Declarative-recall competitors (paper §4: Baseline, REM, LAET).

  Baseline  terminate every query after dists_Rt distance calcs (§3.2.2).
  REM       Recall-to-efSearch/nprobe Mapping: one linear sweep over the
            effort parameter on validation queries; pick the smallest value
            whose mean recall >= target.
  LAET      Learned Adaptive Early Termination (Li et al. 2020): after a
            fixed initial search, predict the TOTAL distance calcs a query
            needs to find all its NNs, multiply by a hand-tuned multiplier,
            terminate at that budget. Multiplier tuned per target on
            validation queries (the paper's adaptation, §4 'Comparison
            Algorithms').
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import gbdt
from repro.core import darth_search, engines as engines_lib
from repro.core import features as features_lib
from repro.core.training import TrainLog
from repro.index import flat


# ---------------------------------------------------------------------------
# REM
# ---------------------------------------------------------------------------

class REM(NamedTuple):
    mapping: Dict[float, int]   # target recall -> effort parameter
    sweep: Dict[int, float]     # effort parameter -> measured mean recall


def fit_rem(make_engine: Callable[[int], engines_lib.Engine],
            q_val: jax.Array, gt_val: jax.Array,
            param_grid: Sequence[int],
            targets: Sequence[float]) -> REM:
    sweep = {}
    for p in sorted(param_grid):
        eng = make_engine(int(p))
        inner = darth_search.plain_search(eng, q_val)
        rec = float(np.asarray(flat.recall_at_k(eng.topk_i(inner), gt_val)).mean())
        sweep[int(p)] = rec
    mapping = {}
    for rt in targets:
        ok = [p for p, r in sweep.items() if r >= rt]
        mapping[float(rt)] = min(ok) if ok else max(sweep)
    return REM(mapping=mapping, sweep=sweep)


# ---------------------------------------------------------------------------
# LAET
# ---------------------------------------------------------------------------

class LAET(NamedTuple):
    params: gbdt.GBDTParams      # predicts log1p(total dists to all NNs)
    n0: int                      # fixed initial steps before prediction
    multipliers: Dict[float, float]


def _total_dists_to_final(log: TrainLog) -> np.ndarray:
    """Per-query ndis at the first step reaching its FINAL recall."""
    t, b = log.recall.shape
    final = log.recall[-1]
    hit = (log.recall >= final[None, :] - 1e-9) & log.valid
    t_idx = np.where(hit.any(0), hit.argmax(0), t - 1)
    return log.ndis[t_idx, np.arange(b)].astype(np.float64)


def fit_laet(log: TrainLog, *, n0: int = 2,
             cfg: gbdt.GBDTConfig = gbdt.GBDTConfig()) -> LAET:
    """Train LAET's total-effort regressor from the same step logs."""
    x = log.features[n0 - 1]            # features after the fixed prefix
    y = np.log1p(_total_dists_to_final(log))
    params = gbdt.fit(x, y.astype(np.float32), cfg)
    return LAET(params=params, n0=n0, multipliers={})


def laet_search(laet: LAET, engine: engines_lib.Engine, q: jax.Array,
                multiplier: float):
    """Run LAET: n0 fixed steps, one prediction, fixed budget after."""
    inner = engine.init(engine.index, q)
    for _ in range(laet.n0):
        inner = engine.step(engine.index, inner)
    feats = features_lib.extract(
        engine.nstep(inner), inner.ndis, inner.ninserts, inner.first_nn,
        engine.topk_d(inner))
    pred_total = jnp.expm1(gbdt.predict_efficient(laet.params, feats))
    budget = jnp.maximum(pred_total * multiplier,
                         inner.ndis.astype(jnp.float32))
    return _run_with_budget(engine, inner, budget)


def _run_with_budget(engine, inner, budget):
    def cond(carry):
        inner, t = carry
        return inner.active.any() & (t < engine.max_steps)

    def body(carry):
        inner, t = carry
        inner = engine.step(engine.index, inner)
        over = inner.ndis.astype(jnp.float32) >= budget
        inner = engines_lib.set_active(inner, inner.active & ~over)
        return inner, t + 1

    inner, _ = jax.lax.while_loop(cond, body, (inner, jnp.zeros((), jnp.int32)))
    return inner


def tune_laet(laet: LAET, engine: engines_lib.Engine, q_val: jax.Array,
              gt_val: jax.Array, targets: Sequence[float],
              lo: float = 0.1, hi: float = 3.0, steps: int = 8) -> LAET:
    """Binary-search the multiplier per target (monotone recall-vs-mult)."""
    mult = {}
    for rt in targets:
        a, b = lo, hi
        best = hi
        for _ in range(steps):
            mid = 0.5 * (a + b)
            inner = laet_search(laet, engine, q_val, mid)
            rec = float(np.asarray(
                flat.recall_at_k(engine.topk_i(inner), gt_val)).mean())
            if rec >= rt:
                best, b = mid, mid
            else:
                a = mid
        mult[float(rt)] = best
    return laet._replace(multipliers=mult)
