"""Search-quality measures used in the paper's evaluation (§4, Fig 10-16):
recall, RDE, RQUT, NRS, P99 error, worst-1% error."""
from __future__ import annotations

from typing import Dict

import numpy as np


def recall(found_ids: np.ndarray, true_ids: np.ndarray) -> np.ndarray:
    """Per-query recall@k. [B, k] int arrays (-1 = empty)."""
    b, k = true_ids.shape
    out = np.zeros((b,), np.float64)
    for i in range(b):
        f = set(x for x in found_ids[i].tolist() if x >= 0)
        out[i] = len(f & set(true_ids[i].tolist())) / k
    return out


def rde(found_d: np.ndarray, true_d: np.ndarray) -> np.ndarray:
    """Relative Distance Error per query: mean over the k slots of
    (d_found - d_true)/d_true using METRIC distances (sqrt of squared)."""
    f = np.sqrt(np.maximum(np.where(np.isfinite(found_d), found_d, 0.0), 0))
    t = np.sqrt(np.maximum(true_d, 0))
    denom = np.maximum(t, 1e-9)
    return np.mean(np.maximum(f - t, 0.0) / denom, axis=1)


def rqut(rec: np.ndarray, r_target: float) -> float:
    """Ratio of Queries Under the recall Target."""
    return float((rec < r_target - 1e-9).mean())


def nrs(found_ids: np.ndarray, gt_ids_wide: np.ndarray) -> np.ndarray:
    """Normalized Rank Sum per query: ideal_rank_sum / actual_rank_sum,
    in (0, 1]; 1 = perfect. gt_ids_wide: [B, K'] (K' >> k) true ranking;
    retrieved ids not in the top-K' get rank K'."""
    b, k = found_ids.shape
    kw = gt_ids_wide.shape[1]
    ideal = k * (k - 1) / 2.0 + k  # sum of ranks 1..k
    out = np.zeros((b,), np.float64)
    for i in range(b):
        pos = {int(v): r + 1 for r, v in enumerate(gt_ids_wide[i].tolist())}
        s = sum(pos.get(int(v), kw + 1) for v in found_ids[i].tolist())
        out[i] = ideal / max(s, 1)
    return out


def error_stats(rec: np.ndarray, r_target: float) -> Dict[str, float]:
    """P99 of |R_t - R_q| and mean error over the worst 1% (paper Fig 15/16).
    Error counts only shortfall below the target."""
    err = np.maximum(r_target - rec, 0.0)
    p99 = float(np.percentile(err, 99))
    n_worst = max(1, int(np.ceil(0.01 * len(err))))
    worst = float(np.sort(err)[-n_worst:].mean())
    return {"p99": p99, "worst1pct": worst}


def summarize(found_d, found_i, true_d, true_i, gt_wide_i,
              r_target: float) -> Dict[str, float]:
    rec = recall(found_i, true_i)
    return {
        "recall": float(rec.mean()),
        "rqut": rqut(rec, r_target),
        "rde": float(rde(found_d, true_d).mean()),
        "nrs": float(nrs(found_i, gt_wide_i).mean()),
        **error_stats(rec, r_target),
    }
