"""starcoder2-3b — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    mlp="gelu",
    source="arXiv:2402.19173; hf",
))
