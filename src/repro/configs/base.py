"""Architecture config schema + input-shape cells (deliverable f).

Every assigned architecture is a frozen `ArchConfig`; the four assigned
input shapes are `ShapeCell`s. `runnable()` encodes the assignment's skip
rules (long_500k needs sub-quadratic attention; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    norm: str = "rmsnorm"        # rmsnorm | nonparam_ln
    mlp: str = "swiglu"          # swiglu | gelu (2-matrix)
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid / linear-attn
    ssm_state: int = 0
    attn_every: int = 0          # hybrid: shared attn after every N ssm blocks
    # enc-dec / frontends
    encoder_layers: int = 0
    frontend: str = ""           # "" | audio_stub | vision_stub
    frontend_dim: int = 0        # stub embedding dim
    frontend_len: int = 0        # stub sequence length (frames / patches)
    # capabilities
    sub_quadratic: bool = False  # can run long_500k
    has_decoder: bool = True     # encoder-only archs skip decode shapes
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced config of the same family (smoke tests)."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def runnable(arch: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Assignment skip rules. Returns (runnable, reason-if-not)."""
    if shape.kind == "decode" and not arch.has_decoder:
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic attention (assignment skip rule)")
    return True, ""


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # populate registry lazily
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    from repro import configs as _c  # noqa: F401
    return tuple(sorted(_REGISTRY))
