"""olmo-1b — non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam_ln",
    source="arXiv:2402.00838; hf",
))
