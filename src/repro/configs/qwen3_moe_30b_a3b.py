"""qwen3-moe-30b-a3b — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,               # per-expert FFN width (assignment table)
    vocab_size=151936,
    head_dim=128,           # Qwen3 uses head_dim 128 (> d_model/heads)
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
