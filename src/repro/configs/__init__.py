"""Assigned-architecture configs (--arch <id>) + shape cells.

Importing this package populates the registry with all 10 assigned
architectures plus the retrieval-plane (DARTH) config.
"""
from repro.configs.base import (ArchConfig, SHAPES, ShapeCell, get_config,
                                list_configs, register, runnable)

# populate registry
from repro.configs import (glm4_9b, internvl2_26b, kimi_k2_1t_a32b, olmo_1b,
                           qwen3_moe_30b_a3b, rwkv6_3b, smollm_360m,
                           starcoder2_3b, whisper_base, zamba2_1p2b)

ALL_ARCHS = tuple(sorted([
    internvl2_26b.CONFIG.name, zamba2_1p2b.CONFIG.name,
    qwen3_moe_30b_a3b.CONFIG.name, kimi_k2_1t_a32b.CONFIG.name,
    glm4_9b.CONFIG.name, smollm_360m.CONFIG.name, olmo_1b.CONFIG.name,
    starcoder2_3b.CONFIG.name, rwkv6_3b.CONFIG.name, whisper_base.CONFIG.name,
]))

__all__ = ["ArchConfig", "SHAPES", "ShapeCell", "get_config", "list_configs",
           "register", "runnable", "ALL_ARCHS"]
