"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified]. input_specs() provides precomputed frame embeddings."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,            # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp="gelu",
    frontend="audio_stub",
    frontend_dim=512,        # post-conv frame embedding width
    frontend_len=1500,       # 30 s of audio at 50 Hz
    rope_theta=0.0,          # whisper uses learned/sinusoidal abs positions
    source="arXiv:2212.04356; unverified",
))
