"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2; unverified (paper-table)]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,              # per-expert FFN width (assignment table)
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    source="arXiv:2501.kimi2; unverified",
))
