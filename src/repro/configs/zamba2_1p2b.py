"""zamba2-1.2b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]. Hybrid: sub-quadratic (runs long_500k)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,          # Mamba2 blocks
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,              # shared-attn block MLP width
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,           # one shared attention application per 6 blocks
    sub_quadratic=True,
    source="arXiv:2411.15242; hf",
))
