"""internvl2-26b — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

The assignment specifies the transformer BACKBONE only; the InternViT
frontend is a stub (`input_specs()` provides precomputed patch embeddings
that a linear connector projects into the LM sequence).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision_stub",
    frontend_dim=3200,      # InternViT-6B embedding width
    frontend_len=256,       # patch tokens per image after pixel-shuffle
    sub_quadratic=False,
    source="arXiv:2404.16821; hf",
))
