"""rwkv6-3b — Finch: attention-free, data-dependent decay
[arXiv:2404.05892; hf]. Sub-quadratic (runs long_500k)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,           # head_dim 64 (rwkv6 standard)
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    ssm_state=64,
    sub_quadratic=True,
    source="arXiv:2404.05892; hf",
))
