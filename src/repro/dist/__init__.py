"""Distributed sharding subsystem: mesh-aware placement rules for model
parameters / optimizer state (dist.sharding), cross-shard search
collectives (dist.collectives), and index placement helpers.

Everything degrades to replication on axes that do not divide, so the
same code path runs on the single-device host mesh and the production
pod mesh (see launch/mesh.py).
"""
from repro.dist import collectives, sharding
from repro.dist.collectives import (make_sharded_beam_step,
                                    make_sharded_flat_search,
                                    make_sharded_probe_step)
from repro.dist.sharding import (batch_shardings, constrain_slots,
                                 opt_shardings, param_shardings,
                                 place_index, refresh_placed_view,
                                 replicated, slot_sharding)

__all__ = ["collectives", "sharding", "make_sharded_flat_search",
           "make_sharded_probe_step", "make_sharded_beam_step",
           "param_shardings", "opt_shardings", "place_index",
           "refresh_placed_view", "replicated",
           "batch_shardings", "slot_sharding", "constrain_slots"]
