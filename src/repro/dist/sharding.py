"""Mesh-aware PartitionSpec rules for parameter trees, optimizer state,
and ANN index placement.

The rules mirror the activation constraints in utils/meshctx.py: "tp"
resolves to the "model" axis, "dp" to ("pod", "data") — whichever of
those axes the mesh actually has. Every rule is divisibility-checked
per dimension: an axis that does not evenly divide the dimension is
dropped from the spec (replication), so the single-device host mesh
(1, 1) and odd shard counts never error, they just replicate more.

Weight layout convention (matches the matmuls in models/layers.py):
  * input projections  [.., d_in, d_out]: d_in over dp (FSDP), d_out
    over tp (Megatron column-parallel);
  * output projections [.., d_out, d_in] (wo / out_proj / cv): the
    contracted dim over tp (row-parallel), the other over dp;
  * leading stacked axes (lax.scan layer stacks, expert stacks) are
    never sharded — they are scanned over, not contracted;
  * vectors / scalars / norm scales / small depthwise convs replicate.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.padding import PAD_ID, PAD_SQNORM

PyTree = Any

# Row-parallel (output) projections: first of the trailing two dims is
# the contracted one.
_OUT_PROJ_NAMES = frozenset({"wo", "out_proj", "cv"})

# Always replicated regardless of shape: per-channel gains, SSM/RWKV
# per-head scalars, depthwise conv stencils, router logits tables.
_REPLICATED_NAMES = frozenset({
    "scale", "ln_x_scale", "norm_scale", "w0", "dt_bias", "a_log",
    "d_skip", "bonus_u", "conv_w", "router",
})


def _resolve_logical(mesh: Mesh, logical) -> Optional[Tuple[str, ...]]:
    """Logical axis name (or tuple of concrete mesh-axis names) -> tuple
    of mesh axes present on this mesh."""
    if logical is None:
        return None
    if isinstance(logical, (tuple, list)):
        axes = tuple(a for a in logical if a in mesh.axis_names)
        return axes or None
    if logical == "dp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes or None
    if logical == "tp":
        return ("model",) if "model" in mesh.axis_names else None
    # "hosts" (slot-pool serving, launch/mesh.make_serve_mesh) resolves
    # through the generic branch below: the slot dim splits ONLY over a
    # dedicated "hosts" axis — the collectives' batch specs and the
    # server's input placement both key on that exact name
    # (collectives.BATCH_AXIS), so resolving to any other axis here
    # would split inputs the device programs treat as replicated.
    return (logical,) if logical in mesh.axis_names else None


def spec_for(mesh: Mesh, shape: Sequence[int],
             logical: Sequence[Optional[str]]) -> P:
    """Divisibility-checked PartitionSpec from per-dim logical axes."""
    entries = []
    for dim, ax in zip(shape, logical):
        axes = _resolve_logical(mesh, ax)
        if axes is None:
            entries.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if size > 1 and dim % size == 0:
            entries.append(axes[0] if len(axes) == 1 else axes)
        else:
            entries.append(None)
    return P(*entries)


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated NamedSharding (the empty PartitionSpec)."""
    return NamedSharding(mesh, P())


def _leaf_name(path) -> str:
    if not path:
        return ""
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def _param_logical(name: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for one parameter leaf, by name and rank."""
    if ndim < 2 or name in _REPLICATED_NAMES or name.startswith("mu_"):
        return (None,) * ndim
    trailing = ("tp", "dp") if name in _OUT_PROJ_NAMES else ("dp", "tp")
    return (None,) * (ndim - 2) + trailing


def param_spec(name: str, shape: Sequence[int], mesh: Mesh) -> P:
    """PartitionSpec for one named parameter (via _param_logical)."""
    return spec_for(mesh, shape, _param_logical(name, len(shape)))


def param_shardings(tree: PyTree, mesh: Mesh) -> PyTree:
    """NamedSharding tree matching `tree` leaf-for-leaf.

    `tree` may hold arrays or ShapeDtypeStructs (abstract_params)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [NamedSharding(mesh, param_spec(_leaf_name(path), leaf.shape, mesh))
           for path, leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, out)


def _padded_spec(sharding: NamedSharding, ndim: int) -> Tuple:
    spec = tuple(sharding.spec)
    return spec + (None,) * (ndim - len(spec))


def _factored_shardings(p_sharding: NamedSharding, state_leaf: dict,
                        mesh: Mesh) -> dict:
    """Shardings for one adafactor per-leaf dict ({v_row, v_col, m} for
    factored leaves, {v, m} otherwise): derived from the param spec so
    moments stay colocated with their parameter shards. v_row/v_col drop
    one reduced param dim each, so their specs drop that dim's entry."""
    out = {}
    for key, arr in state_leaf.items():
        spec = _padded_spec(p_sharding, arr.ndim + 1)  # the param's rank
        if key == "v_row":      # param [.., R, C] -> [.., R]
            out[key] = NamedSharding(mesh, P(*spec[:-1]))
        elif key == "v_col":    # param [.., R, C] -> [.., C]
            out[key] = NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))
        else:  # "m", "v": full parameter shape
            out[key] = p_sharding
    return out


def opt_shardings(opt_state: PyTree, params: PyTree, mesh: Mesh) -> PyTree:
    """Shardings for an optimizer-state dict (adamw / adafactor / ef),
    leaf-for-leaf colocated with `param_shardings(params, mesh)`.

    Understands the repro.optim layouts:
      adamw:     {"m": <params>, "v": <params>, "step": scalar}
      adafactor: {"leaves": <params-of-{v_row,v_col,m}|{v,m}>, "step": ..}
      plus the optional error-feedback buffer "ef" (params structure).
    """
    p_sh = param_shardings(params, mesh)
    rep = replicated(mesh)
    out = {}
    for key, sub in opt_state.items():
        if key == "leaves":
            sh_leaves, treedef = jax.tree_util.tree_flatten(p_sh)
            state_dicts = treedef.flatten_up_to(sub)
            out[key] = jax.tree_util.tree_unflatten(
                treedef, [_factored_shardings(s, d, mesh)
                          for s, d in zip(sh_leaves, state_dicts)])
        elif key in ("m", "v", "ef"):
            out[key] = jax.tree.map(lambda _, s: s, sub, p_sh)
        else:  # "step" and any other bookkeeping scalars
            out[key] = jax.tree.map(lambda _: rep, sub)
    return out


# ---------------------------------------------------------------------------
# Batch / decode-cache placement (launch/dryrun.py contract)
# ---------------------------------------------------------------------------

_KV_CACHE_NAMES = frozenset({"k", "v", "ck", "cv", "shared_k", "shared_v"})


def batch_shardings(batch: PyTree, mesh: Mesh, kind: str = "train"
                    ) -> PyTree:
    """Input batches shard the leading (global-batch) dim over dp; all
    other dims (seq, patch/frame features) stay replicated — sequence
    sharding is an *activation* concern (meshctx "sp"), not an input
    placement. `kind` is accepted for symmetry across train / prefill /
    decode (same rule), except `kind="serve"`: the leading dim is the
    slot-pool SLOT dim, which splits over the "hosts" axis (and only
    that axis — the device programs key on collectives.BATCH_AXIS) so
    each host group's devices own exactly the slot slice its host loop
    manages (serve.engine.DarthServer)."""
    lead = "hosts" if kind == "serve" else "dp"

    def leaf(x):
        logical = (lead,) + (None,) * (x.ndim - 1)
        return NamedSharding(mesh, spec_for(mesh, x.shape, logical))

    return jax.tree.map(leaf, batch)


def slot_sharding(mesh: Mesh, num_slots: int, trailing: int = 0
                  ) -> NamedSharding:
    """Sharding for one slot-pool array [num_slots, ...]: the slot dim
    over "hosts" (see batch_shardings kind="serve"), trailing dims
    replicated. Degrades to replication when the axis is absent or does
    not divide num_slots."""
    shape = (num_slots,) + (1,) * trailing
    logical = ("hosts",) + (None,) * trailing
    return NamedSharding(mesh, spec_for(mesh, shape, logical))


def constrain_slots(tree: PyTree, mesh: Mesh, num_slots: int) -> PyTree:
    """Pin every per-slot leaf of a search-state tree host-local.

    Applies jax.lax.with_sharding_constraint with the slot dim split
    over the "hosts" axis (slot_sharding) to each leaf whose leading dim
    is num_slots, leaving other leaves untouched. Used by the serve
    chunk jits at the fori_loop carry boundaries so the GSPMD
    partitioner keeps the whole chunk state split over host groups
    instead of resolving the loop carry to replicated (which would
    re-gather the per-slot bookkeeping across hosts every step).
    Trailing dims stay UNCONSTRAINED — the HNSW visited bitmap [B, N]
    keeps its node-dim "model" split, only its slot dim is pinned.
    No-op when the mesh has no "hosts" axis or it does not divide
    num_slots (the divisibility contract of slot_sharding)."""
    if ("hosts" not in mesh.axis_names or mesh.shape["hosts"] <= 1
            or num_slots % mesh.shape["hosts"]):
        return tree

    def pin(x):
        if (hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == num_slots):
            spec = P(*(("hosts",) + (P.UNCONSTRAINED,) * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return x

    return jax.tree.map(pin, tree)


def cache_shardings(cache: PyTree, mesh: Mesh) -> PyTree:
    """Decode caches: batch dim over dp, kv-head dim of attention caches
    over tp (matching the attention weight sharding). The batch dim is 1
    past the leading stacked layer axes — 2 under the hybrid "groups"
    subtree (layout [n_groups, group, batch, ..]), 1 everywhere else."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, x in leaves:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        bdim = 2 if "groups" in keys[:-1] else 1
        logical = [None] * x.ndim
        if x.ndim > bdim:
            logical[bdim] = "dp"
        if keys and keys[-1] in _KV_CACHE_NAMES and x.ndim >= 5:
            logical[-2] = "tp"
        out.append(NamedSharding(mesh, spec_for(mesh, x.shape, logical)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Index placement
# ---------------------------------------------------------------------------

def database_sharding(mesh: Mesh, n_rows: int) -> NamedSharding:
    """Row-shard an [N, D] vector database over the "model" axis
    (replicates when the axis is absent or does not divide N)."""
    return NamedSharding(mesh, spec_for(mesh, (n_rows, 1), ("tp", None)))


# Bucket-store arrays whose cap dim (axis 1) is split across shards.
# bucket_sizes [nlist] is NOT here: it replicates so the replicated probe
# bookkeeping (ndis counters) can read true bucket populations directly.
_CAP_SHARDED_NAMES = {"bucket_vecs": 0.0, "bucket_ids": PAD_ID,
                      "bucket_sqnorm": PAD_SQNORM}  # name -> cap-pad value

# HNSW graph arrays whose node dim (axis 0) is split across shards.
# entry / route_ids replicate: routing and frontier bookkeeping stay
# replicated, only vector rows and adjacency rows live on their shard.
_ROW_SHARDED_NAMES = {"vectors": 0.0, "neighbors": PAD_ID,
                      "sqnorm": PAD_SQNORM}  # name -> row-pad value


def place_index(index: Any, mesh: Mesh) -> Any:
    """Place an ANN index dataclass onto `mesh` for the sharded search
    collectives (dist.collectives):

    * IVF (make_sharded_probe_step): every bucket's row block [cap, D]
      is split on the cap dim over the "model" axis, so each shard scans
      its slice of EVERY probed bucket and only [B, k] candidate lists
      cross shards. The small centroid / dequant tables and the
      bucket_sizes counters replicate.
    * HNSW (make_sharded_beam_step): vectors [N, D], sqnorm [N] and
      neighbors [N, M] are split on the node dim over "model", so each
      shard owns a contiguous row block of the graph and only [B, M]
      id/distance frontiers cross shards per beam step. entry and
      route_ids replicate (the routing scan and frontier bookkeeping
      are replicated).

    The sharded dim (cap / node count) is padded up to a shard-count
    multiple first; padded slots keep the index's own padding contract
    (vecs 0, ids -1, sqnorm +inf) so they can never surface in a top-k.
    Degrades to full replication on a 1-device mesh, so the serve path
    is identical.

    On a serve mesh with a "hosts" axis (launch/mesh.make_serve_mesh)
    the index stays GLOBAL: every spec here names only "model", so the
    placed arrays replicate across host groups — each host group's
    devices see the whole sharded index while the slot-pool state splits
    over "hosts" (batch_shardings kind="serve")."""
    import dataclasses

    from repro.dist import collectives

    # Mutable-index view (repro.mutate): shard the base index with the
    # rules below; the delta ring stays REPLICATED on every shard (it is
    # small by construction and replicating it keeps the per-query delta
    # scan collective-free). Tombstones need no handling of their own —
    # they live inside the base arrays as pad-convention slots (sqnorm
    # +inf / ids -1) and travel row-sharded with them.
    from repro.mutate.engine import MutableIndexView

    if isinstance(index, MutableIndexView):
        rep = replicated(mesh)
        return dataclasses.replace(
            index,
            base=place_index(index.base, mesh),
            delta=jax.tree.map(lambda a: jax.device_put(a, rep),
                               index.delta))

    nshards = collectives.shard_count(mesh)

    def pad_dim(arr: jax.Array, dim: int, value) -> jax.Array:
        pad = -arr.shape[dim] % nshards
        if not pad:
            return arr
        widths = [(0, 0)] * arr.ndim
        widths[dim] = (0, pad)
        return jnp.pad(arr, widths, constant_values=value)

    def place(name: str, arr: jax.Array) -> jax.Array:
        if name in _CAP_SHARDED_NAMES:
            arr = pad_dim(arr, 1, _CAP_SHARDED_NAMES[name])
            logical = (None, "tp") + (None,) * (arr.ndim - 2)
        elif name in _ROW_SHARDED_NAMES:
            arr = pad_dim(arr, 0, _ROW_SHARDED_NAMES[name])
            logical = ("tp",) + (None,) * (arr.ndim - 1)
        else:
            logical = (None,) * arr.ndim
        sh = NamedSharding(mesh, spec_for(mesh, arr.shape, logical))
        return jax.device_put(arr, sh)

    if dataclasses.is_dataclass(index):
        return dataclasses.replace(index, **{
            f.name: place(f.name, getattr(index, f.name))
            for f in dataclasses.fields(index)
            if hasattr(getattr(index, f.name), "ndim")})
    return jax.tree.map(lambda a: place("", a), index)


def refresh_placed_view(view: Any, mesh: Mesh, *, base: Any = None,
                        delta: Any = None) -> Any:
    """Shadow-view placement: re-place ONLY the changed component of an
    already-placed MutableIndexView (repro.mutate).

    The double-buffered serving swap needs the shadow base ON the mesh
    before the chunk-boundary hot-swap, and the streaming delta refresh
    happens every few boundaries — re-placing the whole view each time
    would re-transfer the large unchanged half too. `base` (when given,
    an UNPLACED index) is placed with the place_index rules (cap / node
    dim split over "model"); `delta` (when given) is replicated per
    mutate's sharding contract. A component passed as None keeps its
    committed placement untouched, so the transfer cost of a delta
    write is the ring only, and the shadow base transfer runs off the
    serve path (before request_swap), never inside a chunk boundary."""
    import dataclasses

    from repro.mutate.engine import MutableIndexView

    if not isinstance(view, MutableIndexView):
        raise TypeError(
            f"refresh_placed_view needs a MutableIndexView, got "
            f"{type(view).__name__}")
    rep = replicated(mesh)
    return dataclasses.replace(
        view,
        base=view.base if base is None else place_index(base, mesh),
        delta=view.delta if delta is None else jax.tree.map(
            lambda a: jax.device_put(a, rep), delta))


__all__ = ["param_shardings", "opt_shardings", "batch_shardings",
           "cache_shardings", "param_spec", "spec_for", "replicated",
           "database_sharding", "place_index", "refresh_placed_view",
           "slot_sharding", "constrain_slots"]
