"""Cross-shard search collectives: the database is row-sharded over the
"model" mesh axis, each shard runs the fused l2_topk kernel on its local
rows, and the per-shard candidates are merged with one small all-gather —
collective volume O(B * k * shards * 8 bytes), independent of N.

Three sharded entry points:
  * make_sharded_flat_search — exact flat k-NN over a row-sharded [N, D]
    database (ground truth / brute-force baseline).
  * make_sharded_probe_step — one IVF probe over a CAP-sharded bucket
    store [nlist, cap, D] (dist.sharding.place_index splits the cap dim
    over "model"): each shard scans its local slice of the probed bucket
    with the fused bucket_topk kernel, candidates merge via one tiled
    [B, k] all-gather + merge_topk, insert counters psum. Per-probe
    traffic drops from the GSPMD gather's O(B*cap*D) to O(B*k*shards).
  * make_sharded_beam_step — one HNSW beam expansion over a ROW-sharded
    graph (dist.sharding.place_index splits vectors/sqnorm/neighbors on
    the node dim over "model"; the per-query visited structure — exact
    [B, N] bitmap or fixed-width hashed filter [B, W] — splits on its
    second dim too): the shard owning each query's selected
    candidate resolves its adjacency row (one [B, M] psum), every shard
    scans the neighbors IT owns against its local vectors/visited slice,
    and the per-shard [B, M] distance frontiers merge via one tiled
    all-gather + positional min + top-k. Per-step traffic drops from the
    GSPMD gather's O(B*M*D) to O(B*M*shards), independent of N and D.

Padding contract: the sharded dim (N rows / bucket cap / graph nodes) is
padded up to a multiple of the shard count; padded slots carry
sqnorm = +inf so they can never enter a top-k, padded ids (bucket_ids /
neighbors rows) are -1, and any slot whose distance is +inf reports
id -1 (same convention as index/flat.py, index/ivf.py, index/hnsw.py).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.padding import (PAD_DIST, PAD_ID, PAD_SQNORM, pad_dists,
                                pad_ids)
from repro.kernels import ops

SHARD_AXIS = "model"

# Slot-pool serving (serve.engine.DarthServer on a make_serve_mesh):
# when the mesh carries a "hosts" axis, the search state's slot (batch)
# dim splits over it inside the probe/beam shard_maps, so each host
# group's devices step only the slot slice its host loop owns. The
# "model"-axis collectives then run WITHIN a host group — the per-chunk
# all-gather/psum operands shrink from [B, ..] to [B/hosts, ..]. Absent
# the axis, the spec entry is None and the programs are unchanged.
#
# Slot-dim top-k pinning: jax.lax.top_k lowers to a TopK custom-call,
# which the GSPMD partitioner cannot split — any top-k over the
# hosts-split slot dim that runs OUTSIDE a shard_map forces the
# partitioner to all-gather its operand across host groups first (the
# replicated-frontier reshard the ROADMAP flagged at ~1.12x). Both
# sharded steps therefore run their candidate merges INSIDE a shard_map
# whose batch spec is P(BATCH_AXIS, ...), so the custom-call only ever
# sees each host group's local slot rows (benchmarks/dist_search.py
# dist_multi_host_serve gates the resulting per-chunk byte win).
BATCH_AXIS = "hosts"


def shard_count(mesh: Mesh, axis: str = SHARD_AXIS) -> int:
    """Size of `axis` on `mesh` (1 when the mesh lacks the axis)."""
    return int(mesh.shape[axis]) if axis in mesh.axis_names else 1


def _batch_axis(mesh: Mesh) -> "str | None":
    return BATCH_AXIS if BATCH_AXIS in mesh.axis_names else None


def merge_topk(cand_d: jax.Array, cand_i: jax.Array, k: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Merge [B, M] candidate (dist, id) lists to the best k per row.
    +inf candidates (shard padding) are masked back to id -1."""
    neg, pos = jax.lax.top_k(-cand_d, k)
    d = -neg
    i = jnp.take_along_axis(cand_i, pos, axis=1)
    return d, jnp.where(jnp.isfinite(d), i, PAD_ID)


def make_sharded_flat_search(mesh: Mesh, k: int, *, axis: str = SHARD_AXIS,
                             use_kernel: bool = True, interpret: bool = True
                             ) -> Callable[[jax.Array, jax.Array],
                                           Tuple[jax.Array, jax.Array]]:
    """Exact flat k-NN over a database sharded on `axis`.

    Returns fn(q [B, D], x [N, D]) -> (dist [B, k] ascending, idx [B, k]),
    numerically matching index.flat.search on any shard count (including
    the 1-device host mesh). Queries are replicated; per-shard local
    top-k uses the fused Pallas kernel (interpret-mode on CPU), the
    cross-shard merge is one tiled all-gather of [B, k] + top_k.
    """
    nshards = shard_count(mesh, axis)

    def local_topk(q, x_loc, sqn_loc):
        if use_kernel:
            d_loc, i_loc = ops.l2_topk(q, x_loc, k=k, x_sqnorm=sqn_loc,
                                       interpret=interpret)
        else:  # pure-XLA: padded rows enter with sqn=+inf, never win
            qf = q.astype(jnp.float32)
            d2 = (jnp.sum(qf ** 2, 1)[:, None] + sqn_loc[None, :]
                  - 2.0 * qf @ x_loc.astype(jnp.float32).T)
            if d2.shape[1] < k:  # fewer local rows than k: pad candidates
                d2 = jnp.pad(d2, ((0, 0), (0, k - d2.shape[1])),
                             constant_values=PAD_DIST)
            neg, i_loc = jax.lax.top_k(-d2, k)
            d_loc = jnp.maximum(-neg, 0.0)
        rows = x_loc.shape[0]
        base = jax.lax.axis_index(axis) * rows
        i_glob = jnp.where(jnp.isfinite(d_loc) & (i_loc >= 0),
                           i_loc + base, PAD_ID)
        cand_d = jax.lax.all_gather(d_loc, axis, axis=1, tiled=True)
        cand_i = jax.lax.all_gather(i_glob, axis, axis=1, tiled=True)
        return merge_topk(cand_d, cand_i, k)

    sharded = shard_map(
        local_topk, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis)),
        out_specs=(P(), P()),
        check_rep=False)

    @jax.jit
    def search(q: jax.Array, x: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        n = x.shape[0]
        per_shard = -(-n // nshards)
        pad = per_shard * nshards - n
        sqn = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        sqnp = jnp.pad(sqn, (0, pad), constant_values=PAD_SQNORM)
        return sharded(q, xp, sqnp)

    return search


# Keyed on the mesh GEOMETRY + device ids, not the Mesh object: a Mesh
# key would hold the mesh (and through jit caches, its device buffers)
# alive across tests, and two equivalent meshes would compile twice.
# Equivalent-mesh hits reuse the first mesh's compiled fn — same axes
# over the same devices in the same order means identical placement;
# meshes over different device subsets get their own entries.
_SEARCH_CACHE: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()
_SEARCH_CACHE_MAX = 8


def _mesh_key(mesh: Mesh) -> tuple:
    return (tuple(mesh.shape.items()),              # ordered (axis, size)
            tuple(d.id for d in mesh.devices.flat))


def _memoized(cache: "collections.OrderedDict[tuple, Callable]", key: tuple,
              build: Callable[[], Callable]) -> Callable:
    """Shared LRU memo for the jitted sharded-step builders."""
    fn = cache.get(key)
    if fn is None:
        while len(cache) >= _SEARCH_CACHE_MAX:
            cache.popitem(last=False)
        fn = cache[key] = build()
    else:
        cache.move_to_end(key)
    return fn


def _cached_search(mesh: Mesh, k: int):
    return _memoized(_SEARCH_CACHE, (_mesh_key(mesh), k),
                     lambda: make_sharded_flat_search(mesh, k))


def sharded_flat_search(q: jax.Array, x: jax.Array, k: int, mesh: Mesh
                        ) -> Tuple[jax.Array, jax.Array]:
    """One-shot convenience wrapper (builds + caches the jitted fn)."""
    return _cached_search(mesh, k)(q, x)


# ---------------------------------------------------------------------------
# Sharded IVF probe
# ---------------------------------------------------------------------------

# Same geometry-keyed caching rationale as _SEARCH_CACHE: a fresh jitted
# step per call would defeat jit's function-identity cache and recompile
# the shard_map program on every search_sharded invocation.
_PROBE_CACHE: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()

_INIT_CACHE: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()


def make_sharded_ivf_init(mesh: Mesh, *, axis: str = SHARD_AXIS
                          ) -> Callable[..., Any]:
    """IVF search-state init with the probe-order ranking PINNED.

    ivf.init_state ranks centroids with a jax.lax.top_k over [B, nlist]
    — an unpartitionable TopK custom-call. Inside the server's init
    chunk the slot dim B is hosts-split, so the plain init forces GSPMD
    to all-gather the centroid distances across host groups before the
    ranking (the same bug class the step merges' pin_merge fixed; the
    analysis gate's unpartitionable-topk pass caught this one). Running
    ivf.rank_centroids inside a batch-axis shard_map keeps the ranking
    on each host group's local slot rows. Bookkeeping and results are
    bit-identical to ivf.init_state on any mesh; without a hosts axis
    the shard_map is skipped entirely and this IS ivf.init_state.
    """
    from repro.index import ivf as ivf_lib

    key = (_mesh_key(mesh), axis)
    bh = _batch_axis(mesh)

    def init(index: Any, q: jax.Array, *, k: int, nprobe: int) -> Any:
        qf = q.astype(jnp.float32)
        qsq = jnp.sum(qf ** 2, axis=1, keepdims=True)
        if bh is None:
            order, first_nn = ivf_lib.rank_centroids(
                index.centroids, qf, qsq, nprobe)
        else:
            rank = shard_map(
                lambda c, qf_loc, qsq_loc: ivf_lib.rank_centroids(
                    c, qf_loc, qsq_loc, nprobe),
                mesh=mesh,
                in_specs=(P(None, None), P(bh, None), P(bh, None)),
                out_specs=(P(bh, None), P(bh)),
                check_rep=False)
            order, first_nn = rank(index.centroids, qf, qsq)
        return ivf_lib.fresh_state(qf, qsq, order, first_nn, k)

    return _memoized(_INIT_CACHE, key,
                     lambda: jax.jit(init,
                                     static_argnames=("k", "nprobe")))


def make_sharded_probe_step(mesh: Mesh, *, axis: str = SHARD_AXIS,
                            use_kernel: bool = True, interpret: bool = True,
                            pin_merge: bool = True
                            ) -> Callable[[Any, Any], Any]:
    """One IVF probe step over a cap-sharded bucket store.

    Returns step(index, state) -> state, a drop-in replacement for
    index.ivf.probe_step when the index was placed with
    dist.sharding.place_index(index, mesh): bucket_vecs [nlist, cap, D],
    bucket_ids / bucket_sqnorm [nlist, cap] are split on the cap dim over
    `axis`; centroids, bucket_sizes and the SQ8 dequant tables replicate.

    Per shard the probed bucket's local slice [B, cap/S, D] is scanned
    with the fused bucket_topk kernel (pure-XLA fallback when
    use_kernel=False) into per-shard top-k candidates; the only
    cross-shard traffic is one tiled [B, k] all-gather of (dist, id)
    pairs + an insert-count psum. Bookkeeping (probe cursor, active
    masks, ndis from the replicated bucket_sizes) is replicated and
    identical to the single-device step, so results match
    index.ivf.search exactly on any shard count. A cold-tier store
    (index.hot_map set, serve.cold) resolves bucket ids to device
    slots through the replicated map first; cold buckets skip with the
    same semantics as index.ivf.probe_step and add no collective.

    `pin_merge` keeps the running-top-k merge (a jax.lax.top_k, i.e. an
    unpartitionable TopK custom-call) INSIDE the shard_map so it runs on
    each host group's local slot rows; False restores the pre-pinning
    layout (merge outside the shard_map, forcing a cross-host gather of
    the [B, k + k*shards] candidate array when the mesh has a hosts
    axis) so benchmarks can measure the before/after traffic. The two
    layouts are numerically identical.
    """
    key = (_mesh_key(mesh), axis, use_kernel, interpret, pin_merge)
    nshards = shard_count(mesh, axis)
    bh = _batch_axis(mesh)

    def probe_step(index: Any, s: Any) -> Any:
        b, k = s.topk_d.shape
        nprobe = s.probe_order.shape[1]
        cap = index.bucket_vecs.shape[1]
        if cap % nshards:
            raise ValueError(
                f"bucket cap {cap} not divisible by {nshards} shards; "
                f"place the index with dist.place_index(index, mesh) "
                f"(it pads cap to a shard multiple)")
        pos = jnp.minimum(s.probe_pos, nprobe - 1)
        bucket = jnp.take_along_axis(s.probe_order, pos[:, None],
                                     axis=1)[:, 0]
        sizes = index.bucket_sizes[bucket]       # replicated [B]
        if index.hot_map is not None:
            # Cold-tier store (serve.cold): bucket ids resolve through
            # the replicated hot map to device store slots. A cold
            # bucket (slot -1) is skipped THIS step — the probe cursor
            # still advances, the scan contributes no candidates and
            # the masked sizes keep ndis honest. No extra collective.
            slot = index.hot_map[bucket]
            hot = slot >= 0
            slot = jnp.maximum(slot, 0)
            sizes = jnp.where(hot, sizes, 0)
        else:
            slot = bucket
            hot = jnp.ones_like(bucket, dtype=bool)

        if index.quantized:
            # asymmetric SQ8 via the kernel's bias term:
            # ||x_hat - q||^2 = sqn - 2[(q*scale).x8 + q.offset] + ||q||^2
            q_eff = s.q * index.scale[None, :]
            bias = s.qsq - 2.0 * (s.q @ index.offset)[:, None]
        else:
            q_eff = s.q
            bias = s.qsq
        def scan(q_eff, bias, topk_d, topk_i, slot, hot, vecs, sqn, ids):
            # Local batch size, NOT the outer b: with a "hosts" batch
            # axis each host group scans only its slot slice.
            bl = q_eff.shape[0]
            kth = topk_d[:, -1:]
            v = vecs[slot]                       # [Bl, capS, D] local gather
            # Cold (unresident) buckets degrade to the padding contract
            # (ids -1 / sqnorm +inf): no candidate, no insert count.
            sq = jnp.where(hot[:, None], sqn[slot], PAD_SQNORM)
            id_ = jnp.where(hot[:, None], ids[slot], PAD_ID)
            if use_kernel:
                run_d = pad_dists((bl, k))
                run_i = pad_ids((bl, k))
                d_loc, i_loc, cnt = ops.bucket_probe(
                    q_eff, v, sq, id_, bias, kth, run_d, run_i,
                    interpret=interpret)
            else:
                dist = (sq.astype(jnp.float32)
                        - 2.0 * jnp.einsum("bd,bcd->bc", q_eff,
                                           v.astype(jnp.float32))
                        + bias)
                dist = jnp.where(id_ >= 0, jnp.maximum(dist, 0.0), PAD_DIST)
                cnt = jnp.sum(dist < kth, axis=1).astype(jnp.int32)
                if dist.shape[1] < k:   # tiny shard slice: pad candidates
                    pad = k - dist.shape[1]
                    dist = jnp.pad(dist, ((0, 0), (0, pad)),
                                   constant_values=PAD_DIST)
                    id_ = jnp.pad(id_, ((0, 0), (0, pad)),
                                  constant_values=PAD_ID)
                neg, sel = jax.lax.top_k(-dist, k)
                d_loc = -neg
                i_loc = jnp.take_along_axis(id_, sel, axis=1)
            i_loc = jnp.where(jnp.isfinite(d_loc), i_loc, PAD_ID)
            cand_d = jax.lax.all_gather(d_loc, axis, axis=1, tiled=True)
            cand_i = jax.lax.all_gather(i_loc, axis, axis=1, tiled=True)
            if not pin_merge:
                return cand_d, cand_i, jax.lax.psum(cnt, axis)
            # Merge INSIDE the shard_map: the TopK custom-call then only
            # sees this host group's slot rows (see BATCH_AXIS note).
            # Replicated across `axis` within a host group — every
            # device holds the full gathered candidates, same values.
            new_d, new_i = merge_topk(
                jnp.concatenate([topk_d, cand_d], axis=1),
                jnp.concatenate([topk_i, cand_i], axis=1), k)
            return new_d, new_i, jax.lax.psum(cnt, axis)

        sharded = shard_map(
            scan, mesh=mesh,
            in_specs=(P(bh, None), P(bh, None), P(bh, None), P(bh, None),
                      P(bh), P(bh), P(None, axis, None), P(None, axis),
                      P(None, axis)),
            out_specs=(P(bh, None), P(bh, None), P(bh)),
            check_rep=False)
        out_d, out_i, cnt = sharded(
            q_eff, bias, s.topk_d, s.topk_i, slot, hot,
            index.bucket_vecs, index.bucket_sqnorm, index.bucket_ids)

        if pin_merge:
            new_d, new_i = out_d, out_i
        else:
            new_d, new_i = merge_topk(
                jnp.concatenate([s.topk_d, out_d], axis=1),
                jnp.concatenate([s.topk_i, out_i], axis=1), k)
        inserts = jnp.minimum(cnt, k)
        done_probes = s.probe_pos + s.active.astype(jnp.int32)
        return dataclasses.replace(
            s,
            probe_pos=done_probes,
            topk_d=jnp.where(s.active[:, None], new_d, s.topk_d),
            topk_i=jnp.where(s.active[:, None], new_i, s.topk_i),
            active=s.active & (done_probes < nprobe),
            ndis=s.ndis + jnp.where(s.active, sizes, 0).astype(jnp.int32),
            ninserts=s.ninserts + jnp.where(s.active, inserts, 0),
        )

    # Jitted with the index as an ARGUMENT (not a closure constant):
    # closure-captured consts drop their committed cap-axis sharding, and
    # the whole bucket store would be re-laid-out replicated per device.
    return _memoized(_PROBE_CACHE, key, lambda: jax.jit(probe_step))


# ---------------------------------------------------------------------------
# Sharded HNSW beam step
# ---------------------------------------------------------------------------

_BEAM_CACHE: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()


def make_sharded_beam_step(mesh: Mesh, *, axis: str = SHARD_AXIS,
                           pin_merge: bool = True) -> Callable[..., Any]:
    """One HNSW beam expansion over a row-sharded graph.

    Returns step(index, state, k=..) -> state, a drop-in replacement for
    index.hnsw.beam_step when the index was placed with
    dist.sharding.place_index(index, mesh): vectors [N, D], sqnorm [N]
    and neighbors [N, M] are split on the node dim over `axis` (N padded
    to a shard multiple; pad rows carry sqnorm +inf / neighbor ids -1),
    and the search state's visited bitmap [B, N] splits on its node dim.

    Frontier bookkeeping (cand_d / cand_i / cand_exp, [B, ef]) stays
    replicated and identical to the single-device step. Per step, under
    one shard_map:

      1. the shard owning each query's selected candidate contributes
         its adjacency row; a [B, M] psum reconstructs the global
         neighbor-id frontier on every shard,
      2. each shard resolves the neighbors IT owns against its local
         visited slice and vectors (gather + batched distance), masking
         everything else to +inf, and updates its visited slice,
      3. the per-shard [B, M] masked distances merge via one tiled
         all-gather; a positional min over the shard dim restores the
         exact single-device candidate layout (each neighbor is owned by
         exactly one shard), so the ef-merge top-k breaks ties exactly
         like index.hnsw.beam_step and results (topk_d / topk_i / ndis /
         ninserts) match bit-for-bit on any shard count.

    Cross-shard traffic is one [B, M] i32 psum + one [B, M] f32
    all-gather per step — O(B*M*shards) bytes, independent of N and D,
    versus the O(B*M*D) vector gather GSPMD emits for the unsharded
    step on a mesh-placed index.

    When the state carries a HASHED visited filter [B, W] (W < N,
    hnsw.init_state's visited_width; W must be a shard-count multiple)
    step 2 resolves membership at the hash slot's owner instead: one
    extra [B, M] i32 psum rebuilds the global seen mask, keeping the
    per-step traffic N-independent, and the skip behaviour (including
    hash-collision false positives) matches the single-device hashed
    step bit-for-bit. SQ8-resident graphs (int8 vectors) just cast the
    gathered rows — the state's effective query / bias fold the dequant
    transform, so the collective layout is unchanged.

    `pin_merge` runs the frontier merge's top-k (hnsw.frontier_topk, an
    unpartitionable TopK custom-call) inside a batch-axis shard_map so
    it stays on each host group's local slot rows; False restores the
    pre-pinning layout (merge outside, forcing a cross-host gather of
    the [B, ef + M] frontier on a hosts mesh). Numerically identical
    either way — the shard_map wraps the very same frontier_topk.
    """
    key = (_mesh_key(mesh), axis, pin_merge)
    nshards = shard_count(mesh, axis)
    bh = _batch_axis(mesh)

    def local_frontier_topk(cand_d, cand_i, cand_e, ef):
        from repro.index import hnsw as hnsw_lib
        fn = shard_map(
            lambda d, i, e: hnsw_lib.frontier_topk(d, i, e, ef),
            mesh=mesh,
            in_specs=(P(bh, None), P(bh, None), P(bh, None)),
            out_specs=(P(bh, None), P(bh, None), P(bh, None)),
            check_rep=False)
        return fn(cand_d, cand_i, cand_e)

    def beam_step(index: Any, s: Any, *, k: int) -> Any:
        from repro.index import hnsw as hnsw_lib

        b = s.cand_d.shape[0]
        mdeg = index.degree
        if index.num_vectors % nshards:
            raise ValueError(
                f"graph has {index.num_vectors} rows, not divisible by "
                f"{nshards} shards; place the index with "
                f"dist.place_index(index, mesh) (it pads the node dim)")
        # Exact [B, N] bitmap or fixed-width hashed filter [B, W]: the
        # structure is whatever init_state built (static at trace time);
        # either way the visited dim splits over `axis`.
        width = s.visited.shape[1]
        hashed = width < index.num_vectors
        if width % nshards:
            raise ValueError(
                f"visited width {width} not divisible by {nshards} "
                f"shards; pick a power-of-two visited_width that the "
                f"shard count divides")

        # Replicated frontier bookkeeping — shared with hnsw.beam_step
        # so the two steps cannot drift out of parity.
        sel_id_safe, act, cand_exp = hnsw_lib.select_expand(s)

        def expand(q, qsq, sel_id, act, vec_loc, sqn_loc, nbr_loc, vis_loc):
            # Local batch size, NOT the outer b: with a "hosts" batch
            # axis each host group expands only its slot slice.
            bl = q.shape[0]
            rows = vec_loc.shape[0]
            base = jax.lax.axis_index(axis) * rows
            # 1. owner of the selected node contributes its adjacency row
            own_sel = (sel_id >= base) & (sel_id < base + rows)
            sel_loc = jnp.clip(sel_id - base, 0, rows - 1)
            nbrs = jax.lax.psum(
                jnp.where(own_sel[:, None], nbr_loc[sel_loc] + 1, 0),
                axis) - 1                                    # [Bl, M] global
            # 2. scan the neighbors this shard owns
            valid = (nbrs >= 0) & act[:, None]
            owned = valid & (nbrs >= base) & (nbrs < base + rows)
            loc = jnp.where(owned, nbrs - base, 0)
            if hashed:
                # Hashed filter: membership lives at the HASH SLOT's
                # owner, not the vector row's. The slot owner reads its
                # local filter slice and one [Bl, M] i32 psum rebuilds
                # the global seen mask (each slot has exactly one
                # owner), matching hnsw.beam_step's hashed read
                # bit-for-bit — collisions skip the same nodes. The
                # slot owner then sets the bits for every VALID
                # neighbor, as the single-device step does.
                from repro.index import hnsw as hnsw_lib
                slots = hnsw_lib.hash_slot(jnp.maximum(nbrs, 0), width)
                rows_v = vis_loc.shape[1]
                base_v = jax.lax.axis_index(axis) * rows_v
                own_slot = (slots >= base_v) & (slots < base_v + rows_v)
                loc_slot = jnp.where(own_slot, slots - base_v, 0)
                hit = jnp.take_along_axis(vis_loc, loc_slot, axis=1)
                seen = jax.lax.psum(
                    (hit & own_slot).astype(jnp.int32), axis) > 0
                vis_loc = vis_loc.at[
                    jnp.arange(bl)[:, None], loc_slot].max(
                        own_slot & valid)
            else:
                seen = jnp.take_along_axis(vis_loc, loc, axis=1)
                vis_loc = vis_loc.at[
                    jnp.arange(bl)[:, None], loc].max(owned)
            new = owned & ~seen
            vecs = vec_loc[loc].astype(jnp.float32)          # [Bl, M, D]
            dist = (sqn_loc[loc]
                    - 2.0 * jnp.einsum("bd,bmd->bm", q, vecs) + qsq)
            dist = jnp.where(new, jnp.maximum(dist, 0.0), PAD_DIST)
            # 3. merge the masked per-shard frontiers
            dist_all = jax.lax.all_gather(dist, axis, axis=1, tiled=True)
            return nbrs, dist_all, vis_loc

        sharded = shard_map(
            expand, mesh=mesh,
            in_specs=(P(bh, None), P(bh, None), P(bh), P(bh),
                      P(axis, None), P(axis), P(axis, None), P(bh, axis)),
            out_specs=(P(bh, None), P(bh, None), P(bh, axis)),
            check_rep=False)
        nbrs, dist_all, visited = sharded(
            s.q, s.qsq, sel_id_safe, act,
            index.vectors, index.sqnorm, index.neighbors, s.visited)
        # Positional min over the shard dim: each neighbor slot j is
        # finite on its single owner shard, so this restores the exact
        # [B, M] layout (and top_k tie order) of the unsharded step.
        dist = dist_all.reshape(b, nshards, mdeg).min(axis=1)
        topk = (local_frontier_topk if pin_merge and bh is not None
                else hnsw_lib.frontier_topk)
        return hnsw_lib.merge_expand(s, cand_exp, act, nbrs, dist,
                                     visited, k=k, topk=topk)

    # Same jit discipline as the probe step: the index crosses the jit
    # boundary as an argument so its committed row sharding is respected.
    return _memoized(_BEAM_CACHE, key,
                     lambda: jax.jit(beam_step, static_argnames=("k",)))


__all__ = ["make_sharded_flat_search", "sharded_flat_search",
           "make_sharded_ivf_init", "make_sharded_probe_step",
           "make_sharded_beam_step", "merge_topk", "shard_count",
           "SHARD_AXIS", "BATCH_AXIS"]
