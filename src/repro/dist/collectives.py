"""Cross-shard search collectives: the database is row-sharded over the
"model" mesh axis, each shard runs the fused l2_topk kernel on its local
rows, and the per-shard candidates are merged with one small all-gather —
collective volume O(B * k * shards * 8 bytes), independent of N.

Padding contract: N is padded up to a multiple of the shard count; padded
rows carry x_sqnorm = +inf so they can never enter a top-k, and any slot
whose distance is +inf reports id -1 (same convention as index/flat.py).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops

SHARD_AXIS = "model"


def shard_count(mesh: Mesh, axis: str = SHARD_AXIS) -> int:
    return int(mesh.shape[axis]) if axis in mesh.axis_names else 1


def merge_topk(cand_d: jax.Array, cand_i: jax.Array, k: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Merge [B, M] candidate (dist, id) lists to the best k per row.
    +inf candidates (shard padding) are masked back to id -1."""
    neg, pos = jax.lax.top_k(-cand_d, k)
    d = -neg
    i = jnp.take_along_axis(cand_i, pos, axis=1)
    return d, jnp.where(jnp.isfinite(d), i, -1)


def make_sharded_flat_search(mesh: Mesh, k: int, *, axis: str = SHARD_AXIS,
                             use_kernel: bool = True, interpret: bool = True
                             ) -> Callable[[jax.Array, jax.Array],
                                           Tuple[jax.Array, jax.Array]]:
    """Exact flat k-NN over a database sharded on `axis`.

    Returns fn(q [B, D], x [N, D]) -> (dist [B, k] ascending, idx [B, k]),
    numerically matching index.flat.search on any shard count (including
    the 1-device host mesh). Queries are replicated; per-shard local
    top-k uses the fused Pallas kernel (interpret-mode on CPU), the
    cross-shard merge is one tiled all-gather of [B, k] + top_k.
    """
    nshards = shard_count(mesh, axis)

    def local_topk(q, x_loc, sqn_loc):
        if use_kernel:
            d_loc, i_loc = ops.l2_topk(q, x_loc, k=k, x_sqnorm=sqn_loc,
                                       interpret=interpret)
        else:  # pure-XLA: padded rows enter with sqn=+inf, never win
            qf = q.astype(jnp.float32)
            d2 = (jnp.sum(qf ** 2, 1)[:, None] + sqn_loc[None, :]
                  - 2.0 * qf @ x_loc.astype(jnp.float32).T)
            if d2.shape[1] < k:  # fewer local rows than k: pad candidates
                d2 = jnp.pad(d2, ((0, 0), (0, k - d2.shape[1])),
                             constant_values=jnp.inf)
            neg, i_loc = jax.lax.top_k(-d2, k)
            d_loc = jnp.maximum(-neg, 0.0)
        rows = x_loc.shape[0]
        base = jax.lax.axis_index(axis) * rows
        i_glob = jnp.where(jnp.isfinite(d_loc) & (i_loc >= 0),
                           i_loc + base, -1)
        cand_d = jax.lax.all_gather(d_loc, axis, axis=1, tiled=True)
        cand_i = jax.lax.all_gather(i_glob, axis, axis=1, tiled=True)
        return merge_topk(cand_d, cand_i, k)

    sharded = shard_map(
        local_topk, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis)),
        out_specs=(P(), P()),
        check_rep=False)

    @jax.jit
    def search(q: jax.Array, x: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        n = x.shape[0]
        per_shard = -(-n // nshards)
        pad = per_shard * nshards - n
        sqn = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        sqnp = jnp.pad(sqn, (0, pad), constant_values=jnp.inf)
        return sharded(q, xp, sqnp)

    return search


@functools.lru_cache(maxsize=8)
def _cached_search(mesh: Mesh, k: int):
    return make_sharded_flat_search(mesh, k)


def sharded_flat_search(q: jax.Array, x: jax.Array, k: int, mesh: Mesh
                        ) -> Tuple[jax.Array, jax.Array]:
    """One-shot convenience wrapper (builds + caches the jitted fn)."""
    return _cached_search(mesh, k)(q, x)


__all__ = ["make_sharded_flat_search", "sharded_flat_search", "merge_topk",
           "shard_count", "SHARD_AXIS"]
