"""Delta tier: a fixed-capacity ring of recently inserted vectors.

The streaming-update subsystem keeps the base index (IVF bucket store /
HNSW graph) immutable between compactions; inserts land here, in a flat
[capacity, D] buffer that every search scans brute-force with the fused
`l2_topk` kernel and merges into the base top-k (LSM memtable, vector
edition). Slots follow the repo-wide padding contract so an empty or
tombstoned slot can never surface in a result set:

    vecs 0, ids -1, sqnorm +inf

(the same convention dist.place_index uses for shard padding). The ring
is replicated on every shard when the base index is mesh-placed — it is
small by construction, and replicating it keeps the delta scan free of
collectives.

Ring-cursor bookkeeping lives on the host (mutate.index.MutableIndex):
the device arrays carry no cursor, so the same DeltaTier pytree crosses
every jit boundary with a stable treedef and inserts never retrace the
serving chunks.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.padding import (PAD_ID, pad_dists, pad_id_scalar, pad_ids,
                                pad_sqnorm_scalar)
from repro.kernels import ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeltaTier:
    vecs: jax.Array    # f32[capacity, D] (zeros when empty)
    ids: jax.Array     # i32[capacity] global ids (-1 = empty/tombstoned)
    sqnorm: jax.Array  # f32[capacity] (+inf = empty/tombstoned)

    @property
    def capacity(self) -> int:
        return self.ids.shape[0]

    @property
    def dim(self) -> int:
        return self.vecs.shape[1]


def make_delta(capacity: int, dim: int) -> DeltaTier:
    """Empty delta ring (all slots carry the pad convention)."""
    return DeltaTier(
        vecs=jnp.zeros((capacity, dim), jnp.float32),
        ids=pad_ids((capacity,)),
        sqnorm=pad_dists((capacity,)),
    )


@jax.jit
def write(delta: DeltaTier, slots: jax.Array, vecs: jax.Array,
          ids: jax.Array) -> DeltaTier:
    """Scatter `vecs`/`ids` into ring `slots`. Padded entries (slot -1)
    are routed out of bounds, which JAX scatters drop — so the host can
    pad every write to one fixed length and never retrace."""
    s = jnp.where(slots >= 0, slots, delta.ids.shape[0])
    sq = jnp.sum(vecs.astype(jnp.float32) ** 2, axis=1)
    return DeltaTier(
        vecs=delta.vecs.at[s].set(vecs.astype(jnp.float32)),
        ids=delta.ids.at[s].set(ids.astype(jnp.int32)),
        sqnorm=delta.sqnorm.at[s].set(sq),
    )


@jax.jit
def tombstone(delta: DeltaTier, slots: jax.Array) -> DeltaTier:
    """Mask ring `slots` back to the pad convention (ids -1, sqnorm +inf)
    so a deleted insert can never re-enter a top-k. Slot -1 = no-op."""
    s = jnp.where(slots >= 0, slots, delta.ids.shape[0])
    return dataclasses.replace(
        delta,
        ids=delta.ids.at[s].set(pad_id_scalar(delta.ids.dtype)),
        sqnorm=delta.sqnorm.at[s].set(pad_sqnorm_scalar(delta.sqnorm.dtype)),
    )


@jax.jit
def live_count(delta: DeltaTier) -> jax.Array:
    return jnp.sum(delta.ids >= 0).astype(jnp.int32)


def delta_topk(delta: DeltaTier, q: jax.Array, k: int, *,
               interpret: bool = True
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Brute-force scan of the delta tier with the fused l2_topk kernel.

    Returns (dist f32[B, k] squared ascending, global ids i32[B, k],
    live i32[] scanned-slot count, ninserts i32[B] finite candidates).
    Empty / tombstoned slots enter with sqnorm +inf so they can never
    win; their ids are masked to -1 on the way out.
    """
    d, i_loc = ops.l2_topk(q, delta.vecs, k=k, x_sqnorm=delta.sqnorm,
                           interpret=interpret)
    g = delta.ids[jnp.maximum(i_loc, 0)]
    g = jnp.where((i_loc >= 0) & jnp.isfinite(d), g, PAD_ID)
    nins = jnp.sum(jnp.isfinite(d), axis=1).astype(jnp.int32)
    return d, g, live_count(delta), nins
