"""Drift-triggered predictor recalibration (the declarative-recall
contract under mutation).

The GBDT recall predictor was fit against a frozen index; inserts shift
the feature distribution (the merged top-k's distance statistics move
with the delta's contents — the delta scan's fixed cost is deliberately
NOT in ndis, see mutate.engine) and deletes change what recall even
means. The monitor closes the loop:

  1. `observe` samples served queries (query, declared target, returned
     ids) into a fixed-capacity replay ring;
  2. `drift` recomputes EXACT ground truth over the live base+delta
     vector set (training.ground_truth, mesh-sharded when available)
     and measures achieved recall per declared target;
  3. when any target's achieved recall falls more than `threshold`
     below its declaration, `recalibrate` refits the predictor through
     the CURRENT mutable engine (Darth.fit with global-id ground truth)
     and hot-swaps it into a running DarthServer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.padding import PAD_ID
from repro.index import flat


@dataclasses.dataclass
class DriftReport:
    achieved: Dict[float, float]   # declared target -> mean achieved
    counts: Dict[float, int]       # declared target -> #replay queries
    worst_gap: float               # max(target - achieved), 0 if none
    num_queries: int
    drifted: bool


class RecalibrationMonitor:
    """Replay buffer + drift check + refit/hot-swap."""

    def __init__(self, mutable, darth, *,
                 targets: Sequence[float] = (0.8, 0.9, 0.95),
                 threshold: float = 0.02, capacity: int = 2048,
                 mesh=None, metrics=None):
        self.mutable = mutable
        self.darth = darth
        # optional obs.MetricsRegistry: drift checks and recalibrations
        # land in its event log + gauges (docs/observability.md)
        self.metrics = metrics
        self.targets = tuple(float(t) for t in targets)
        self.threshold = float(threshold)
        self.capacity = int(capacity)
        self.mesh = mesh
        self.k = darth.engine.k
        dim = mutable.dim
        self._q = np.zeros((self.capacity, dim), np.float32)
        self._rt = np.zeros((self.capacity,), np.float32)
        self._ids = np.full((self.capacity, self.k), PAD_ID, np.int64)
        # -1 is the "never written" epoch sentinel (the mutation-version
        # stamp), not a pad id — padlint: ok
        self._ver = np.full((self.capacity,), -1, np.int64)
        self._n = 0
        self._cursor = 0
        self.recalibrations = 0

    # -- replay buffer -----------------------------------------------------
    def observe(self, q: np.ndarray, r_t: np.ndarray,
                ids: np.ndarray) -> None:
        """Record served queries (ring overwrite when full). Entries are
        stamped with the index's mutation epoch: results served against
        an OLDER live set can never contain vectors inserted since, so
        their recall gap is irreducible by a predictor refit and they
        must not count as drift."""
        q = np.asarray(q, np.float32).reshape(-1, self._q.shape[1])
        r_t = np.broadcast_to(np.asarray(r_t, np.float32), (q.shape[0],))
        ids = np.asarray(ids).reshape(q.shape[0], -1)[:, :self.k]
        for j in range(q.shape[0]):
            c = self._cursor
            self._q[c] = q[j]
            self._rt[c] = r_t[j]
            self._ids[c] = ids[j]
            self._ver[c] = self.mutable.version
            self._cursor = (c + 1) % self.capacity
            self._n = min(self._n + 1, self.capacity)

    def drift(self) -> DriftReport:
        """Replay recall vs fresh base+delta ground truth, per target
        (current-epoch replay entries only — see observe)."""
        cur = self._ver[:self._n] == self.mutable.version
        if not cur.any():
            return DriftReport({}, {}, 0.0, 0, False)
        q = self._q[:self._n][cur]
        rt = self._rt[:self._n][cur]
        found = self._ids[:self._n][cur]
        gt = self.mutable.live_ground_truth(q, self.k, mesh=self.mesh)
        rec = np.asarray(flat.recall_at_k(jnp.asarray(found.astype(np.int32)),
                                          jnp.asarray(gt)))
        achieved, counts = {}, {}
        worst = 0.0
        for t in self.targets:
            sel = np.abs(rt - t) < 1e-6
            if not sel.any():
                continue
            achieved[t] = float(rec[sel].mean())
            counts[t] = int(sel.sum())
            worst = max(worst, t - achieved[t])
        rep = DriftReport(achieved=achieved, counts=counts,
                          worst_gap=worst, num_queries=int(cur.sum()),
                          drifted=worst > self.threshold)
        if self.metrics is not None:
            self.metrics.event("drift", worst_gap=rep.worst_gap,
                               num_queries=rep.num_queries,
                               drifted=rep.drifted,
                               version=int(self.mutable.version))
            self.metrics.gauge(
                "darth_drift_worst_gap",
                "declared-minus-achieved recall gap at the last drift "
                "check").set(rep.worst_gap)
        return rep

    # -- recalibration -----------------------------------------------------
    def recalibrate(self, learn_q: np.ndarray, *, server=None,
                    batch: int = 256, seed: int = 0):
        """Refit the predictor through the current mutable engine against
        live base+delta ground truth; hot-swap into `server` if given."""
        live_ids, live_vecs = self.mutable.live_vectors()
        trained = self.darth.fit(
            jnp.asarray(np.asarray(learn_q, np.float32)),
            jnp.asarray(live_vecs),
            ids=live_ids, batch=batch, seed=seed, mesh=self.mesh)
        self.recalibrations += 1
        if self.metrics is not None:
            self.metrics.event("recal", recalibrations=self.recalibrations,
                               version=int(self.mutable.version),
                               hot_swapped=server is not None)
            self.metrics.counter(
                "darth_recalibrations_total",
                "predictor refits triggered by drift").inc()
        if server is not None:
            server.set_predictor(trained.predictor)
        # Drop the replay ring: its entries were served by the OLD
        # predictor against an older live set — entries observed before
        # an insert burst can never contain the new vectors, so keeping
        # them would pin drift() above threshold and make step() refit
        # on every tick with no effect on the measured gap.
        self._n = 0
        self._cursor = 0
        return trained

    def step(self, learn_q: np.ndarray, *, server=None,
             batch: int = 256) -> DriftReport:
        """One monitor tick: check drift, recalibrate if past threshold."""
        rep = self.drift()
        if rep.drifted:
            self.recalibrate(learn_q, server=server, batch=batch)
        return rep
