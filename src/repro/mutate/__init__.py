"""Streaming mutable-index subsystem (LSM style): delta tier (ring of
recent inserts, scanned with the fused l2_topk kernel), tombstones (the
shard-pad convention: sqnorm +inf / ids -1), compaction back into the
base index, and drift-triggered predictor recalibration — so DARTH's
declarative-recall contract survives a mutating collection.
"""
from repro.mutate import compact, delta, engine, index, monitor
from repro.mutate.delta import DeltaTier, make_delta
from repro.mutate.engine import (MutableIndexView, MutableSearchState,
                                 mutable_engine, refresh_view)
from repro.mutate.index import CompactionJob, MutableIndex
from repro.mutate.monitor import DriftReport, RecalibrationMonitor

__all__ = ["compact", "delta", "engine", "index", "monitor",
           "DeltaTier", "make_delta", "MutableIndexView",
           "MutableSearchState", "mutable_engine", "refresh_view",
           "MutableIndex", "CompactionJob",
           "DriftReport", "RecalibrationMonitor"]
