"""Mutable-index engine adapter: base engine + delta tier, one Engine.

`mutable_engine(base_engine, delta)` wraps ANY Engine (single-device or
sharded, IVF or HNSW) into a new Engine whose init runs the base init
plus one brute-force delta scan (fused l2_topk), whose step is exactly
the base probe/beam step, and whose top-k getters merge the frozen
delta candidates into the base result via merge_topk. Because the
wrapper honors the full Engine protocol (state carries active / ndis /
ninserts / first_nn, init/step take the index as an argument), the
DARTH driver, budget/plain baselines, the slot-pool server and the
training-data generator all serve a mutating index unchanged.

Accounting: the delta scan is a FIXED per-query cost (one fused kernel
call at init, `live` distances), deliberately kept OUT of ndis /
ninserts — those counters pace DARTH's adaptive prediction intervals
and feed the ndis feature, and folding a large constant into them
inflates dists_Rt until the heuristic intervals exceed the engine's
remaining work and early termination never fires. The predictor still
sees the delta through the distance-statistic features (closestNN,
percentiles, ...), which are extracted from the MERGED top-k; fit and
serve both run through the wrapper, so the feature scale is consistent.
An EMPTY delta therefore perturbs nothing: the wrapper is bit-for-bit
identical to the base engine (the post-compaction parity contract,
tests/test_mutate.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import engines as engines_lib
from repro.dist.collectives import merge_topk
from repro.mutate import delta as delta_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MutableIndexView:
    """The pytree a mutable Engine carries as `.index`: the base index
    (possibly mesh-placed; its committed sharding survives every jit
    boundary because drivers pass the index as an argument) plus the
    replicated delta ring."""
    base: Any
    delta: delta_lib.DeltaTier


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MutableSearchState:
    """Base search state + the per-query delta-scan candidates.

    `active` is the authoritative mask (set_active replaces it; step
    syncs it into the base state before stepping). ndis / ninserts /
    first_nn forward to the base state — the delta scan's fixed cost is
    intentionally not folded in (see module docstring)."""
    inner: Any           # base engine state (IVFSearchState / HNSW...)
    delta_d: jax.Array   # f32[B, k] squared, ascending (+inf empty)
    delta_i: jax.Array   # i32[B, k] global ids (-1 empty)
    active: jax.Array    # bool[B]

    @property
    def ndis(self) -> jax.Array:
        return self.inner.ndis

    @property
    def ninserts(self) -> jax.Array:
        return self.inner.ninserts

    @property
    def first_nn(self) -> jax.Array:
        return self.inner.first_nn


def mutable_engine(base: engines_lib.Engine, delta: delta_lib.DeltaTier, *,
                   interpret: bool = True) -> engines_lib.Engine:
    """Wrap `base` so search covers base + delta minus tombstones."""
    k = base.k
    if delta.capacity < k:
        raise ValueError(
            f"delta capacity {delta.capacity} < k={k}: the delta scan "
            f"must be able to yield k candidates")
    view = MutableIndexView(base=base.index, delta=delta)
    # The wrapper's closures capture `base` — strip its index first:
    # init/step only ever read the index from the `idx` ARGUMENT, and a
    # captured copy would pin the construction-time base buffers (the
    # whole placed bucket store / graph) inside any outer jit that
    # closes over this engine (e.g. DarthServer's chunks) across
    # contents-only engine swaps.
    base = base._replace(index=None)

    def init(idx: MutableIndexView, q: jax.Array) -> MutableSearchState:
        inner = base.init(idx.base, q)
        dd, di, _, _ = delta_lib.delta_topk(idx.delta, q, k,
                                            interpret=interpret)
        return MutableSearchState(inner=inner, delta_d=dd, delta_i=di,
                                  active=inner.active)

    def step(idx: MutableIndexView, ws: MutableSearchState
             ) -> MutableSearchState:
        inner = engines_lib.set_active(ws.inner, ws.active)
        inner = base.step(idx.base, inner)
        return MutableSearchState(inner=inner, delta_d=ws.delta_d,
                                  delta_i=ws.delta_i, active=inner.active)

    def merged(ws: MutableSearchState):
        # topk_d and topk_i are separate protocol getters but callers
        # (slot harvest, Darth.search returns) invoke both on the same
        # state outside jit — memoize the merge on the state instance so
        # the concat + merge_topk dispatches once. Fresh pytree
        # instances (jit outputs, scan carries) never carry the cache.
        cached = ws.__dict__.get("_merged_topk")
        if cached is None:
            cached = merge_topk(
                jnp.concatenate([base.topk_d(ws.inner), ws.delta_d], 1),
                jnp.concatenate([base.topk_i(ws.inner), ws.delta_i], 1), k)
            ws.__dict__["_merged_topk"] = cached
        return cached

    return engines_lib.Engine(
        index=view,
        init=init,
        step=step,
        topk_d=lambda ws: merged(ws)[0],
        topk_i=lambda ws: merged(ws)[1],
        nstep=lambda ws: base.nstep(ws.inner),
        max_steps=base.max_steps,
        name=base.name + "+delta",
        k=k,
    )


def refresh_view(engine: engines_lib.Engine, *, base: Any = None,
                 delta: Any = None) -> engines_lib.Engine:
    """Contents-only view refresh — the cheap half of the
    double-buffered swap. Returns a new Engine reusing the wrapper's
    closures (and therefore every jit cache keyed on them) with only
    the view's base and/or delta replaced. Because init/step read the
    index from their ARGUMENT, handing the result to
    DarthServer.set_engine(contents_only=True) retargets every
    subsequent chunk to the new contents with no rebuild and no
    recompile; components passed as None keep the current (possibly
    mesh-placed) buffers untouched."""
    view = engine.index
    if not isinstance(view, MutableIndexView):
        raise TypeError(
            f"refresh_view needs an Engine carrying a MutableIndexView "
            f"(mutable_engine), got {type(view).__name__}")
    return engine._replace(index=MutableIndexView(
        base=view.base if base is None else base,
        delta=view.delta if delta is None else delta))
