"""MutableIndex: host-side orchestrator for a streaming mutable index.

Wraps a built IVF or HNSW index with a delta ring (mutate.delta) and
tombstone bookkeeping, exposing insert / delete / compact plus a
`view()` pytree the mutable Engine carries as its `.index`. Global ids
are assigned monotonically (base ids first, inserts continue from
max(base id) + 1) and never reused, so results, replay buffers and
ground truth stay comparable across mutations AND compactions.

Tombstones follow the repo-wide pad convention on-device — a deleted
slot keeps sqnorm +inf / ids -1, exactly like shard padding, so it can
never enter a top-k through any engine (single-device or sharded) —
while a host-side set tracks which ids are dead for compaction and
ground-truth recomputation. Device updates are fixed-shape scatters
(padded to a round length, out-of-bounds rows dropped), so streaming
deletes never retrace the serving chunks.

The canonical base index is kept UNPLACED; sharded serving places a
snapshot per burst (`dist.place_index(mutable.base, mesh)`), with the
delta ring replicated alongside (mutate's sharding contract: delta
replicated, tombstones travel row-sharded inside the base arrays).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.padding import PAD_ID, pad_id_scalar, pad_sqnorm_scalar
from repro.index import hnsw as hnsw_lib
from repro.index import ivf as ivf_lib
from repro.mutate import compact as compact_lib
from repro.mutate import delta as delta_lib
from repro.mutate.engine import MutableIndexView


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pad_idx(vals) -> np.ndarray:
    """Pad an index vector to a round length with -1 (fixed-shape
    scatters; the -1 rows route out of bounds and are dropped)."""
    vals = np.asarray(vals, np.int64).reshape(-1)
    out = np.full((_round_up(max(vals.size, 1), 64),), PAD_ID, np.int32)
    out[:vals.size] = vals
    return out


class CompactionJob:
    """One in-flight background compaction: a shadow base rebuilt
    incrementally off the serve path (the double-buffer's back buffer).

    Snapshot isolation comes free from jax functional updates: delete()
    REPLACES the active base object with a masked copy, so the
    generator's begin-time base reference is immutable and the rebuild
    never sees a torn read. `deleted_since` records ids deleted after
    begin so swap_compaction() can re-tombstone them in the finished
    shadow; `folded_ids` is the delta snapshot baked into the shadow —
    the swap frees exactly those ring slots, while inserts admitted
    mid-rebuild stay live in the ring (served from the delta until the
    next compaction)."""

    def __init__(self, gen, folded_ids: np.ndarray):
        self._gen = gen
        self.folded_ids = frozenset(
            int(i) for i in np.asarray(folded_ids).reshape(-1))
        self.deleted_since: set = set()
        self.ticks = 0
        self.done = False
        self.shadow: Any = None

    def tick(self) -> bool:
        """Run one bounded unit of rebuild work; returns True once the
        shadow is complete and ready for swap_compaction()."""
        if not self.done:
            try:
                next(self._gen)
                self.ticks += 1
            except StopIteration as stop:
                self.shadow = stop.value
                self.done = True
        return self.done


@jax.jit
def _mask_ivf_slots(index: ivf_lib.IVFIndex, b_idx: jax.Array,
                    s_idx: jax.Array) -> ivf_lib.IVFIndex:
    """Tombstone bucket slots (ids -1 / sqnorm +inf) and decrement the
    live-population counters; padded entries (bucket -1) route out of
    bounds and are dropped by the scatter."""
    nb = index.bucket_ids.shape[0]
    b = jnp.where(b_idx >= 0, b_idx, nb)
    return dataclasses.replace(
        index,
        bucket_ids=index.bucket_ids.at[b, s_idx].set(
            pad_id_scalar(index.bucket_ids.dtype)),
        bucket_sqnorm=index.bucket_sqnorm.at[b, s_idx].set(
            pad_sqnorm_scalar(index.bucket_sqnorm.dtype)),
        bucket_sizes=index.bucket_sizes.at[b].add(-1))


@jax.jit
def _mask_hnsw_rows(index: hnsw_lib.HNSWIndex,
                    rows: jax.Array) -> hnsw_lib.HNSWIndex:
    """Tombstone graph rows: sqnorm +inf makes every distance to the row
    +inf, so it can never enter a frontier or result set (the row stays
    allocated — id = row is an invariant)."""
    r = jnp.where(rows >= 0, rows, index.sqnorm.shape[0])
    return dataclasses.replace(
        index, sqnorm=index.sqnorm.at[r].set(
            pad_sqnorm_scalar(index.sqnorm.dtype)))


class MutableIndex:
    """Streaming mutable ANN index = base + delta ring + tombstones."""

    def __init__(self, base: Any, *, capacity: int = 1024):
        self.base = base
        self.capacity = int(capacity)
        self.kind = "ivf" if hasattr(base, "centroids") else "hnsw"
        self.delta = delta_lib.make_delta(self.capacity, self.dim)
        # Mutation epoch: bumped by every insert/delete/compact. The
        # drift monitor stamps replay entries with it so observations
        # served against an older live set never contaminate a drift
        # check (their recall gap is irreducible by a predictor refit).
        self.version = 0
        # Epoch-memoized live-ground-truth cache (live_ground_truth):
        # lives HERE, next to `version`, because the mutation epoch is
        # the one thing that invalidates it — callers (drift monitor,
        # launcher, benchmarks) share one scan per (epoch, k, queries).
        self._gt_version = -1
        self._gt_cache: dict = {}
        self._cursor = 0
        self._live_delta = 0
        self._deleted: set = set()
        self._delta_slot: dict = {}   # live delta id -> ring slot
        self._slot_id: dict = {}      # ring slot -> id (live or dead)
        self._job: Optional[CompactionJob] = None
        # optional obs.MetricsRegistry (attach_metrics): compaction
        # begin/tick/swap land in its event log
        self.metrics = None
        if self.kind == "ivf":
            bi = np.asarray(jax.device_get(base.bucket_ids))
            self._next_id = int(bi.max()) + 1 if (bi >= 0).any() else 0
            self._bucket_of = np.full((self._next_id,), PAD_ID, np.int32)
            self._slot_of = np.full((self._next_id,), PAD_ID, np.int32)
            b, s = np.nonzero(bi >= 0)
            self._bucket_of[bi[b, s]] = b
            self._slot_of[bi[b, s]] = s
        else:
            self._next_id = int(base.num_vectors)

    def attach_metrics(self, registry) -> None:
        """Attach an obs.MetricsRegistry: compaction begin/tick/swap
        land in its event log from then on (None detaches)."""
        self.metrics = registry

    # -- introspection -----------------------------------------------------
    @property
    def dim(self) -> int:
        """Vector dimensionality of the wrapped base index."""
        return (self.base.dim if self.kind == "ivf"
                else self.base.vectors.shape[1])

    @property
    def num_live(self) -> int:
        """Live vectors: every id ever issued minus the tombstones
        (ring placement never overwrites a live slot)."""
        return self._next_id - len(self._deleted)

    @property
    def num_delta(self) -> int:
        """Live entries currently in the delta ring (not yet folded)."""
        return self._live_delta

    @property
    def deleted_ids(self) -> np.ndarray:
        """Tombstoned global ids, as an int64 array (unordered)."""
        return np.fromiter(self._deleted, np.int64,
                           count=len(self._deleted))

    def view(self) -> MutableIndexView:
        """Immutable snapshot (base + delta) for engine construction."""
        return MutableIndexView(base=self.base, delta=self.delta)

    # -- mutations ---------------------------------------------------------
    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Append vectors to the delta ring; returns their global ids."""
        vecs = np.asarray(vecs, np.float32).reshape(-1, self.dim)
        m = vecs.shape[0]
        if m == 0:
            return np.zeros((0,), np.int64)
        if self._live_delta + m > self.capacity:
            raise RuntimeError(
                f"delta tier full ({self._live_delta} live + {m} new > "
                f"capacity {self.capacity}); call compact() first")
        ids = np.arange(self._next_id, self._next_id + m, dtype=np.int64)
        self._next_id += m
        # Ring placement over FREE slots only (empty or tombstoned),
        # scanning from the cursor: interleaved deletes leave dead slots
        # behind the cursor, and a blind cursor+arange walk could land on
        # a LIVE slot and silently drop its vector.
        live_slots = np.zeros((self.capacity,), bool)
        occupied = np.fromiter(self._delta_slot.values(), np.int64,
                               count=len(self._delta_slot))
        live_slots[occupied] = True
        order = (self._cursor + np.arange(self.capacity)) % self.capacity
        slots = order[~live_slots[order]][:m]
        self._cursor = int((slots[-1] + 1) % self.capacity)
        for s, i in zip(slots, ids):
            old = self._slot_id.get(int(s))
            if old is not None:            # ring reuse of a dead slot
                self._delta_slot.pop(old, None)
            self._slot_id[int(s)] = int(i)
            self._delta_slot[int(i)] = int(s)
        pad = _round_up(m, 64) - m
        self.delta = delta_lib.write(
            self.delta,
            jnp.asarray(np.concatenate([slots, np.full(pad, PAD_ID)])
                        .astype(np.int32)),
            jnp.asarray(np.concatenate([vecs, np.zeros((pad, self.dim),
                                                       np.float32)])),
            jnp.asarray(np.concatenate([ids, np.full(pad, PAD_ID)])
                        .astype(np.int32)))
        self._live_delta += m
        self.version += 1
        return ids

    def delete(self, ids: Iterable[int]) -> int:
        """Tombstone ids (unknown / already-deleted ids are no-ops).
        Returns the number of ids actually deleted."""
        delta_slots: List[int] = []
        ivf_b: List[int] = []
        ivf_s: List[int] = []
        hnsw_rows: List[int] = []
        newly: List[int] = []
        count = 0
        for i in np.unique(np.asarray(list(ids), np.int64)):
            i = int(i)
            if i < 0 or i >= self._next_id or i in self._deleted:
                continue
            slot = self._delta_slot.pop(i, None)
            if slot is not None:
                delta_slots.append(slot)
                self._live_delta -= 1
            elif self.kind == "ivf":
                if i >= self._bucket_of.shape[0] or self._bucket_of[i] < 0:
                    continue               # folded id moved by compaction?
                ivf_b.append(int(self._bucket_of[i]))
                ivf_s.append(int(self._slot_of[i]))
                self._bucket_of[i] = PAD_ID
                self._slot_of[i] = PAD_ID
            else:
                hnsw_rows.append(i)
            self._deleted.add(i)
            newly.append(i)
            count += 1

        if delta_slots:
            self.delta = delta_lib.tombstone(
                self.delta, jnp.asarray(_pad_idx(delta_slots)))
        if ivf_b:
            self.base = _mask_ivf_slots(self.base,
                                        jnp.asarray(_pad_idx(ivf_b)),
                                        jnp.asarray(_pad_idx(ivf_s)))
        if hnsw_rows:
            self.base = _mask_hnsw_rows(self.base,
                                        jnp.asarray(_pad_idx(hnsw_rows)))
        if count:
            # a running background rebuild read the begin-time snapshot;
            # these deletes must be re-applied to its shadow at swap
            if self._job is not None:
                self._job.deleted_since.update(newly)
            self.version += 1
        return count

    def apply(self, events) -> None:
        """Apply a data.vectors.mutation_stream schedule in order."""
        for ev in events:
            if ev.kind == "insert":
                self.insert(ev.vecs)
            elif ev.kind == "delete":
                self.delete(ev.ids)
            else:
                raise ValueError(f"unknown mutation kind {ev.kind!r}")

    # -- live-set extraction -----------------------------------------------
    def _delta_live(self) -> Tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(jax.device_get(self.delta.ids))
        vecs = np.asarray(jax.device_get(self.delta.vecs))
        live = ids >= 0
        return ids[live].astype(np.int64), vecs[live]

    def live_vectors(self) -> Tuple[np.ndarray, np.ndarray]:
        """(ids i64[L], vecs f32[L, D]) of every live vector, base +
        delta — the ground-truth universe for drift checks and refits.
        IVF SQ8 returns dequantized vectors (what search measures)."""
        if self.kind == "ivf":
            bi = np.asarray(jax.device_get(self.base.bucket_ids))
            bv = np.asarray(jax.device_get(self.base.bucket_vecs))
            live = bi >= 0
            vecs = bv[live].astype(np.float32)
            if self.base.quantized:
                vecs = (vecs * np.asarray(self.base.scale)
                        + np.asarray(self.base.offset))
            ids = bi[live].astype(np.int64)
        else:
            sq = np.asarray(jax.device_get(self.base.sqnorm))
            rows = np.nonzero(np.isfinite(sq))[0]
            vecs = np.asarray(jax.device_get(
                self.base.vectors))[rows].astype(np.float32)
            if self.base.quantized:
                vecs = (vecs * np.asarray(self.base.scale)
                        + np.asarray(self.base.offset))
            ids = rows.astype(np.int64)
        d_ids, d_vecs = self._delta_live()
        return (np.concatenate([ids, d_ids]),
                np.concatenate([vecs, d_vecs], axis=0))

    def live_ground_truth(self, q: np.ndarray, k: int, *,
                          mesh=None) -> np.ndarray:
        """Exact top-k over the live base+delta set as GLOBAL ids
        (i32[B, k], -1 when fewer than k live vectors). The one
        definition of "fresh ground truth under mutation" shared by the
        drift monitor, the launcher and the benchmarks. With `mesh`,
        the scan row-shards over it (training.ground_truth).

        Memoized on the mutation epoch: consecutive calls over an
        unchanged live set (e.g. a post-burst phase followed by a
        post-recalibration phase) reuse one scan; any insert / delete /
        compact bumps `version` and drops the cache."""
        from repro.core import training as training_lib

        q = np.asarray(q, np.float32)
        if self._gt_version != self.version:
            self._gt_cache.clear()
            self._gt_version = self.version
        key = (int(k), q.shape, hash(q.tobytes()))
        hit = self._gt_cache.get(key)
        if hit is not None:
            return hit

        live_ids, live_vecs = self.live_vectors()
        _, rows = training_lib.ground_truth(
            jnp.asarray(q), jnp.asarray(live_vecs), k, mesh=mesh)
        rows = np.asarray(rows)
        out = np.where(rows >= 0, live_ids[np.maximum(rows, 0)], PAD_ID
                       ).astype(np.int32)
        self._gt_cache[key] = out
        return out

    # -- compaction --------------------------------------------------------
    @property
    def compacting(self) -> bool:
        """True while a background compaction job is in flight."""
        return self._job is not None

    @property
    def compaction_ticks(self) -> int:
        """Ticks the in-flight compaction job has consumed (0 if none)."""
        return self._job.ticks if self._job is not None else 0

    def begin_compaction(self, *, cap_round: int = 8,
                         ef_construction: int = 64, alpha: float = 1.2,
                         chunk: int = 1024, seed: int = 0
                         ) -> CompactionJob:
        """Start a background compaction: snapshot the live delta and
        the current base, and return the job whose tick() advances an
        incremental shadow rebuild (compact.compact_*_steps) without
        ever touching the active view. Mutations stay legal while the
        job runs: inserts land in the ring (NOT folded — they survive
        the swap live in the delta), deletes mask the active view and
        are recorded for re-application to the shadow. Call
        swap_compaction() once tick() returns True."""
        if self._job is not None:
            raise RuntimeError("compaction already in progress")
        d_ids, d_vecs = self._delta_live()
        if self.kind == "ivf":
            gen = compact_lib.compact_ivf_steps(
                self.base, d_ids, d_vecs, cap_round=cap_round,
                metrics=self.metrics)
        else:
            gen = compact_lib.compact_hnsw_steps(
                self.base, d_ids, d_vecs, self._next_id,
                ef_construction=ef_construction, alpha=alpha,
                chunk=chunk, seed=seed, metrics=self.metrics)
        self._job = CompactionJob(gen, d_ids)
        if self.metrics is not None:
            self.metrics.event("compact_begin", version=int(self.version),
                               folded=len(self._job.folded_ids))
        return self._job

    def compact_tick(self) -> bool:
        """Advance the background rebuild by one bounded work unit;
        returns True once the shadow is ready to swap."""
        if self._job is None:
            raise RuntimeError("no compaction in progress")
        done = self._job.tick()
        if self.metrics is not None:
            self.metrics.event("compact_tick", tick=self._job.ticks,
                               done=done)
        return done

    def swap_compaction(self) -> None:
        """Install the finished shadow as the new base — the host half
        of the atomic hot-swap (the server applies the matching engine
        swap at a drained chunk boundary via request_swap). Re-applies
        mid-rebuild deletes as shadow tombstones, frees the folded ring
        slots (mid-rebuild inserts stay live in the ring), and bumps
        the mutation epoch. The active view keeps serving unchanged
        right up to the moment `self.base` is re-pointed."""
        job = self._job
        if job is None:
            raise RuntimeError("no compaction in progress")
        if not job.done:
            raise RuntimeError(
                "compaction not finished: tick() until it returns True")
        shadow = job.shadow
        # 1) mid-rebuild deletes: the shadow folded the begin-time live
        #    set, so anything deleted since must be re-tombstoned there
        #    (ids inserted after begin were never folded — no-ops here).
        late = np.fromiter(sorted(job.deleted_since), np.int64,
                           count=len(job.deleted_since))
        if late.size:
            if self.kind == "ivf":
                bi = np.asarray(jax.device_get(shadow.bucket_ids))
                b, s = np.nonzero((bi >= 0) & np.isin(bi, late))
                if b.size:
                    shadow = _mask_ivf_slots(shadow,
                                             jnp.asarray(_pad_idx(b)),
                                             jnp.asarray(_pad_idx(s)))
            else:
                rows = late[late < int(shadow.num_vectors)]
                if rows.size:
                    shadow = _mask_hnsw_rows(shadow,
                                             jnp.asarray(_pad_idx(rows)))
        self.base = shadow
        # 2) free the folded ring slots — their vectors now live in the
        #    base. Slots freed by a mid-rebuild delete are already gone
        #    from _delta_slot; ids inserted mid-rebuild keep theirs.
        slots = [self._delta_slot.pop(i) for i in sorted(job.folded_ids)
                 if i in self._delta_slot]
        if slots:
            self.delta = delta_lib.tombstone(self.delta,
                                             jnp.asarray(_pad_idx(slots)))
            self._live_delta -= len(slots)
        if not self._delta_slot:
            # ring fully drained (no mid-rebuild inserts): reset to the
            # pristine state the synchronous compact() always produced
            self.delta = delta_lib.make_delta(self.capacity, self.dim)
            self._cursor = 0
            self._live_delta = 0
            self._slot_id.clear()
        if self.kind == "ivf":
            self._reindex_ivf()
        ticks = job.ticks
        self._job = None
        self.version += 1
        if self.metrics is not None:
            self.metrics.event("compact_swap", version=int(self.version),
                               ticks=ticks)
            self.metrics.counter(
                "darth_compactions_total",
                "background compactions swapped in").inc()

    def _reindex_ivf(self) -> None:
        """Rebuild the id -> (bucket, slot) delete maps from the base
        (slots masked at swap time carry id -1 and stay unmapped)."""
        bi = np.asarray(jax.device_get(self.base.bucket_ids))
        self._bucket_of = np.full((self._next_id,), PAD_ID, np.int32)
        self._slot_of = np.full((self._next_id,), PAD_ID, np.int32)
        b, s = np.nonzero(bi >= 0)
        self._bucket_of[bi[b, s]] = b
        self._slot_of[bi[b, s]] = s

    def compact(self, *, cap_round: int = 8, ef_construction: int = 64,
                alpha: float = 1.2, chunk: int = 1024,
                seed: int = 0) -> None:
        """Fold the delta into the base and empty the ring. The base
        object is REPLACED (shapes may grow); rebuild engines/views from
        `self.base` / `self.view()` afterwards. Synchronous convenience:
        begin_compaction + drain every tick + swap_compaction — the
        exact code path the background rebuild takes, in one call."""
        self.begin_compaction(cap_round=cap_round,
                              ef_construction=ef_construction,
                              alpha=alpha, chunk=chunk, seed=seed)
        while not self.compact_tick():
            pass
        self.swap_compaction()
