"""Compaction: fold the delta tier back into the base index.

The LSM minor-compaction analogue. Global ids are STABLE across
compaction — surviving base vectors and folded delta vectors keep the
ids they were assigned at build/insert time, so replay buffers, ground
truth and served results stay comparable across the fold.

IVF: delta vectors are re-spilled onto the EXISTING centroids with
`kmeans.assign` (no re-clustering — the coarse quantizer is the part of
the index worth keeping warm), tombstoned slots are dropped, and the
bucket store is re-packed with `ivf.pack_buckets`, regrowing cap to the
new max bucket size. SQ8 storage quantizes the folded delta with the
base's frozen scale/offset.

HNSW: the id = row invariant is preserved by growing the node dim to
cover every id ever issued — deleted/overwritten ids become inert rows
(sqnorm +inf, neighbors -1, the shard-pad convention, unreachable by
construction). Live delta vectors land at their id rows and are linked
with `hnsw.insert_nodes` (beam-search candidate pool -> RobustPrune ->
reverse-edge repair); rows that pointed at a deleted node splice in
that node's own neighbor list before re-pruning, so the deleted node's
"highway" role is repaired rather than severed.

Both folds are exposed as INCREMENTAL generators (`compact_ivf_steps`,
`compact_hnsw_steps`): every `yield` is a tick boundary, the work
between two yields is one bounded unit (an assign / pack / repair /
link chunk), so a serve loop can interleave rebuild ticks with chunk
boundaries and never block for more than one unit. The synchronous
`compact_ivf` / `compact_hnsw` entry points simply drain the generator
— one code path, so background and stop-the-world compaction produce
bit-identical shadows. The generators read the input index ONCE up
front; jax functional updates mean concurrent deletes REPLACE the
active base object rather than mutating it, so the begin-time snapshot
is immutable (snapshot isolation for free) and
`MutableIndex.swap_compaction` re-applies mid-rebuild deletes to the
finished shadow.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.padding import PAD_ID, PAD_SQNORM
from repro.index import hnsw as hnsw_lib
from repro.index import ivf as ivf_lib
from repro.index import kmeans as kmeans_lib


def drain(gen):
    """Run an incremental-compaction generator to completion and return
    its final value (the rebuilt base index)."""
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


def compact_ivf(index: ivf_lib.IVFIndex, delta_ids: np.ndarray,
                delta_vecs: np.ndarray, *, cap_round: int = 8,
                metrics=None) -> ivf_lib.IVFIndex:
    """Fold live delta entries into the bucket store; drop tombstones.
    (Synchronous: drains compact_ivf_steps in one call.)"""
    return drain(compact_ivf_steps(index, delta_ids, delta_vecs,
                                   cap_round=cap_round, metrics=metrics))


def compact_ivf_steps(index: ivf_lib.IVFIndex, delta_ids: np.ndarray,
                      delta_vecs: np.ndarray, *, cap_round: int = 8,
                      assign_chunk: int = 4096, pack_chunk: int = 64,
                      metrics=None):
    """Incremental IVF fold: snapshot reads, chunked delta re-spill,
    chunked bucket re-pack; yields between bounded units and returns
    the shadow IVFIndex via StopIteration.value."""
    cents = np.asarray(index.centroids)
    bv = np.asarray(index.bucket_vecs)
    bi = np.asarray(index.bucket_ids)
    yield
    live = bi >= 0
    base_store = bv[live]                     # [L, D] stored dtype
    base_ids = bi[live].astype(np.int32)
    # live entries keep their bucket assignment (their centroid did not
    # move); the bucket row of each live slot is its assignment
    base_assign = np.broadcast_to(
        np.arange(bi.shape[0], dtype=np.int32)[:, None], bi.shape)[live]
    yield

    scale = np.asarray(index.scale)
    offset = np.asarray(index.offset)
    delta_vecs = np.asarray(delta_vecs, np.float32).reshape(-1, index.dim)
    delta_ids = np.asarray(delta_ids, np.int32).reshape(-1)
    delta_assign = np.zeros((delta_ids.size,), np.int32)
    for lo in range(0, delta_ids.size, assign_chunk):   # re-spill
        hi = min(delta_ids.size, lo + assign_chunk)
        delta_assign[lo:hi] = np.asarray(kmeans_lib.assign(
            jnp.asarray(delta_vecs[lo:hi]), jnp.asarray(cents)))
        yield

    if index.quantized:
        base_deq = base_store.astype(np.float32) * scale + offset
        # The delta is quantized against the FROZEN base range so codes
        # stay comparable; an OOD drift burst can exceed it. The clamp
        # is correct but lossy — surface it instead of clipping silently
        # (the recorded count is the drift monitor's cue to re-derive
        # the range at the next full rebuild).
        delta_store, delta_deq, nclip = ivf_lib.quantize_sq8(
            delta_vecs, scale, offset)
        if nclip and metrics is not None:
            metrics.counter(
                "darth_sq8_clipped_total",
                "SQ8 values clamped to the frozen base range during "
                "delta re-quantization").inc(nclip)
    else:
        base_deq = base_store
        delta_store, delta_deq = delta_vecs, delta_vecs

    x_store = np.concatenate([base_store, delta_store], axis=0)
    x_deq = np.concatenate([base_deq, delta_deq], axis=0)
    ids = np.concatenate([base_ids, delta_ids])
    assign = np.concatenate([base_assign, delta_assign]).astype(np.int64)
    yield
    bucket_vecs, bucket_ids, bucket_sqnorm, sizes = yield from (
        ivf_lib.pack_buckets_steps(x_store, x_deq, ids, assign,
                                   index.nlist, cap_round=cap_round,
                                   chunk=pack_chunk))
    return ivf_lib.IVFIndex(
        centroids=index.centroids,
        bucket_vecs=jnp.asarray(bucket_vecs),
        bucket_ids=jnp.asarray(bucket_ids),
        bucket_sqnorm=jnp.asarray(bucket_sqnorm),
        bucket_sizes=jnp.asarray(sizes),
        scale=index.scale,
        offset=index.offset,
    )


def compact_hnsw(index: hnsw_lib.HNSWIndex, delta_ids: np.ndarray,
                 delta_vecs: np.ndarray, next_id: int, *,
                 ef_construction: int = 64, alpha: float = 1.2,
                 chunk: int = 1024, seed: int = 0,
                 metrics=None) -> hnsw_lib.HNSWIndex:
    """Grow the graph to `next_id` rows, repair deletions, link delta.
    (Synchronous: drains compact_hnsw_steps in one call.)"""
    return drain(compact_hnsw_steps(index, delta_ids, delta_vecs, next_id,
                                    ef_construction=ef_construction,
                                    alpha=alpha, chunk=chunk, seed=seed,
                                    metrics=metrics))


def compact_hnsw_steps(index: hnsw_lib.HNSWIndex, delta_ids: np.ndarray,
                       delta_vecs: np.ndarray, next_id: int, *,
                       ef_construction: int = 64, alpha: float = 1.2,
                       chunk: int = 1024, seed: int = 0,
                       repair_chunk: int = 256, metrics=None):
    """Incremental HNSW fold: snapshot reads, chunked deletion repair,
    chunked incremental linking; yields between bounded units and
    returns the shadow HNSWIndex via StopIteration.value.

    SQ8-resident graphs dequantize at entry (pruning geometry runs in
    f32) and re-quantize at exit against the FROZEN base range, so the
    rebuilt view stays int8-resident; delta clips are recorded like the
    IVF path's."""
    x = np.asarray(index.vectors)
    if index.quantized:
        x = (x.astype(np.float32) * np.asarray(index.scale)
             + np.asarray(index.offset))
    sq = np.asarray(index.sqnorm)
    nbr = np.asarray(index.neighbors)
    yield
    n_old, d = x.shape
    m = nbr.shape[1]
    alpha2 = float(alpha) ** 2

    n_new = max(int(next_id), n_old)
    x2 = np.zeros((n_new, d), np.float32)
    sq2 = np.full((n_new,), PAD_SQNORM, np.float32)
    nbr2 = np.full((n_new, m), PAD_ID, np.int32)
    x2[:n_old] = x
    sq2[:n_old] = sq
    nbr2[:n_old] = nbr

    delta_ids = np.asarray(delta_ids, np.int64).reshape(-1)
    delta_vecs = np.asarray(delta_vecs, np.float32).reshape(-1, d)
    x2[delta_ids] = delta_vecs
    sq2[delta_ids] = (delta_vecs ** 2).sum(axis=1)
    yield

    # 1) deletion repair: rows pointing at a dead node splice in that
    #    node's neighbors (minus dead) and re-prune; dead rows go inert.
    dead = ~np.isfinite(sq2[:n_old])
    dead_rows = np.nonzero(dead)[0]
    if dead_rows.size:
        dead_mask = np.zeros((n_new,), bool)
        dead_mask[dead_rows] = True
        ref = (nbr2 >= 0) & dead_mask[np.maximum(nbr2, 0)]
        affected = np.nonzero(ref.any(axis=1))[0]
        affected = affected[~dead_mask[affected]]
        # chunked: merged lists are m + m*m wide and the re-prune's
        # pairwise block is quadratic in that width
        for lo in range(0, affected.size, repair_chunk):
            aff = affected[lo:lo + repair_chunk]
            own = np.where(ref[aff], PAD_ID, nbr2[aff])
            # dead targets' own out-edges, flattened per affected row
            spliced = np.where(ref[aff, :, None],
                               nbr2[np.maximum(nbr2[aff], 0)],
                               PAD_ID).reshape(aff.size, -1)
            merged = np.concatenate([own, spliced], axis=1)
            merged = np.where(
                (merged >= 0) & ~dead_mask[np.maximum(merged, 0)],
                merged, PAD_ID)
            merged = hnsw_lib._dedup_rows_vec(merged)
            nbr2[aff] = hnsw_lib._prune_rows(x2, aff, merged, m, alpha2)
            yield
        nbr2[dead_rows] = PAD_ID

    # 2) routing sample / entry over LIVE, LINKED nodes only (new rows
    #    are not linked yet, so they cannot seed the link searches).
    rng = np.random.default_rng(seed)
    old_live = np.nonzero(np.isfinite(sq2[:n_old]))[0]
    if old_live.size == 0:
        raise ValueError("compaction needs at least one live base node "
                         "to seed incremental linking")
    r = int(min(8192, max(64, n_new // 64)))
    route_link = rng.choice(old_live, size=min(r, old_live.size),
                            replace=False).astype(np.int32)
    entry_link = int(old_live[np.argmin(
        ((x2[old_live] - x2[old_live].mean(0)) ** 2).sum(1))])
    yield

    grown = hnsw_lib.HNSWIndex(
        vectors=jnp.asarray(x2), sqnorm=jnp.asarray(sq2),
        neighbors=jnp.asarray(nbr2),
        entry=jnp.asarray(entry_link, jnp.int32),
        route_ids=jnp.asarray(route_link))
    grown = yield from hnsw_lib.insert_nodes_steps(
        grown, delta_ids, ef_construction=ef_construction,
        alpha=alpha, chunk=chunk)

    # 3) final routing sample drawn over ALL live nodes (incl. new ones,
    #    now linked) so routing covers the folded distribution.
    live = np.nonzero(np.isfinite(sq2))[0]
    route_ids = rng.choice(live, size=min(r, live.size),
                           replace=False).astype(np.int32)
    entry = int(live[np.argmin(((x2[live] - x2[live].mean(0)) ** 2).sum(1))])
    grown = dataclasses.replace(
        grown, entry=jnp.asarray(entry, jnp.int32),
        route_ids=jnp.asarray(route_ids))
    if not index.quantized:
        return grown
    # Re-quantize at exit against the frozen base range: base rows
    # round-trip exactly; only delta rows can clip (recorded, not
    # silent). sqnorm is recomputed on the DEQUANTIZED codes so served
    # distances match what the quantized search measures.
    scale = np.asarray(index.scale)
    offset = np.asarray(index.offset)
    codes, deq, _ = ivf_lib.quantize_sq8(x2, scale, offset)
    nclip = (ivf_lib.quantize_sq8(delta_vecs, scale, offset)[2]
             if delta_ids.size else 0)
    if nclip and metrics is not None:
        metrics.counter(
            "darth_sq8_clipped_total",
            "SQ8 values clamped to the frozen base range during "
            "delta re-quantization").inc(nclip)
    sq_q = np.full((n_new,), PAD_SQNORM, np.float32)
    sq_q[live] = (deq[live] ** 2).sum(axis=1)
    return dataclasses.replace(
        grown, vectors=jnp.asarray(codes), sqnorm=jnp.asarray(sq_q),
        scale=index.scale, offset=index.offset)
