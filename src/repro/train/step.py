"""Train-step factory: value_and_grad + global-norm clip + optimizer,
with optional int8 error-feedback gradient compression (the wire-format
roundtrip; the shard_map DP reduction lives in optim/grad_compress.py).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model_zoo
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, grad_compress, schedule as sched_lib)

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def make_optimizer(name: str):
    if name == "adamw":
        return (lambda p: adamw_init(p),
                lambda g, s, p, lr: adamw_update(g, s, p, lr))
    if name == "adafactor":
        return (lambda p: adafactor_init(p),
                lambda g, s, p, lr: adafactor_update(g, s, p, lr))
    raise ValueError(name)


def optimizer_for(cfg: ArchConfig) -> str:
    """Adafactor for the 1T MoE (f32 Adam moments do not fit 512 chips at
    16 GB HBM — DESIGN.md §7); AdamW otherwise."""
    return "adafactor" if cfg.name.startswith("kimi") else "adamw"


def make_train_step(cfg: ArchConfig, *, optimizer: Optional[str] = None,
                    peak_lr: float = 3e-4, warmup_steps: int = 100,
                    total_steps: int = 10_000, clip_norm: float = 1.0,
                    compress_grads: bool = False, remat: bool = True,
                    attn_chunk: int = 512
                    ) -> Tuple[Callable, Callable]:
    """Returns (init_opt_state, train_step).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    opt_name = optimizer or optimizer_for(cfg)
    opt_init, opt_update = make_optimizer(opt_name)

    def init_opt_state(params: PyTree) -> PyTree:
        state = opt_init(params)
        if compress_grads:
            state = dict(state, ef=jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
        return state

    def train_step(params: PyTree, opt_state: PyTree,
                   batch: Dict[str, jax.Array]):
        def lf(p):
            return model_zoo.loss_fn(cfg, p, batch, remat=remat,
                                     chunk=attn_chunk)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)

        if compress_grads:
            ef = opt_state["ef"]

            def comp(g, e):
                gf = g.astype(jnp.float32) + e
                sent = grad_compress.compress_roundtrip(gf)
                return sent.astype(g.dtype), gf - sent
            out = jax.tree.map(comp, grads, ef)
            grads = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_ef = jax.tree.map(lambda o: o[1], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
            opt_state = dict(opt_state, ef=new_ef)

        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                        ).astype(g.dtype), grads)
        step = opt_state["step"]
        lr = sched_lib.warmup_cosine(step, peak_lr=peak_lr,
                                     warmup_steps=warmup_steps,
                                     total_steps=total_steps)
        ef_saved = opt_state.get("ef")
        core_state = {k: v for k, v in opt_state.items() if k != "ef"}
        params, core_state = opt_update(grads, core_state, params, lr)
        if ef_saved is not None:
            core_state = dict(core_state, ef=opt_state["ef"])
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, core_state, metrics

    return init_opt_state, train_step


def make_prefill_step(cfg: ArchConfig, attn_chunk: int = 512) -> Callable:
    def prefill_step(params, batch):
        return model_zoo.prefill(cfg, params, batch, chunk=attn_chunk)
    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, cache, tokens, pos):
        return model_zoo.decode_step(cfg, params, cache, tokens, pos)
    return serve_step
