from repro.train import loop, step
from repro.train.loop import SimulatedFailure, train
from repro.train.step import (make_prefill_step, make_serve_step,
                              make_train_step, optimizer_for)

__all__ = ["loop", "step", "train", "SimulatedFailure", "make_train_step",
           "make_prefill_step", "make_serve_step", "optimizer_for"]
