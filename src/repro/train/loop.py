"""Training loop with fault tolerance: checkpoint/restart, failure
injection, restart-exact data order (counter-based pipeline).

Contract exercised in tests/test_train_loop.py:
  * kill the loop at step K (REPRO_FAIL_AT_STEP or fail_at), restart,
    and the loss trajectory continues bit-identically vs an uninterrupted
    run (same pipeline stream, same optimizer state).
  * checkpoints are atomic: a crash mid-save never corrupts the latest
    committed step.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro import ckpt
from repro.configs.base import ArchConfig
from repro.data.synthetic import PipelineConfig, TokenPipeline
from repro.models import model_zoo
from repro.train import step as step_lib


class SimulatedFailure(RuntimeError):
    pass


def train(cfg: ArchConfig, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: str, ckpt_every: int = 50, keep: int = 3,
          peak_lr: float = 3e-4, seed: int = 0,
          fail_at: Optional[int] = None, log_every: int = 10,
          compress_grads: bool = False,
          metrics_sink: Optional[List[Dict[str, float]]] = None
          ) -> Dict[str, Any]:
    """Single-host training driver (the multi-pod variant is launch/train.py
    with pjit shardings; this loop is the logic both share)."""
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed))
    init_opt, train_step_fn = step_lib.make_train_step(
        cfg, peak_lr=peak_lr, compress_grads=compress_grads)
    train_step_fn = jax.jit(train_step_fn, donate_argnums=(0, 1))

    params = model_zoo.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt(params)
    start_step = 0

    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        (params, opt_state), meta = ckpt.restore(
            ckpt_dir, (params, opt_state))
        start_step = int(meta["extra"]["next_step"])

    env_fail = os.environ.get("REPRO_FAIL_AT_STEP")
    fail_at = fail_at if fail_at is not None else (
        int(env_fail) if env_fail else None)

    history: List[Dict[str, float]] = (metrics_sink if metrics_sink
                                       is not None else [])
    t0 = time.time()
    for s in range(start_step, steps):
        if fail_at is not None and s == fail_at:
            raise SimulatedFailure(f"injected failure at step {s}")
        batch = pipe.get_batch(s)
        params, opt_state, metrics = train_step_fn(params, opt_state, batch)
        if s % log_every == 0 or s == steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = s
            history.append(m)
        if ckpt_every and (s + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, s + 1, (params, opt_state),
                      extra={"next_step": s + 1,
                             "pipeline": pipe.state_dict(s + 1)},
                      keep=keep)
    if ckpt_every:
        ckpt.save(ckpt_dir, steps, (params, opt_state),
                  extra={"next_step": steps,
                         "pipeline": pipe.state_dict(steps)}, keep=keep)
    return {"history": history, "params": params, "opt_state": opt_state,
            "seconds": time.time() - t0}
