"""Deterministic, shardable, restartable token pipeline.

Counter-based PRNG (threefry fold_in of (seed, step, shard)) means:
  * restart-exact: the pipeline's only state is the integer step — a
    checkpoint restores the exact batch stream (fault-tolerance contract),
  * shardable: each data-parallel host draws only its shard,
  * skip-ahead: no sequential scan to reach step N.

The stream is a Zipf-ish mixture over the vocab with shifted labels —
enough structure for a loss to fall during example training runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0


def _zipf_logits(vocab: int) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    return np.log(1.0 / ranks)


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards
        self._logits = jnp.asarray(_zipf_logits(cfg.vocab_size), jnp.float32)
        self._base = jax.random.PRNGKey(cfg.seed)

    def get_batch(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(self._base, step), self.cfg.shard_id)
        toks = jax.random.categorical(
            key, self._logits,
            shape=(self.local_batch, self.cfg.seq_len + 1))
        tokens = toks[:, :-1].astype(jnp.int32)
        labels = toks[:, 1:].astype(jnp.int32)
        return {"tokens": tokens, "labels": labels}

    def state_dict(self, step: int) -> Dict[str, int]:
        return {"step": int(step), "seed": self.cfg.seed,
                "num_shards": self.cfg.num_shards,
                "shard_id": self.cfg.shard_id}
