"""Synthetic vector collections + query workloads (paper §4 methodology).

The paper's datasets (SIFT/DEEP/T2I/GLOVE/GIST) are not redistributable in
this offline container; this module generates matched-structure stand-ins:

  * clustered Gaussian mixtures with a hardness dial (cluster count,
    spread ratio) — GLOVE-like when tightly clustered, GIST-like when
    diffuse;
  * *noisy* query workloads: Gaussian noise with sigma = pct * ||q||
    (exactly the paper's harder-workload generator, §4 'Queries');
  * *OOD* query workloads: queries drawn from a shifted/rotated
    distribution (the T2I100M analogue);
  * learn/base/query splits that never overlap, mirroring the benchmarks'
    learning sets.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np


class VectorDataset(NamedTuple):
    base: np.ndarray      # f32[N, D] indexed collection
    learn: np.ndarray     # f32[L, D] training-query pool (disjoint)
    queries: np.ndarray   # f32[Q, D] default test workload
    name: str


def make_dataset(n: int = 100_000, d: int = 64, *, num_learn: int = 10_000,
                 num_queries: int = 1_000, clusters: int = 256,
                 cluster_std: float = 1.0, center_scale: float = 4.0,
                 seed: int = 0, name: str = "synth") -> VectorDataset:
    """Clustered mixture. center_scale/cluster_std controls separation
    (higher = more clustered = easier queries, lower LID)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(clusters, d)) * center_scale
    total = n + num_learn + num_queries

    assign = rng.integers(0, clusters, size=total)
    pts = centers[assign] + rng.normal(size=(total, d)) * cluster_std
    pts = pts.astype(np.float32)
    base = pts[:n]
    learn = pts[n:n + num_learn]
    queries = pts[n + num_learn:]
    # Real benchmark learning sets span a DIVERSE hardness range (paper
    # Fig 4b: effort is ~normally distributed). A purely in-cluster
    # synthetic learn set is uniformly easy, which starves the recall
    # predictor of hard examples; diversify ~30% of it: 20% noise-
    # perturbed, 10% drawn from unseen modes of the same family.
    if num_learn >= 10:
        n_noisy = num_learn // 5
        n_far = num_learn // 10
        idx = rng.permutation(num_learn)
        noisy_sel = idx[:n_noisy]
        far_sel = idx[n_noisy:n_noisy + n_far]
        learn = learn.copy()
        pcts = rng.uniform(0.5, 8.0, size=(n_noisy, 1)).astype(np.float32)
        norms = np.linalg.norm(learn[noisy_sel], axis=1, keepdims=True)
        sigma = np.sqrt(pcts * norms / d)
        learn[noisy_sel] += (rng.normal(size=(n_noisy, d)) * sigma
                             ).astype(np.float32)
        far_centers = rng.normal(size=(n_far, d)) * center_scale
        learn[far_sel] = (far_centers + rng.normal(size=(n_far, d))
                          * cluster_std).astype(np.float32)
    return VectorDataset(base=base, learn=learn, queries=queries, name=name)


def noisy_queries(q: np.ndarray, noise_pct: float,
                  seed: int = 0) -> np.ndarray:
    """Harder workloads: add Gaussian noise with sigma^2 = pct * ||q||
    (paper §4: 'The sigma^2 of the added Gaussian Noise is a percentage of
    the norm of each query vector')."""
    rng = np.random.default_rng(seed)
    norms = np.linalg.norm(q, axis=1, keepdims=True)
    sigma = np.sqrt(noise_pct * norms / q.shape[1])
    return (q + rng.normal(size=q.shape) * sigma).astype(np.float32)


def ood_queries(d: int, num: int, *, clusters: int = 64,
                cluster_std: float = 1.0, center_scale: float = 4.0,
                seed: int = 1, like: Optional[np.ndarray] = None
                ) -> np.ndarray:
    """Out-of-distribution workload (T2I100M analogue): queries drawn from
    UNSEEN modes of the same generative family — same scale, different
    cluster centers (text-vs-image embeddings sharing one space). Queries
    land between/outside the indexed clusters: harder, distribution-
    shifted, but within the feature ranges a tree predictor can interpolate
    (matching the paper's T2I setup, where OOD degrades predictor MSE but
    targets remain attainable)."""
    rng = np.random.default_rng(seed + 104729)
    centers = rng.normal(size=(clusters, d)) * center_scale
    assign = rng.integers(0, clusters, size=num)
    q = centers[assign] + rng.normal(size=(num, d)) * cluster_std
    return q.astype(np.float32)


class MutationEvent(NamedTuple):
    """One timestamped step of a streaming-update workload."""
    t: int
    kind: str                      # "insert" | "delete"
    vecs: Optional[np.ndarray]     # inserts: f32[M, D]
    ids: Optional[np.ndarray]      # deletes: i64[M] base ids


def mutation_stream(ds: VectorDataset, insert_pct: float = 0.2,
                    delete_pct: float = 0.1, *, drift: float = 0.0,
                    steps: int = 8, clusters: int = 64,
                    seed: int = 0) -> list:
    """Timestamped insert/delete schedule over `ds.base` — the ONE
    workload definition the mutable-index benchmarks and tests share.

    Inserts total insert_pct * N vectors: a `drift` fraction is drawn
    from UNSEEN modes via the `ood_queries` cluster machinery (the
    distribution shift that decays a frozen recall predictor), the rest
    are in-distribution noisy perturbations of base vectors
    (`noisy_queries`). Deletes remove delete_pct * N distinct base ids.
    Events alternate insert/delete across `steps` timestamps so the two
    interleave the way a live collection mutates.
    """
    rng = np.random.default_rng(seed + 7919)
    n, d = ds.base.shape
    n_ins = int(round(insert_pct * n))
    n_del = int(round(delete_pct * n))
    n_ood = int(round(np.clip(drift, 0.0, 1.0) * n_ins))

    src = rng.choice(n, size=max(n_ins - n_ood, 0), replace=True)
    in_dist = noisy_queries(ds.base[src], 0.05, seed=seed + 1)
    ood = ood_queries(d, n_ood, clusters=clusters, seed=seed + 2)
    inserts = np.concatenate([in_dist, ood], axis=0).astype(np.float32)
    inserts = inserts[rng.permutation(inserts.shape[0])]
    del_ids = rng.choice(n, size=min(n_del, n), replace=False
                         ).astype(np.int64)

    events = []
    for t in range(steps):
        ins_t = inserts[t * n_ins // steps:(t + 1) * n_ins // steps]
        if ins_t.shape[0]:
            events.append(MutationEvent(t=t, kind="insert", vecs=ins_t,
                                        ids=None))
        del_t = del_ids[t * n_del // steps:(t + 1) * n_del // steps]
        if del_t.shape[0]:
            events.append(MutationEvent(t=t, kind="delete", vecs=None,
                                        ids=del_t))
    return events


def local_intrinsic_dimensionality(dists: np.ndarray) -> np.ndarray:
    """MLE LID per query from ascending kNN distances [B, k] (paper §4
    'Dataset Complexity'): LID = -(1/k * sum log(d_i / d_k))^-1."""
    d = np.asarray(dists, np.float64)
    d = np.sqrt(np.maximum(d, 1e-12))  # squared -> metric
    w = d[:, -1:]
    ratio = np.clip(d / w, 1e-12, 1.0)
    s = np.mean(np.log(ratio[:, :-1]), axis=1)
    return -1.0 / np.minimum(s, -1e-12)
