"""Synthetic vector collections + query workloads (paper §4 methodology).

The paper's datasets (SIFT/DEEP/T2I/GLOVE/GIST) are not redistributable in
this offline container; this module generates matched-structure stand-ins:

  * clustered Gaussian mixtures with a hardness dial (cluster count,
    spread ratio) — GLOVE-like when tightly clustered, GIST-like when
    diffuse;
  * *noisy* query workloads: Gaussian noise with sigma = pct * ||q||
    (exactly the paper's harder-workload generator, §4 'Queries');
  * *OOD* query workloads: queries drawn from a shifted/rotated
    distribution (the T2I100M analogue);
  * learn/base/query splits that never overlap, mirroring the benchmarks'
    learning sets.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np


class VectorDataset(NamedTuple):
    base: np.ndarray      # f32[N, D] indexed collection
    learn: np.ndarray     # f32[L, D] training-query pool (disjoint)
    queries: np.ndarray   # f32[Q, D] default test workload
    name: str


def make_dataset(n: int = 100_000, d: int = 64, *, num_learn: int = 10_000,
                 num_queries: int = 1_000, clusters: int = 256,
                 cluster_std: float = 1.0, center_scale: float = 4.0,
                 seed: int = 0, name: str = "synth") -> VectorDataset:
    """Clustered mixture. center_scale/cluster_std controls separation
    (higher = more clustered = easier queries, lower LID)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(clusters, d)) * center_scale
    total = n + num_learn + num_queries

    assign = rng.integers(0, clusters, size=total)
    pts = centers[assign] + rng.normal(size=(total, d)) * cluster_std
    pts = pts.astype(np.float32)
    base = pts[:n]
    learn = pts[n:n + num_learn]
    queries = pts[n + num_learn:]
    # Real benchmark learning sets span a DIVERSE hardness range (paper
    # Fig 4b: effort is ~normally distributed). A purely in-cluster
    # synthetic learn set is uniformly easy, which starves the recall
    # predictor of hard examples; diversify ~30% of it: 20% noise-
    # perturbed, 10% drawn from unseen modes of the same family.
    if num_learn >= 10:
        n_noisy = num_learn // 5
        n_far = num_learn // 10
        idx = rng.permutation(num_learn)
        noisy_sel = idx[:n_noisy]
        far_sel = idx[n_noisy:n_noisy + n_far]
        learn = learn.copy()
        pcts = rng.uniform(0.5, 8.0, size=(n_noisy, 1)).astype(np.float32)
        norms = np.linalg.norm(learn[noisy_sel], axis=1, keepdims=True)
        sigma = np.sqrt(pcts * norms / d)
        learn[noisy_sel] += (rng.normal(size=(n_noisy, d)) * sigma
                             ).astype(np.float32)
        far_centers = rng.normal(size=(n_far, d)) * center_scale
        learn[far_sel] = (far_centers + rng.normal(size=(n_far, d))
                          * cluster_std).astype(np.float32)
    return VectorDataset(base=base, learn=learn, queries=queries, name=name)


def noisy_queries(q: np.ndarray, noise_pct: float,
                  seed: int = 0) -> np.ndarray:
    """Harder workloads: add Gaussian noise with sigma^2 = pct * ||q||
    (paper §4: 'The sigma^2 of the added Gaussian Noise is a percentage of
    the norm of each query vector')."""
    rng = np.random.default_rng(seed)
    norms = np.linalg.norm(q, axis=1, keepdims=True)
    sigma = np.sqrt(noise_pct * norms / q.shape[1])
    return (q + rng.normal(size=q.shape) * sigma).astype(np.float32)


def ood_queries(d: int, num: int, *, clusters: int = 64,
                cluster_std: float = 1.0, center_scale: float = 4.0,
                seed: int = 1, like: Optional[np.ndarray] = None
                ) -> np.ndarray:
    """Out-of-distribution workload (T2I100M analogue): queries drawn from
    UNSEEN modes of the same generative family — same scale, different
    cluster centers (text-vs-image embeddings sharing one space). Queries
    land between/outside the indexed clusters: harder, distribution-
    shifted, but within the feature ranges a tree predictor can interpolate
    (matching the paper's T2I setup, where OOD degrades predictor MSE but
    targets remain attainable)."""
    rng = np.random.default_rng(seed + 104729)
    centers = rng.normal(size=(clusters, d)) * center_scale
    assign = rng.integers(0, clusters, size=num)
    q = centers[assign] + rng.normal(size=(num, d)) * cluster_std
    return q.astype(np.float32)


def local_intrinsic_dimensionality(dists: np.ndarray) -> np.ndarray:
    """MLE LID per query from ascending kNN distances [B, k] (paper §4
    'Dataset Complexity'): LID = -(1/k * sum log(d_i / d_k))^-1."""
    d = np.asarray(dists, np.float64)
    d = np.sqrt(np.maximum(d, 1e-12))  # squared -> metric
    w = d[:, -1:]
    ratio = np.clip(d / w, 1e-12, 1.0)
    s = np.mean(np.log(ratio[:, :-1]), axis=1)
    return -1.0 / np.minimum(s, -1e-12)
