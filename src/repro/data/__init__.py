from repro.data import synthetic, vectors
from repro.data.synthetic import PipelineConfig, TokenPipeline
from repro.data.vectors import (MutationEvent, VectorDataset, make_dataset,
                                mutation_stream, noisy_queries, ood_queries)

__all__ = ["synthetic", "vectors", "PipelineConfig", "TokenPipeline",
           "VectorDataset", "make_dataset", "noisy_queries", "ood_queries",
           "MutationEvent", "mutation_stream"]
