"""Compact index residency: SQ8 views, f32 re-rank, byte accounting.

The residency tiers (docs/architecture.md "Index residency tiers"):

  * device HBM holds the SQ8 view of the vector payload — per-dim
    affine int8 codes (4x smaller than f32) searched with asymmetric
    distances (f32 query vs dequantized codes), the format both
    engines serve by default;
  * host memory holds the exact f32 vectors (`RerankStore`) used to
    re-rank the final over-provisioned top-k, and the IVF cold bucket
    tier (serve.cold);
  * `resident_bytes` is the accounting the shardlint resident-bytes
    pass and the dist_residency benchmark gate against.

Conversion is host-side numpy (like build/compaction): `quantize_ivf` /
`quantize_hnsw` derive the per-dim range from the live rows and return
a same-shape index whose payload is int8 — drop-in for every engine
and for `dist.place_index` (scale/offset replicate like the other
small fields).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.padding import PAD_DIST, PAD_ID, PAD_SQNORM
from repro.index import hnsw as hnsw_lib
from repro.index import ivf as ivf_lib

AnyIndex = Union[ivf_lib.IVFIndex, hnsw_lib.HNSWIndex]


def sq8_range(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-dim affine SQ8 range of ``x`` [L, D]: (scale, offset) such
    that the observed min/max map to the int8 code range [-127, 127]."""
    lo = x.min(axis=0)
    hi = x.max(axis=0)
    scale = np.maximum((hi - lo) / 254.0, 1e-12).astype(np.float32)
    offset = ((hi + lo) / 2.0).astype(np.float32)
    return scale, offset


def quantize_ivf(index: ivf_lib.IVFIndex) -> ivf_lib.IVFIndex:
    """SQ8-resident view of an f32 IVF index (bucket layout, ids and
    sizes unchanged; bucket_sqnorm recomputed on the dequantized codes
    so served distances match what the quantized search measures)."""
    if index.quantized:
        return index
    bv = np.asarray(jax.device_get(index.bucket_vecs), np.float32)
    bi = np.asarray(jax.device_get(index.bucket_ids))
    live = bi >= 0
    scale, offset = sq8_range(bv[live])
    codes_live, deq_live, _ = ivf_lib.quantize_sq8(bv[live], scale, offset)
    codes = np.zeros(bv.shape, np.int8)
    codes[live] = codes_live
    sqn = np.full(bi.shape, PAD_SQNORM, np.float32)
    sqn[live] = (deq_live ** 2).sum(axis=1)
    return dataclasses.replace(
        index, bucket_vecs=jnp.asarray(codes),
        bucket_sqnorm=jnp.asarray(sqn),
        scale=jnp.asarray(scale), offset=jnp.asarray(offset))


def quantize_hnsw(index: hnsw_lib.HNSWIndex) -> hnsw_lib.HNSWIndex:
    """SQ8-resident view of an f32 HNSW graph (adjacency, entry and
    routing sample unchanged; dead rows keep sqnorm +inf)."""
    if index.quantized:
        return index
    x = np.asarray(jax.device_get(index.vectors), np.float32)
    sq = np.asarray(jax.device_get(index.sqnorm))
    live = np.isfinite(sq)
    scale, offset = sq8_range(x[live] if live.any() else x)
    codes, deq, _ = ivf_lib.quantize_sq8(x, scale, offset)
    sqn = np.where(live, (deq ** 2).sum(axis=1),
                   PAD_SQNORM).astype(np.float32)
    return dataclasses.replace(
        index, vectors=jnp.asarray(codes), sqnorm=jnp.asarray(sqn),
        scale=jnp.asarray(scale), offset=jnp.asarray(offset))


def resident_bytes(index: AnyIndex) -> Dict[str, int]:
    """Per-array device-resident bytes of an index view, plus "total".

    The steady-state footprint the residency work is gated on: the
    dist_residency benchmark asserts the SQ8 total is >= 3.5x smaller
    than the f32 baseline for the IVF layout, and the shardlint
    resident-bytes pass asserts the N-scaled payload entering the
    compiled step programs is int8-width."""
    out: Dict[str, int] = {}
    total = 0
    for f in dataclasses.fields(index):
        v = getattr(index, f.name)
        if v is None or not hasattr(v, "dtype"):
            continue
        nbytes = int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
        out[f.name] = nbytes
        total += nbytes
    out["total"] = total
    return out


@dataclasses.dataclass
class RerankStore:
    """Host-memory exact f32 vectors for final-top-k re-ranking.

    Row index == global vector id (the id space both engines report).
    The store never ships to the device: candidates come back from the
    SQ8 search over-provisioned (k' = margin * k), the store re-ranks
    them exactly and returns the final k — recovering f32-exact result
    ids at SQ8-resident device cost."""

    vectors: np.ndarray   # f32[N, D]

    def __post_init__(self):
        self.vectors = np.asarray(self.vectors, np.float32)

    def rerank(self, q: np.ndarray, ids: np.ndarray, k: int = 0
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact squared-L2 re-rank of candidate ``ids`` for query
        ``q``; returns (dist f32[k], ids i32[k]) ascending with the
        repo's pad convention (+inf / -1) for missing candidates.
        ``k=0`` keeps the candidate count."""
        ids = np.asarray(ids).reshape(-1).astype(np.int64)
        k = int(k) or ids.size
        valid = (ids >= 0) & (ids < self.vectors.shape[0])
        v = self.vectors[np.clip(ids, 0, self.vectors.shape[0] - 1)]
        q = np.asarray(q, np.float32).reshape(-1)
        d = ((v - q[None, :]) ** 2).sum(axis=1).astype(np.float32)
        d = np.where(valid, d, PAD_DIST)
        order = np.argsort(d, kind="stable")[:k]
        out_d = np.full((k,), PAD_DIST, np.float32)
        out_i = np.full((k,), PAD_ID, np.int32)
        out_d[:order.size] = d[order]
        out_i[:order.size] = np.where(np.isfinite(d[order]), ids[order],
                                      PAD_ID).astype(np.int32)
        return out_d, out_i

    def reranker(self, k: int):
        """Bind ``k``: returns the (q, ids) -> (d, i) callable shape
        DarthServer's ``rerank=`` hook expects."""
        return lambda q, ids: self.rerank(q, ids, k)
