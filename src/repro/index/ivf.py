"""IVF index with a step-wise probe API (the shape DARTH drives).

TPU-native layout (DESIGN.md §2): bucket-major padded storage
``[nlist, cap, D]`` — every probe is a fixed-shape gather + batched matvec,
so the whole search is jit/scan/while-able with per-query active masks.

The probe loop exposes exactly the counters DARTH's features need:
``ndis`` advances by the *true* bucket population (padding excluded),
``nstep`` is the probe number, ``firstNN`` is the distance to the nearest
centroid (paper §3.3.2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.padding import (PAD_DIST, PAD_ID, PAD_SQNORM, pad_dists,
                                pad_ids)
from repro.index import kmeans as kmeans_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array      # f32[nlist, D]
    bucket_vecs: jax.Array    # f32|int8[nlist, cap, D] (zero padded)
    bucket_ids: jax.Array     # i32[nlist, cap] (-1 padding)
    bucket_sqnorm: jax.Array  # f32[nlist, cap] (+inf padding) — of the
    #                           DEQUANTIZED vectors when SQ8
    bucket_sizes: jax.Array   # i32[nlist]
    # SQ8 affine dequant (x_hat = scale * x8 + offset, per dim); identity
    # (ones/zeros) for f32 storage.
    scale: jax.Array          # f32[D]
    offset: jax.Array         # f32[D]
    # Cold-tier indirection (serve.cold): the bucket arrays above hold
    # only the RESIDENT buckets and hot_map[bucket] names the slot a
    # bucket currently occupies (-1 = spilled to the host cold tier; a
    # probe of a cold bucket is skipped, never stalls). None = every
    # bucket resident at its own slot (bucket id == slot id).
    hot_map: Optional[jax.Array] = None   # i32[nlist]

    @property
    def quantized(self) -> bool:
        return self.bucket_vecs.dtype == jnp.int8

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def cap(self) -> int:
        return self.bucket_vecs.shape[1]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    @property
    def num_vectors(self) -> int:
        return int(jax.device_get(self.bucket_sizes).sum())


def quantize_sq8(x: np.ndarray, scale: np.ndarray, offset: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Per-dim affine SQ8: returns (int8 codes, dequantized f32,
    clipped-value count).

    ``scale``/``offset`` are usually the FROZEN base range (compaction
    re-quantizes deltas against it so stored codes stay comparable), so
    vectors from an OOD drift burst can exceed it. They are clamped to
    the representable range — correct, but lossy — and the third return
    counts the clamped scalars so callers can surface the loss
    (``darth_sq8_clipped_total``) instead of silently biasing the
    asymmetric distances."""
    raw = np.round((x - offset) / scale)
    nclipped = int(np.count_nonzero((raw < -127.0) | (raw > 127.0)))
    x8 = np.clip(raw, -127, 127).astype(np.int8)
    return x8, x8.astype(np.float32) * scale + offset, nclipped


def pack_buckets(x_store: np.ndarray, x_deq: np.ndarray, ids: np.ndarray,
                 assign: np.ndarray, nlist: int, *, cap_round: int = 8
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Bucket-major padded layout from precomputed assignments.

    `ids` are arbitrary GLOBAL ids (build passes 0..n-1; streaming
    compaction passes the surviving base + delta ids, which keeps ids
    stable across compactions). cap = max bucket size rounded up to
    cap_round; padded slots carry the repo convention vecs 0 / ids -1 /
    sqnorm +inf. Returns (bucket_vecs, bucket_ids, bucket_sqnorm, sizes).
    """
    gen = pack_buckets_steps(x_store, x_deq, ids, assign, nlist,
                             cap_round=cap_round)
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


def pack_buckets_steps(x_store: np.ndarray, x_deq: np.ndarray,
                       ids: np.ndarray, assign: np.ndarray, nlist: int, *,
                       cap_round: int = 8, chunk: int = 64):
    """Incremental pack_buckets: one generator, both pack paths.

    Yields after filling each `chunk` of buckets so a background
    compaction (mutate.compact) can bound the work per serve-loop tick;
    pack_buckets drains it in one call for the synchronous build path.
    Returns (bucket_vecs, bucket_ids, bucket_sqnorm, sizes) via
    StopIteration.value.
    """
    d = x_store.shape[1]
    order = np.argsort(assign, kind="stable")
    sizes = np.bincount(assign, minlength=nlist)
    cap = int(max(8, -(-int(max(sizes.max(), 1)) // cap_round) * cap_round))
    bucket_vecs = np.zeros((nlist, cap, d), x_store.dtype)
    bucket_ids = np.full((nlist, cap), PAD_ID, np.int32)
    bucket_sqnorm = np.full((nlist, cap), PAD_SQNORM, np.float32)
    starts = np.concatenate([[0], np.cumsum(sizes)])
    for c0 in range(0, nlist, chunk):
        for c in range(c0, min(nlist, c0 + chunk)):
            sz = int(sizes[c])
            sel = order[starts[c]:starts[c] + sz]
            bucket_vecs[c, :sz] = x_store[sel]
            bucket_ids[c, :sz] = ids[sel]
            bucket_sqnorm[c, :sz] = (x_deq[sel] ** 2).sum(axis=1)
        yield
    return bucket_vecs, bucket_ids, bucket_sqnorm, sizes.astype(np.int32)


def build(x: np.ndarray, nlist: int, *, iters: int = 15, seed: int = 0,
          cap_round: int = 8, quantize: bool = False) -> IVFIndex:
    """Cluster + bucket-major layout. cap = max bucket size rounded up.

    quantize=True stores vectors as SQ8 (per-dim affine int8): 4x less HBM
    at search time with asymmetric (f32-query vs dequantized-db) distances;
    bucket_sqnorm is computed on the dequantized vectors so reported
    distances match what the quantized search actually measures.
    """
    x = np.asarray(x, np.float32)
    n, d = x.shape
    cents = kmeans_lib.kmeans(x, nlist, iters=iters, seed=seed)
    a = np.asarray(kmeans_lib.assign(jnp.asarray(x), jnp.asarray(cents)))

    if quantize:
        lo = x.min(axis=0)
        hi = x.max(axis=0)
        scale = np.maximum((hi - lo) / 254.0, 1e-12).astype(np.float32)
        offset = ((hi + lo) / 2.0).astype(np.float32)
        x_store, x_deq, _ = quantize_sq8(x, scale, offset)
    else:
        scale = np.ones((d,), np.float32)
        offset = np.zeros((d,), np.float32)
        x_store = x
        x_deq = x

    bucket_vecs, bucket_ids, bucket_sqnorm, sizes = pack_buckets(
        x_store, x_deq, np.arange(n, dtype=np.int32), a, nlist,
        cap_round=cap_round)
    return IVFIndex(
        centroids=jnp.asarray(cents),
        bucket_vecs=jnp.asarray(bucket_vecs),
        bucket_ids=jnp.asarray(bucket_ids),
        bucket_sqnorm=jnp.asarray(bucket_sqnorm),
        bucket_sizes=jnp.asarray(sizes),
        scale=jnp.asarray(scale),
        offset=jnp.asarray(offset),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IVFSearchState:
    q: jax.Array            # f32[B, D]
    qsq: jax.Array          # f32[B, 1]
    probe_order: jax.Array  # i32[B, nprobe] ranked centroids
    first_nn: jax.Array     # f32[B] distance to nearest centroid
    probe_pos: jax.Array    # i32[B] next probe
    topk_d: jax.Array       # f32[B, K] ascending (inf = empty)
    topk_i: jax.Array       # i32[B, K] (-1 = empty)
    active: jax.Array       # bool[B]
    ndis: jax.Array         # i32[B] true distance calcs so far
    ninserts: jax.Array     # i32[B] result-set updates so far


def rank_centroids(centroids: jax.Array, qf: jax.Array, qsq: jax.Array,
                   nprobe: int) -> Tuple[jax.Array, jax.Array]:
    """Rank the nprobe closest centroids per query; also returns the
    first-NN distance feature. Shared by init_state and the sharded
    init (dist.collectives pins this top_k inside a batch-axis
    shard_map on a hosts mesh — one definition keeps them in parity)."""
    cd = (jnp.sum(centroids**2, axis=1)[None, :]
          - 2.0 * qf @ centroids.T)                            # [B, nlist]
    neg, order = jax.lax.top_k(-cd, nprobe)
    first_nn = jnp.sqrt(jnp.maximum(-neg[:, 0] + qsq[:, 0], 0.0))
    return order.astype(jnp.int32), first_nn


def fresh_state(qf: jax.Array, qsq: jax.Array, order: jax.Array,
                first_nn: jax.Array, k: int) -> IVFSearchState:
    """Assemble the start-of-search state around a ranked probe order."""
    b = qf.shape[0]
    return IVFSearchState(
        q=qf, qsq=qsq,
        probe_order=order,
        first_nn=first_nn,
        probe_pos=jnp.zeros((b,), jnp.int32),
        topk_d=pad_dists((b, k)),
        topk_i=pad_ids((b, k)),
        active=jnp.ones((b,), bool),
        ndis=jnp.zeros((b,), jnp.int32),
        ninserts=jnp.zeros((b,), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def init_state(index: IVFIndex, q: jax.Array, *, k: int,
               nprobe: int) -> IVFSearchState:
    qf = q.astype(jnp.float32)
    qsq = jnp.sum(qf**2, axis=1, keepdims=True)
    order, first_nn = rank_centroids(index.centroids, qf, qsq, nprobe)
    return fresh_state(qf, qsq, order, first_nn, k)


@jax.jit
def probe_step(index: IVFIndex, s: IVFSearchState) -> IVFSearchState:
    """Scan one bucket per active query; merge global top-k; bump counters."""
    b, k = s.topk_d.shape
    nprobe = s.probe_order.shape[1]
    pos = jnp.minimum(s.probe_pos, nprobe - 1)
    bucket = jnp.take_along_axis(s.probe_order, pos[:, None], axis=1)[:, 0]

    if index.hot_map is not None:
        # Cold tier: resolve bucket -> resident slot; a cold bucket
        # (slot -1) is SKIPPED this probe — the position still
        # advances, its candidates and ndis are masked out — so a cold
        # hit never stalls the fixed-shape step (serve.cold prefetches
        # ahead of the probe order to make misses rare).
        slot = index.hot_map[bucket]        # [B]
        hot = slot >= 0
        slot = jnp.maximum(slot, 0)
    else:
        slot = bucket
        hot = None
    vecs = index.bucket_vecs[slot]          # [B, cap, D] (f32 or int8)
    ids = index.bucket_ids[slot]            # [B, cap]
    sqn = index.bucket_sqnorm[slot]         # [B, cap]
    sizes = index.bucket_sizes[bucket]      # [B] (full per-bucket sizes)

    if index.quantized:
        # asymmetric SQ8: q . x_hat = (q*scale) . x8 + q . offset
        qa = s.q * index.scale[None, :]
        dots = (jnp.einsum("bd,bcd->bc", qa, vecs.astype(jnp.float32))
                + (s.q @ index.offset)[:, None])
    else:
        dots = jnp.einsum("bd,bcd->bc", s.q, vecs)
    dist = sqn - 2.0 * dots + s.qsq
    dist = jnp.where(ids >= 0, jnp.maximum(dist, 0.0), PAD_DIST)
    # Inactive queries contribute nothing.
    dist = jnp.where(s.active[:, None], dist, PAD_DIST)
    if hot is not None:
        dist = jnp.where(hot[:, None], dist, PAD_DIST)
        sizes = jnp.where(hot, sizes, 0)

    old_kth = s.topk_d[:, -1]
    cand_d = jnp.concatenate([s.topk_d, dist], axis=1)
    cand_i = jnp.concatenate([s.topk_i, ids], axis=1)
    neg, sel = jax.lax.top_k(-cand_d, k)
    new_d = -neg
    new_i = jnp.take_along_axis(cand_i, sel, axis=1)

    inserts = jnp.sum(dist < old_kth[:, None], axis=1).astype(jnp.int32)
    inserts = jnp.minimum(inserts, k)
    done_probes = s.probe_pos + s.active.astype(jnp.int32)
    return IVFSearchState(
        q=s.q, qsq=s.qsq, probe_order=s.probe_order, first_nn=s.first_nn,
        probe_pos=done_probes,
        topk_d=jnp.where(s.active[:, None], new_d, s.topk_d),
        topk_i=jnp.where(s.active[:, None], new_i, s.topk_i),
        active=s.active & (done_probes < nprobe),
        ndis=s.ndis + jnp.where(s.active, sizes, 0).astype(jnp.int32),
        ninserts=s.ninserts + jnp.where(s.active, inserts, 0),
    )


def _drive(step, index: IVFIndex, s: IVFSearchState
           ) -> Tuple[jax.Array, jax.Array, IVFSearchState]:
    """Run a probe step to natural termination (all probes exhausted)."""
    s = jax.lax.while_loop(lambda s: s.active.any(),
                           lambda s: step(index, s), s)
    return s.topk_d, s.topk_i, s


def search(index: IVFIndex, q: jax.Array, *, k: int,
           nprobe: int) -> Tuple[jax.Array, jax.Array, IVFSearchState]:
    """Plain (no early termination) IVF search: scan all nprobe buckets."""
    return _drive(probe_step, index, init_state(index, q, k=k, nprobe=nprobe))


def search_sharded(index: IVFIndex, q: jax.Array, *, k: int, nprobe: int,
                   mesh, use_kernel: bool = True, interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array, IVFSearchState]:
    """Plain IVF search through the shard_map probe step: `index` must be
    placed with dist.place_index(index, mesh) (cap dim split over the
    "model" axis). Numerically matches `search` on any shard count."""
    from repro.dist import collectives  # local import: dist uses kernels

    step = collectives.make_sharded_probe_step(
        mesh, use_kernel=use_kernel, interpret=interpret)
    return _drive(step, index, init_state(index, q, k=k, nprobe=nprobe))
