"""TPU-adapted HNSW: fixed-degree navigable graph + batched beam search.

Hardware adaptation (DESIGN.md §2): the paper's HNSW is a pointer-chasing,
one-query-per-core CPU structure. The TPU-native equivalent keeps the
*search semantics* of HNSW's base layer (best-first beam with an
efSearch-sized frontier, natural termination when no unexpanded candidate
remains among the best ef) but re-structures everything as fixed shapes:

  * graph      = int32[N, M] adjacency (padded with -1), built in vectorized
                 batches: exact kNN candidates -> RobustPrune (alpha-CNG,
                 the Vamana rule) -> reverse-edge merge -> re-prune. GPU/TPU
                 HNSW builders use the same batch strategy; the paper's
                 upper layers are replaced by a medoid entry point (their
                 role — a good entry — is preamble, not where DARTH acts).
  * frontier   = the best `ef` candidates per query, ascending, with an
                 expanded bitmask; result set = first k of the frontier
                 (always sorted, so DARTH's percentile features are O(1)).
  * visited    = per-query bitmap [B, N] (exact; a hashed variant would
                 trade memory for false-positive skips at billion scale).
  * one step   = expand closest unexpanded candidate of every active query:
                 gather M neighbors, mask visited, batched distance, merge.
                 ndis advances by the number of *new* distance computations,
                 matching the paper's accounting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.padding import PAD_DIST, PAD_ID, pad_dists, pad_ids


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HNSWIndex:
    vectors: jax.Array    # f32|int8[N, D] (SQ8-resident when int8)
    sqnorm: jax.Array     # f32[N] — of the DEQUANTIZED vectors when SQ8
    neighbors: jax.Array  # i32[N, M] (-1 pad)
    entry: jax.Array      # i32[] medoid entry point (fallback)
    route_ids: jax.Array  # i32[R] upper-layer stand-in: uniform node sample;
    #                       one dense scan picks a per-query base-layer entry
    #                       (the role HNSW's upper layers play, one matmul)
    # SQ8 affine dequant (x_hat = scale * x8 + offset, per dim); None for
    # f32 storage (index.residency.quantize_hnsw produces SQ8 views).
    scale: Optional[jax.Array] = None    # f32[D]
    offset: Optional[jax.Array] = None   # f32[D]

    @property
    def quantized(self) -> bool:
        return self.vectors.dtype == jnp.int8

    @property
    def num_vectors(self) -> int:
        return self.vectors.shape[0]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]


def asym_query(index: HNSWIndex, qf: jax.Array, qsq: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """SQ8 asymmetric query transform (identity for f32 storage).

    Distances to dequantized codes decompose per query:
    ``||x_hat - q||^2 = ||x_hat||^2 - 2 (q*scale).x8 + (||q||^2 -
    2 q.offset)``, so passing ``(q*scale, qsq - 2 q.offset)`` as the
    state's (q, qsq) lets every downstream dot-product path — the
    routing scan, beam_step, the sharded expand — serve int8 codes
    UNCHANGED except for an f32 cast of the gathered vectors."""
    if not index.quantized:
        return qf, qsq
    q_eff = qf * index.scale[None, :]
    bias = qsq - 2.0 * (qf @ index.offset)[:, None]
    return q_eff, bias


def hash_slot(ids: jax.Array, width: int) -> jax.Array:
    """Fibonacci-hash node ids into [0, width); width a power of two.

    The hashed visited filter's slot function: multiplicative hashing
    by 2654435761 (2^32/phi) then taking the TOP log2(width) bits, so
    consecutive ids (bucket-local neighborhoods) spread across the
    filter instead of aliasing into the same word."""
    log2w = int(width).bit_length() - 1
    h = (ids.astype(jnp.uint32) * jnp.uint32(2654435761)
         ) >> jnp.uint32(32 - log2w)
    return h.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def _pairwise_sq(v: jax.Array) -> jax.Array:
    """v: [B, C, D] -> [B, C, C] squared L2 among candidates."""
    sq = jnp.sum(v**2, axis=2)
    dots = jnp.einsum("bcd,bed->bce", v, v)
    return jnp.maximum(sq[:, :, None] + sq[:, None, :] - 2.0 * dots, 0.0)


@functools.partial(jax.jit, static_argnames=("m", "alpha"))
def _robust_prune(cand_i: jax.Array, cand_d: jax.Array, pd: jax.Array,
                  m: int, alpha: float = 1.2) -> jax.Array:
    """Vectorized Vamana RobustPrune.

    cand_i: i32[B, C] candidate ids sorted by distance to owner (-1 invalid)
    cand_d: f32[B, C] distances to owner
    pd:     f32[B, C, C] pairwise distances among candidates
    Returns i32[B, m] selected neighbors (-1 pad).
    """
    b, c = cand_i.shape
    alive = cand_i >= 0
    out = pad_ids((b, m))
    col = jnp.arange(c)

    def body(t, carry):
        alive, out = carry
        # First alive candidate (they are distance-sorted).
        score = jnp.where(alive, col[None, :], c + 1)
        pick = jnp.argmin(score, axis=1)                       # [B]
        has = jnp.take_along_axis(alive, pick[:, None], 1)[:, 0]
        pick_id = jnp.take_along_axis(cand_i, pick[:, None], 1)[:, 0]
        out = out.at[:, t].set(jnp.where(has, pick_id, PAD_ID))
        # Kill candidates dominated by the pick: alpha*d(pick,c) <= d(u,c).
        pd_pick = jnp.take_along_axis(pd, pick[:, None, None], 1)[:, 0, :]
        dominated = alpha * pd_pick <= cand_d
        alive = alive & ~dominated & (col[None, :] != pick[:, None])
        alive = alive & has[:, None]
        return alive, out

    _, out = jax.lax.fori_loop(0, m, body, (alive, out))
    return out


def _dedup_rows_vec(ids: np.ndarray) -> np.ndarray:
    """Vectorized per-row dedup: keeps first occurrence, others -> -1."""
    b, c = ids.shape
    order = np.argsort(ids, axis=1, kind="stable")
    s = np.take_along_axis(ids, order, axis=1)
    dup = np.zeros_like(s, dtype=bool)
    dup[:, 1:] = (s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)
    mask = np.zeros_like(dup)
    np.put_along_axis(mask, order, dup, axis=1)
    out = ids.copy()
    out[mask] = PAD_ID
    return out


def _reverse_edges(fwd: np.ndarray, slots: int) -> np.ndarray:
    """Collect up to `slots` reverse proposals per node from forward edges."""
    n, m = fwd.shape
    src = np.repeat(np.arange(n, dtype=np.int32), m)
    dst = fwd.reshape(-1)
    ok = (dst >= 0) & (dst != src)
    src, dst = src[ok], dst[ok]
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    grp_start = np.r_[True, dst[1:] != dst[:-1]] if len(dst) else np.zeros(0, bool)
    pos = (np.arange(len(dst))
           - np.maximum.accumulate(np.where(grp_start, np.arange(len(dst)), 0)))
    rev = np.full((n, slots), PAD_ID, np.int32)
    keep = pos < slots
    rev[dst[keep], pos[keep]] = src[keep]
    return rev


def _prune_rows(x: np.ndarray, owners: np.ndarray, merged: np.ndarray,
                m: int, alpha2: float) -> np.ndarray:
    """Distance-sort + alpha-prune candidate lists for `owners` rows.

    owners: i64[B] node ids; merged: i32[B, C] candidate ids (-1 invalid,
    self-edges dropped). Returns i32[B, m]. Shared by the full-graph
    build re-prune and the streaming insert/delete repair paths."""
    vi = x[np.maximum(merged, 0)]
    du = ((vi - x[owners, None, :]) ** 2).sum(axis=2).astype(np.float32)
    du = np.where((merged >= 0) & (merged != owners[:, None]), du, PAD_DIST)
    ord_ = np.argsort(du, axis=1, kind="stable")
    ci_s = np.where(np.take_along_axis(du, ord_, 1) < np.inf,
                    np.take_along_axis(merged, ord_, 1), PAD_ID)
    du_s = np.take_along_axis(du, ord_, axis=1)
    pd = _pairwise_sq(jnp.asarray(x[np.maximum(ci_s, 0)]))
    return np.asarray(_robust_prune(
        jnp.asarray(ci_s), jnp.asarray(du_s), pd, m, alpha2))


def _pool_prune(x: np.ndarray, owners: np.ndarray, cand_d: np.ndarray,
                cand_i: np.ndarray, m: int, alpha2: float) -> np.ndarray:
    """Forward edges from a beam-search candidate pool.

    cand_d / cand_i are the owners' ef-wide search frontier (the
    ef_construction candidate pool): drop self and invalid entries,
    distance-sort, RobustPrune to m forward edges. owners: i64[B] node
    ids; returns i32[B, m] (-1 padded). Shared by the batch build and
    the streaming insert path — the two were duplicated copies before.
    """
    cd = np.where((cand_i == owners[:, None]) | (cand_i < 0), PAD_DIST,
                  cand_d)
    ord_ = np.argsort(cd, axis=1, kind="stable")
    ci_s = np.where(np.take_along_axis(cd, ord_, 1) < np.inf,
                    np.take_along_axis(cand_i, ord_, 1), PAD_ID)
    cd_s = np.take_along_axis(cd, ord_, axis=1)
    pd = _pairwise_sq(jnp.asarray(x[np.maximum(ci_s, 0)]))
    return np.asarray(_robust_prune(
        jnp.asarray(ci_s), jnp.asarray(cd_s), pd, m, alpha2))


def _prune_merged(x: np.ndarray, merged: np.ndarray, m: int, alpha2: float,
                  chunk: int) -> np.ndarray:
    """Distance-sort + alpha-prune candidate lists to degree m (chunked)."""
    n = x.shape[0]
    out = np.zeros((n, m), np.int32)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        out[lo:hi] = _prune_rows(x, np.arange(lo, hi), merged[lo:hi],
                                 m, alpha2)
    return out


def build(x: np.ndarray, m: int = 16, *, ef_construction: int = 64,
          passes: int = 2, alpha: float = 1.2, chunk: int = 1024,
          seed: int = 0) -> HNSWIndex:
    """Vamana-style batch build (see module docstring).

    Random-init R-regular graph (global connectivity), then `passes` rounds:
    for each node batch, beam-search the current graph for the node itself
    (ef_construction frontier = candidate pool), RobustPrune to m forward
    edges, then merge reverse proposals and re-prune. `alpha` is the metric-
    space diversification factor (applied as alpha^2 in squared-L2 space).
    """
    x = np.asarray(x, np.float32)
    n, d = x.shape
    xs = jnp.asarray(x)
    sq = jnp.sum(xs**2, axis=1)
    rng = np.random.default_rng(seed)
    alpha2 = float(alpha) ** 2

    neighbors = rng.integers(0, n, size=(n, m), dtype=np.int64).astype(np.int32)
    neighbors = _dedup_rows_vec(neighbors)
    entry = int(np.argmin(((x - x.mean(0)) ** 2).sum(1)))
    # Routing sample = upper-layer stand-in (uniform, like HNSW level draws).
    r = int(min(8192, max(64, n // 64)))
    route_ids = jnp.asarray(rng.choice(n, size=min(r, n), replace=False)
                            .astype(np.int32))
    efc = max(ef_construction, 2 * m)

    for _ in range(passes):
        idx = HNSWIndex(vectors=xs, sqnorm=sq,
                        neighbors=jnp.asarray(neighbors),
                        entry=jnp.asarray(entry, jnp.int32),
                        route_ids=route_ids)
        fwd = np.zeros((n, m), np.int32)
        for lo in range(0, n, chunk):
            hi = min(n, lo + chunk)
            _, _, s = search(idx, xs[lo:hi], k=m, ef=efc,
                             max_steps=4 * efc)
            fwd[lo:hi] = _pool_prune(x, np.arange(lo, hi),
                                     np.asarray(s.cand_d),
                                     np.asarray(s.cand_i), m, alpha2)
        rev = _reverse_edges(fwd, m)
        # Union with the previous graph: keeps the long "highway" edges the
        # frontier-only candidate pool cannot see (Vamana's visited-set role).
        merged = _dedup_rows_vec(np.concatenate([fwd, rev, neighbors], axis=1))
        neighbors = _prune_merged(x, merged, m, alpha2, chunk)

    return HNSWIndex(vectors=xs, sqnorm=sq,
                     neighbors=jnp.asarray(neighbors),
                     entry=jnp.asarray(entry, jnp.int32),
                     route_ids=route_ids)


def insert_nodes(index: HNSWIndex, rows: np.ndarray, *,
                 ef_construction: int = 64, alpha: float = 1.2,
                 chunk: int = 1024) -> HNSWIndex:
    """Incrementally link already-appended rows (streaming compaction).

    `rows` must already be present in vectors/sqnorm (their neighbor
    rows are overwritten); entry/route_ids must reference nodes that are
    live and linked, since they seed the candidate searches. Per chunk:
    beam-search the CURRENT graph for each new vector (its
    ef_construction frontier is the candidate pool, exactly like the
    batch build), RobustPrune to m forward edges, then merge the reverse
    proposals into each target's list and re-prune — the reverse-edge
    repair that makes new nodes reachable.

    (Synchronous wrapper: drains insert_nodes_steps in one call.)
    """
    gen = insert_nodes_steps(index, rows, ef_construction=ef_construction,
                             alpha=alpha, chunk=chunk)
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


def insert_nodes_steps(index: HNSWIndex, rows: np.ndarray, *,
                       ef_construction: int = 64, alpha: float = 1.2,
                       chunk: int = 1024):
    """Generator form of insert_nodes: yields after each linked chunk
    (one bounded unit of work — a background compaction's tick
    boundary) and returns the updated index via StopIteration.value."""
    rows = np.asarray(rows, np.int64)
    if rows.size == 0:
        return index
    x = np.asarray(index.vectors)
    sq = np.asarray(index.sqnorm)
    nbr = np.asarray(index.neighbors).copy()
    n, m = nbr.shape
    alpha2 = float(alpha) ** 2
    efc = max(ef_construction, 2 * m)
    # vectors/sqnorm never change across chunks — upload once; only the
    # adjacency is re-wrapped per chunk
    xv = jnp.asarray(x)
    sqv = jnp.asarray(sq)

    for lo in range(0, rows.size, chunk):
        sel = rows[lo:lo + chunk]
        cur = HNSWIndex(vectors=xv, sqnorm=sqv,
                        neighbors=jnp.asarray(nbr), entry=index.entry,
                        route_ids=index.route_ids)
        _, _, s = search(cur, jnp.asarray(x[sel]), k=m, ef=efc,
                         max_steps=4 * efc)
        fwd = _pool_prune(x, sel, np.asarray(s.cand_d),
                          np.asarray(s.cand_i), m, alpha2)
        nbr[sel] = fwd
        # Reverse-edge repair: every forward target merges the new node
        # into its own list and re-prunes to degree m.
        fwd_full = np.full((n, m), PAD_ID, np.int32)
        fwd_full[sel] = fwd
        rev = _reverse_edges(fwd_full, m)
        targets = np.nonzero((rev >= 0).any(axis=1))[0]
        if targets.size:
            merged = _dedup_rows_vec(
                np.concatenate([nbr[targets], rev[targets]], axis=1))
            nbr[targets] = _prune_rows(x, targets, merged, m, alpha2)
        yield

    return dataclasses.replace(index, neighbors=jnp.asarray(nbr))


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HNSWSearchState:
    q: jax.Array         # f32[B, D] effective query (q*scale when SQ8)
    qsq: jax.Array       # f32[B, 1] effective bias (see asym_query)
    cand_d: jax.Array    # f32[B, ef] ascending (frontier + results)
    cand_i: jax.Array    # i32[B, ef]
    cand_exp: jax.Array  # bool[B, ef]
    visited: jax.Array   # bool[B, N] exact bitmap, or [B, W] hashed
    #                      filter when W < N (see hash_slot)
    first_nn: jax.Array  # f32[B]
    active: jax.Array    # bool[B]
    ndis: jax.Array      # i32[B]
    ninserts: jax.Array  # i32[B]
    nstep: jax.Array     # i32[B]

    def topk(self, k: int) -> Tuple[jax.Array, jax.Array]:
        return self.cand_d[:, :k], self.cand_i[:, :k]


@functools.partial(jax.jit, static_argnames=("ef", "visited_width"))
def init_state(index: HNSWIndex, q: jax.Array, *, ef: int,
               visited_width: int = 0) -> HNSWSearchState:
    """Start-of-search state. ``visited_width=0`` keeps the exact
    [B, N] visited bitmap; a nonzero power-of-two width < N switches to
    the N-independent hashed visited filter (bounded false-positive
    skips — a colliding NEW node is treated as already seen)."""
    b = q.shape[0]
    n = index.num_vectors
    qf = q.astype(jnp.float32)
    qsq = jnp.sum(qf**2, axis=1, keepdims=True)
    # SQ8: fold the asymmetric transform into the state's (q, qsq) so
    # every later dot product serves int8 codes unchanged.
    q_eff, qb = asym_query(index, qf, qsq)
    # Upper-layer stand-in: one dense scan of the routing sample picks a
    # per-query base-layer entry (greedy descent's role in HNSW).
    rv = index.vectors[index.route_ids]                     # [R, D]
    rd = (index.sqnorm[index.route_ids][None, :]
          - 2.0 * q_eff @ rv.astype(jnp.float32).T + qb)    # [B, R]
    r_best = jnp.argmin(rd, axis=1)
    e = index.route_ids[r_best]                             # [B]
    ed = jnp.maximum(jnp.take_along_axis(rd, r_best[:, None], 1)[:, 0], 0.0)
    first_nn = jnp.sqrt(ed)
    # Frontier sentinels via the shared pad helpers (dtype-pinned: the
    # three hand-rolled fulls here and in mutate's tombstone writes used
    # to mix strong f32 with weak floats — see core/padding.py).
    cand_d = pad_dists((b, ef)).at[:, 0].set(ed)
    cand_i = pad_ids((b, ef)).at[:, 0].set(e)
    cand_exp = jnp.zeros((b, ef), bool)
    if visited_width:
        w = int(visited_width)
        if w < 2 or w & (w - 1) or w >= n:
            raise ValueError(
                f"visited_width must be a power of two in [2, N) "
                f"(got {w} for N={n})")
        visited = jnp.zeros((b, w), bool).at[
            jnp.arange(b), hash_slot(e, w)].set(True)
    else:
        visited = jnp.zeros((b, n), bool).at[jnp.arange(b), e].set(True)
    # The routing scan above really computes R distances per query, so
    # ndis starts at R — NOT 1 — keeping fit-time ground-truth features
    # and serve-time features on the same scale (the entry's distance is
    # one of the R; beam steps then add only *new* computations).
    nroute = index.route_ids.shape[0]
    return HNSWSearchState(
        q=q_eff, qsq=qb, cand_d=cand_d, cand_i=cand_i, cand_exp=cand_exp,
        visited=visited, first_nn=first_nn,
        active=jnp.ones((b,), bool),
        ndis=jnp.full((b,), nroute, jnp.int32),
        ninserts=jnp.ones((b,), jnp.int32),
        nstep=jnp.zeros((b,), jnp.int32),
    )


def select_expand(s: HNSWSearchState
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pick each query's closest unexpanded candidate.

    Replicated frontier bookkeeping shared by the single-device and
    sharded (dist.collectives.make_sharded_beam_step) beam steps — one
    definition so the two stay in exact parity. Returns
    (sel_id_safe i32[B], act bool[B], cand_exp bool[B, ef])."""
    b, ef = s.cand_d.shape
    unexp_d = jnp.where(s.cand_exp | (s.cand_i < 0), PAD_DIST, s.cand_d)
    sel = jnp.argmin(unexp_d, axis=1)                       # [B]
    sel_d = jnp.take_along_axis(unexp_d, sel[:, None], 1)[:, 0]
    # Natural termination: no unexpanded candidate among the best ef.
    act = s.active & jnp.isfinite(sel_d)
    sel_id = jnp.take_along_axis(s.cand_i, sel[:, None], 1)[:, 0]
    onehot = jax.lax.broadcasted_iota(jnp.int32, (b, ef), 1) == sel[:, None]
    cand_exp = s.cand_exp | (onehot & act[:, None])
    return jnp.maximum(sel_id, 0), act, cand_exp


def frontier_topk(cand_d: jax.Array, cand_i: jax.Array, cand_e: jax.Array,
                  ef: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Keep the best ef of the concatenated [B, ef + M] frontier.

    The single source of the beam frontier's tie-break order: both
    merge_expand (below) and the batch-local wrapper the sharded beam
    step substitutes (dist.collectives.make_sharded_beam_step) call this
    exact function, so the single-device and sharded steps cannot drift
    out of parity."""
    neg, pos = jax.lax.top_k(-cand_d, ef)
    return (-neg, jnp.take_along_axis(cand_i, pos, axis=1),
            jnp.take_along_axis(cand_e, pos, axis=1))


def merge_expand(s: HNSWSearchState, cand_exp: jax.Array, act: jax.Array,
                 nbrs: jax.Array, dist: jax.Array, visited: jax.Array, *,
                 k: int, topk=frontier_topk) -> HNSWSearchState:
    """Merge one expansion's [B, M] candidates into the frontier and
    advance the counters (shared tail of both beam steps; the top_k over
    the concatenated [B, ef + M] layout fixes the tie-break order).

    `dist` carries +inf for masked (invalid / already-seen) slots, so
    the finite count IS the number of new distance computations.

    `topk` must be observationally identical to frontier_topk — the
    sharded beam step passes a shard_map-wrapped frontier_topk so the
    top-k custom-call runs on each host group's local slot rows instead
    of forcing a cross-host gather (jax.lax.top_k lowers to a TopK
    custom-call, which the GSPMD partitioner cannot split)."""
    b, ef = s.cand_d.shape
    mdeg = nbrs.shape[1]
    old_kth = s.cand_d[:, k - 1]
    cand_d = jnp.concatenate([s.cand_d, dist], axis=1)
    cand_i = jnp.concatenate([s.cand_i, nbrs], axis=1)
    cand_e = jnp.concatenate([cand_exp, jnp.zeros((b, mdeg), bool)], axis=1)
    new_d, new_i, new_e = topk(cand_d, cand_i, cand_e, ef)

    ndis_inc = jnp.sum(jnp.isfinite(dist), axis=1)
    inserts = jnp.minimum(jnp.sum(dist < old_kth[:, None], axis=1), k)
    return dataclasses.replace(
        s,
        cand_d=jnp.where(act[:, None], new_d, s.cand_d),
        cand_i=jnp.where(act[:, None], new_i, s.cand_i),
        cand_exp=jnp.where(act[:, None], new_e, cand_exp),
        visited=visited,
        active=act,
        ndis=s.ndis + jnp.where(act, ndis_inc, 0).astype(jnp.int32),
        ninserts=s.ninserts + jnp.where(act, inserts, 0).astype(jnp.int32),
        nstep=s.nstep + act.astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def beam_step(index: HNSWIndex, s: HNSWSearchState, *,
              k: int) -> HNSWSearchState:
    """Expand the closest unexpanded candidate of every active query."""
    b = s.cand_d.shape[0]
    sel_id_safe, act, cand_exp = select_expand(s)

    nbrs = index.neighbors[sel_id_safe]                     # [B, M]
    valid = (nbrs >= 0) & act[:, None]
    nbrs_safe = jnp.maximum(nbrs, 0)
    if s.visited.shape[1] < index.num_vectors:
        # Hashed visited filter: membership checked/set at the hash
        # slot. A colliding NEW node reads as seen and is skipped — the
        # bounded false-positive cost the conformance suite budgets.
        mark = hash_slot(nbrs_safe, s.visited.shape[1])
    else:
        mark = nbrs_safe
    seen = jnp.take_along_axis(s.visited, mark, axis=1)
    new = valid & ~seen
    visited = s.visited.at[
        jnp.arange(b)[:, None], jnp.where(valid, mark, 0)].max(valid)

    vecs = index.vectors[nbrs_safe]                 # [B, M, D] f32|int8
    dist = (index.sqnorm[nbrs_safe]
            - 2.0 * jnp.einsum("bd,bmd->bm", s.q, vecs.astype(jnp.float32))
            + s.qsq)
    dist = jnp.where(new, jnp.maximum(dist, 0.0), PAD_DIST)
    return merge_expand(s, cand_exp, act, nbrs, dist, visited, k=k)


def _drive(step, index: HNSWIndex, s: HNSWSearchState, k: int, limit
           ) -> Tuple[jax.Array, jax.Array, HNSWSearchState]:
    """Run a beam step to natural termination (or the step limit)."""
    def cond(carry):
        s, t = carry
        return s.active.any() & (t < limit)

    def body(carry):
        s, t = carry
        return step(index, s, k=k), t + 1

    s, _ = jax.lax.while_loop(cond, body, (s, jnp.asarray(0, jnp.int32)))
    d, i = s.topk(k)
    return d, i, s


def search(index: HNSWIndex, q: jax.Array, *, k: int, ef: int,
           max_steps: int = 0, visited_width: int = 0
           ) -> Tuple[jax.Array, jax.Array, HNSWSearchState]:
    """Plain HNSW search to natural termination."""
    return _drive(beam_step, index,
                  init_state(index, q, ef=ef, visited_width=visited_width),
                  k, max_steps or index.num_vectors)


def search_sharded(index: HNSWIndex, q: jax.Array, *, k: int, ef: int,
                   mesh, max_steps: int = 0, visited_width: int = 0
                   ) -> Tuple[jax.Array, jax.Array, HNSWSearchState]:
    """Plain HNSW search through the shard_map beam step: `index` must be
    placed with dist.place_index(index, mesh) (vectors/sqnorm/neighbors
    split on the node dim over the "model" axis; the visited structure —
    exact bitmap or hashed filter — is split the same way inside the
    step). Matches `search` exactly (topk_d / topk_i / ndis / ninserts)
    on any shard count."""
    from repro.dist import collectives  # local import: dist uses kernels

    step = collectives.make_sharded_beam_step(mesh)
    return _drive(step, index,
                  init_state(index, q, ef=ef, visited_width=visited_width),
                  k, max_steps or index.num_vectors)
