"""ANN index substrate: exact flat search, IVF, TPU-adapted HNSW graph."""
from repro.index import flat, hnsw, ivf, kmeans

__all__ = ["flat", "hnsw", "ivf", "kmeans"]
