"""Exact (flat) k-NN search — ground truth for recall measurement and the
training-data generator, plus the sharded brute-force baseline.

Single-device path chunks over the DB; the distributed path shards the DB
rows across the mesh and merges per-shard top-k with one small all-gather
(see dist/collectives.py) — collective volume O(B*k*devices), independent
of N.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.padding import PAD_DIST, pad_dists, pad_ids


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def search(q: jax.Array, x: jax.Array, k: int,
           chunk: int = 65536) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k. q: [B, D], x: [N, D] -> (dist [B,k] ascending, idx [B,k])."""
    n, d = x.shape
    b = q.shape[0]
    qf = q.astype(jnp.float32)
    qsq = jnp.sum(qf**2, axis=1, keepdims=True)
    n_chunks = max(1, -(-n // chunk))
    pad = n_chunks * chunk - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xsq = jnp.concatenate([jnp.sum(xp[:n].astype(jnp.float32) ** 2, axis=1),
                           pad_dists((pad,))])
    xc = xp.reshape(n_chunks, chunk, d)
    xsqc = xsq.reshape(n_chunks, chunk)

    def body(carry, inp):
        best_d, best_i = carry
        xi, xsqi, off = inp
        dist = xsqi[None, :] - 2.0 * qf @ xi.astype(jnp.float32).T
        ids = off + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
        cand_d = jnp.concatenate([best_d, dist], axis=1)
        cand_i = jnp.concatenate([best_i, ids], axis=1)
        neg, pos = jax.lax.top_k(-cand_d, k)
        return (-neg, jnp.take_along_axis(cand_i, pos, axis=1)), None

    init = (pad_dists((b, k)), pad_ids((b, k)))
    offs = (jnp.arange(n_chunks, dtype=jnp.int32) * chunk)
    (best_d, best_i), _ = jax.lax.scan(body, init, (xc, xsqc, offs))
    best_d = jnp.where(best_i >= 0, jnp.maximum(best_d + qsq, 0.0), PAD_DIST)
    return best_d, best_i


def search_sharded(q: jax.Array, x: jax.Array, k: int, mesh
                   ) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k with the database row-sharded over mesh axis "model"
    (dist/collectives.py); numerically matches `search`."""
    from repro.dist import collectives  # local import: dist uses kernels
    return collectives.sharded_flat_search(q, x, k, mesh)


def recall_at_k(found_ids: jax.Array, true_ids: jax.Array) -> jax.Array:
    """recall@k: |found ∩ true| / k. found/true: int32[B, k] (-1 = empty)."""
    matches = (found_ids[:, :, None] == true_ids[:, None, :]) & (found_ids[:, :, None] >= 0)
    return matches.any(axis=2).sum(axis=1).astype(jnp.float32) / true_ids.shape[1]
