"""Lloyd's k-means in JAX (IVF coarse quantizer).

kmeans++-style seeding on a subsample, then jitted Lloyd iterations with
chunked assignment (the assignment hot loop is the same fused distance
pattern as kernels/l2_topk; on CPU we use the XLA path for speed, on TPU
the Pallas kernel path).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("chunk",))
def assign(x: jax.Array, centroids: jax.Array, chunk: int = 8192) -> jax.Array:
    """Nearest-centroid assignment. x: [N, D], centroids: [C, D] -> int32[N]."""
    n = x.shape[0]
    csq = jnp.sum(centroids**2, axis=1)

    def one(chunk_x):
        d = csq[None, :] - 2.0 * chunk_x @ centroids.T
        return jnp.argmin(d, axis=1).astype(jnp.int32)

    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    out = jax.lax.map(one, xp.reshape(-1, chunk, x.shape[1]))
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, donate_argnums=(1,))
def _lloyd_step(x: jax.Array, centroids: jax.Array,
                key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    c = centroids.shape[0]
    a = assign(x, centroids)
    sums = jax.ops.segment_sum(x, a, num_segments=c)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), jnp.float32), a,
                                 num_segments=c)
    new = sums / jnp.maximum(counts, 1.0)[:, None]
    # Re-seed empty clusters from random points.
    rand_idx = jax.random.randint(key, (c,), 0, x.shape[0])
    new = jnp.where((counts > 0)[:, None], new, x[rand_idx])
    shift = jnp.sum((new - centroids) ** 2)
    return new, shift


def kmeans(x: np.ndarray, num_clusters: int, iters: int = 15,
           seed: int = 0, sample: int = 200_000) -> np.ndarray:
    """Fit centroids. Returns float32[num_clusters, D]."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    k_init, key = jax.random.split(key)
    train = x
    if n > sample:
        idx = jax.random.choice(k_init, n, (sample,), replace=False)
        train = x[idx]

    # kmeans++-lite seeding: d2-weighted sequential picks on a subsample.
    k_seed, key = jax.random.split(key)
    seed_pool = train[jax.random.choice(k_seed, train.shape[0],
                                        (min(train.shape[0], 20 * num_clusters),),
                                        replace=False)]
    cents = [seed_pool[0]]
    d2 = jnp.sum((seed_pool - cents[0]) ** 2, axis=1)
    for i in range(1, num_clusters):
        k_i = jax.random.fold_in(key, i)
        p = d2 / jnp.maximum(d2.sum(), 1e-9)
        pick = jax.random.choice(k_i, seed_pool.shape[0], p=p)
        cents.append(seed_pool[pick])
        d2 = jnp.minimum(d2, jnp.sum((seed_pool - cents[-1]) ** 2, axis=1))
    centroids = jnp.stack(cents)

    for i in range(iters):
        centroids, shift = _lloyd_step(train, centroids,
                                       jax.random.fold_in(key, 10_000 + i))
        if float(shift) < 1e-7:
            break
    return np.asarray(centroids)
