"""Model assembly: family-specific blocks stacked under lax.scan.

Families (DESIGN.md §5):
  dense / vlm-backbone / moe : pre-norm GQA attention + SwiGLU-or-MoE FFN
  ssm (rwkv6)                : time-mix + channel-mix
  hybrid (zamba2)            : Mamba2 backbone, one SHARED attention block
                               applied after every `attn_every` Mamba layers
  audio (whisper)            : enc-dec, sinusoidal positions, cross-attn

All stacks scan over a single block body with stacked params
(leading L axis) so the 512-device dry-run compiles one block regardless
of depth. `jax.checkpoint` wraps the body for training.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ArchConfig
from repro.models import layers, linear_attn, moe as moe_lib
from repro.utils.meshctx import constrain

Params = Dict[str, Any]


def attn_dims(cfg: ArchConfig) -> layers.AttnDims:
    return layers.AttnDims(num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads,
                           head_dim=cfg.resolved_head_dim)


def mamba_dims(cfg: ArchConfig) -> linear_attn.Mamba2Dims:
    return linear_attn.Mamba2Dims(
        d_model=cfg.d_model, d_inner=2 * cfg.d_model,
        num_heads=(2 * cfg.d_model) // 64, d_state=cfg.ssm_state)


def rwkv_dims(cfg: ArchConfig) -> linear_attn.RWKV6Dims:
    return linear_attn.RWKV6Dims(d_model=cfg.d_model,
                                 num_heads=cfg.num_heads, d_ff=cfg.d_ff)


def _norm(cfg: ArchConfig, p: Optional[Params], x: jax.Array) -> jax.Array:
    return layers.apply_norm(cfg.norm, x, p)


def _cast(p: Params, dtype) -> Params:
    """Cast block params to the compute dtype (weights stored f32/bf16;
    numerically-sensitive paths re-promote to f32 internally)."""
    return jax.tree.map(lambda a: a.astype(dtype), p)


# ---------------------------------------------------------------------------
# Attention-family block (dense / moe / vlm / whisper-decoder)
# ---------------------------------------------------------------------------

def attn_block(cfg: ArchConfig, p: Params, x: jax.Array, *,
               positions: Optional[jax.Array] = None,
               enc: Optional[jax.Array] = None, causal: bool = True,
               chunk: int = 512) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    p = _cast(p, x.dtype)
    use_rope = cfg.rope_theta > 0
    h = x + layers.gqa_attention(
        p["attn"], _norm(cfg, p.get("attn_norm"), x), attn_dims(cfg),
        positions=positions, causal=causal, rope_theta=cfg.rope_theta or 1e4,
        chunk=chunk, use_rope=use_rope)
    if enc is not None:
        h = h + layers.cross_attention(
            p["cross"], _norm(cfg, p.get("cross_norm"), h), enc,
            attn_dims(cfg), chunk=chunk)
    metrics: Dict[str, jax.Array] = {}
    hn = _norm(cfg, p.get("mlp_norm"), h)
    if cfg.num_experts:
        out, metrics = moe_lib.moe_ffn(
            p["moe"], hn, experts_per_token=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor)
        h = h + out
    else:
        h = h + layers.swiglu_mlp(p["mlp"], hn)
    return h, metrics


def attn_block_decode(cfg: ArchConfig, p: Params, x: jax.Array,
                      cache: Dict[str, jax.Array], pos: jax.Array, *,
                      enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    p = dict(p, **_cast({k: v for k, v in p.items() if k != "moe"}, x.dtype))
    use_rope = cfg.rope_theta > 0
    a, ck, cv = layers.gqa_decode(
        p["attn"], _norm(cfg, p.get("attn_norm"), x), cache["k"], cache["v"],
        pos, attn_dims(cfg), rope_theta=cfg.rope_theta or 1e4,
        use_rope=use_rope)
    h = x + a
    new_cache = dict(cache, k=ck, v=cv)
    if enc_kv is not None:
        # cross-attn with precomputed enc K/V (whisper decode)
        dims = attn_dims(cfg)
        b = x.shape[0]
        q = (_norm(cfg, p.get("cross_norm"), h) @ p["cross"]["wq"]).reshape(
            b, 1, dims.num_heads, dims.head_dim)
        kk = layers._repeat_kv(enc_kv[0], dims.num_heads // dims.num_kv_heads)
        vv = layers._repeat_kv(enc_kv[1], dims.num_heads // dims.num_kv_heads)
        o = layers.chunked_attention(q, kk, vv, causal=False)
        h = h + o.reshape(b, 1, dims.num_heads * dims.head_dim) @ p["cross"]["wo"]
    hn = _norm(cfg, p.get("mlp_norm"), h)
    if cfg.num_experts:
        out, _ = moe_lib.moe_ffn(p["moe"], hn,
                                 experts_per_token=cfg.experts_per_token,
                                 capacity_factor=cfg.capacity_factor)
        h = h + out
    else:
        h = h + layers.swiglu_mlp(p["mlp"], hn)
    return h, new_cache


# ---------------------------------------------------------------------------
# RWKV6 block
# ---------------------------------------------------------------------------

def rwkv_block(cfg: ArchConfig, p: Params, x: jax.Array, *,
               chunk: int = 64) -> jax.Array:
    p = _cast(p, x.dtype)
    dims = rwkv_dims(cfg)
    h = x + linear_attn.rwkv6_time_mix(
        p["time_mix"], _norm(cfg, p.get("attn_norm"), x), dims, chunk=chunk)
    h = h + linear_attn.rwkv6_channel_mix(
        p["channel_mix"], _norm(cfg, p.get("mlp_norm"), h))
    return h


def rwkv_block_decode(cfg: ArchConfig, p: Params, x: jax.Array,
                      cache: Dict[str, jax.Array]
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    p = _cast(p, x.dtype)
    dims = rwkv_dims(cfg)
    xn = _norm(cfg, p.get("attn_norm"), x)[:, 0]
    a, tm_state = linear_attn.rwkv6_time_mix_step(
        p["time_mix"], xn, {"shift": cache["att_shift"],
                            "wkv": cache["wkv"]}, dims)
    h = x + a[:, None, :]
    hn = _norm(cfg, p.get("mlp_norm"), h)[:, 0]
    c, cm_state = linear_attn.rwkv6_channel_mix_step(
        p["channel_mix"], hn, {"shift": cache["ffn_shift"]})
    h = h + c[:, None, :]
    return h, {"att_shift": tm_state["shift"], "wkv": tm_state["wkv"],
               "ffn_shift": cm_state["shift"]}


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 backbone)
# ---------------------------------------------------------------------------

def mamba_block(cfg: ArchConfig, p: Params, x: jax.Array, *,
                chunk: int = 64) -> jax.Array:
    p = _cast(p, x.dtype)
    h = x + linear_attn.mamba2_block(
        p["mamba"], _norm(cfg, p.get("attn_norm"), x), mamba_dims(cfg),
        chunk=chunk)
    return h


def mamba_block_decode(cfg: ArchConfig, p: Params, x: jax.Array,
                       cache: Dict[str, jax.Array]
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    p = _cast(p, x.dtype)
    out, st = linear_attn.mamba2_decode(
        p["mamba"], _norm(cfg, p.get("attn_norm"), x), cache, mamba_dims(cfg))
    return x + out, st


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _scan_blocks(body, x: jax.Array, stacked: Params, *,
                 remat: bool = False) -> Tuple[jax.Array, Any]:
    fn = jax.checkpoint(body) if remat else body
    return jax.lax.scan(fn, x, stacked)


def dense_stack(cfg: ArchConfig, blocks: Params, x: jax.Array, *,
                causal: bool = True, remat: bool = False,
                chunk: int = 512) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Attention-family stack (dense/moe/vlm). blocks: stacked params."""
    def body(h, p):
        h, m = attn_block(cfg, p, h, causal=causal, chunk=chunk)
        return constrain(h, "dp", "sp", None), m
    x, ms = _scan_blocks(body, x, blocks, remat=remat)
    metrics = {k: v.mean() for k, v in ms.items()} if ms else {}
    return x, metrics


def rwkv_stack(cfg: ArchConfig, blocks: Params, x: jax.Array, *,
               remat: bool = False, chunk: int = 64) -> jax.Array:
    def body(h, p):
        return constrain(rwkv_block(cfg, p, h, chunk=chunk),
                         "dp", None, None), None
    x, _ = _scan_blocks(body, x, blocks, remat=remat)
    return x


def zamba_stack(cfg: ArchConfig, params: Params, x: jax.Array, *,
                remat: bool = False, chunk: int = 64,
                attn_chunk: int = 512) -> jax.Array:
    """Mamba2 backbone with one shared attention block every attn_every
    layers. Layout: groups of (attn_every mamba + shared attn), then a tail
    of leftover mamba layers."""
    g = cfg.attn_every
    n_groups = cfg.num_layers // g
    shared = params["shared_attn"]

    def group_body(h, group_params):
        def mamba_body(hh, p):
            return constrain(mamba_block(cfg, p, hh, chunk=chunk),
                             "dp", None, None), None
        h, _ = jax.lax.scan(mamba_body, h, group_params)
        h, _ = attn_block(cfg, shared, h, causal=True, chunk=attn_chunk)
        return constrain(h, "dp", None, None), None

    fn = jax.checkpoint(group_body) if remat else group_body
    x, _ = jax.lax.scan(fn, x, params["groups"])  # [G, g, ...]
    if "tail" in params and params["tail"]:
        def tail_body(h, p):
            return constrain(mamba_block(cfg, p, h, chunk=chunk),
                             "dp", None, None), None
        tb = jax.checkpoint(tail_body) if remat else tail_body
        x, _ = jax.lax.scan(tb, x, params["tail"])
    return x
