"""Chunked linear-attention machinery: Mamba2 (SSD) and RWKV-6 blocks.

Both architectures are instances of one recurrence
    S_t = Diag(w_t) S_{t-1} + k_t^T v_t,     y_t = q_t S_t (+ diag terms)
with different decay shapes (Mamba2: scalar per head; RWKV-6:
data-dependent per key channel). Training/prefill uses the chunkwise
parallel form (intra-chunk attention matrix + inter-chunk state carry, the
standard GLA/SSD scheme) — O(T * chunk) memory, scan over chunks, MXU
matmuls inside. Decode is the O(1) recurrent step on a [dk, dv] state.

These give the sub-quadratic path required for the `long_500k` shape
(rwkv6-3b, zamba2-1.2b).

Simplifications vs the reference CUDA implementations are noted in
DESIGN.md §7 (single B/C group for Mamba2; static token-shift +
low-rank data-dependent decay for RWKV-6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.utils.meshctx import constrain

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Core chunked recurrence
# ---------------------------------------------------------------------------

def chunked_linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             log_w: jax.Array, *,
                             u: Optional[jax.Array] = None,
                             s0: Optional[jax.Array] = None,
                             chunk: int = 64
                             ) -> Tuple[jax.Array, jax.Array]:
    """Chunkwise parallel linear attention.

    q, k:   f32[B, T, H, dk]
    v:      f32[B, T, H, dv]
    log_w:  f32[B, T, H, dk] log decay (<= 0), applied to the key dim
    u:      optional f32[H, dk] RWKV "bonus" for the current token; if
            given, the recurrence reads S_{t-1} (strict causality) and adds
            (q_t . (u*k_t)) v_t; otherwise reads S_t (inclusive, Mamba).
    s0:     optional initial state f32[B, H, dk, dv]
    Returns (y f32[B, T, H, dv], final state f32[B, H, dk, dv]).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, t)
    assert t % c == 0, (t, c)
    n = t // c

    strict = u is not None
    mask = (np.tril(np.ones((c, c)), k=-1 if strict else 0) > 0)
    mask = jnp.asarray(mask)

    # Memory discipline (EXPERIMENTS iteration 5): inputs are sliced per
    # chunk from the [B, T, H, *] layout (no materialized [n, B, H, c, *]
    # f32 copies — those alone were 4 x T x d_inner f32 per layer) and the
    # body is rematerialized, so the backward saves only the per-chunk
    # carried state instead of every intra-chunk intermediate.
    def body(s, j):
        def sl(a, width):
            return jax.lax.dynamic_slice_in_dim(a, j * c, c, axis=1)
        qi = sl(q, dk).astype(jnp.float32).transpose(0, 2, 1, 3)  # [B,H,c,dk]
        ki = sl(k, dk).astype(jnp.float32).transpose(0, 2, 1, 3)
        vi = sl(v, dv).astype(jnp.float32).transpose(0, 2, 1, 3)
        wi = sl(log_w, dk).astype(jnp.float32).transpose(0, 2, 1, 3)
        logp = jnp.cumsum(wi, axis=2)               # inclusive cumulative
        p_end = logp[:, :, -1:, :]                  # [B,H,1,dk]
        # query-side decay: inclusive (mamba) or exclusive (rwkv strict)
        q_dec = logp - wi if strict else logp
        qt = qi * jnp.exp(q_dec)
        kt = ki * jnp.exp(-logp)
        a = jnp.einsum("bhqd,bhkd->bhqk", qt, kt)
        a = jnp.where(mask[None, None], a, 0.0)
        y = jnp.einsum("bhqk,bhkv->bhqv", a, vi)
        y = y + jnp.einsum("bhqd,bhdv->bhqv", qt, s)
        if strict:
            diag = jnp.einsum("bhtd,bhtd->bht", qi, ki * u[None, :, None, :])
            y = y + diag[..., None] * vi
        k_for_state = ki * jnp.exp(p_end - logp)
        s_new = s * jnp.exp(p_end).transpose(0, 1, 3, 2) + jnp.einsum(
            "bhtd,bhtv->bhdv", k_for_state, vi)
        return s_new, y.transpose(0, 2, 1, 3)        # y: [B, c, H, dv]

    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    s_fin, ys = jax.lax.scan(jax.checkpoint(body), s0, jnp.arange(n))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, dv)
    return y, s_fin


def linear_attention_step(q: jax.Array, k: jax.Array, v: jax.Array,
                          log_w: jax.Array, s: jax.Array, *,
                          u: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """O(1) decode step. q/k/log_w: [B, H, dk]; v: [B, H, dv];
    s: [B, H, dk, dv]. Returns (y [B, H, dv], new state)."""
    kv = jnp.einsum("bhd,bhv->bhdv", k, v)
    if u is not None:
        read = s + u[None, :, :, None] * kv
    else:
        read = s * jnp.exp(log_w)[..., None] + kv
    y = jnp.einsum("bhd,bhdv->bhv", q, read)
    s_new = s * jnp.exp(log_w)[..., None] + kv
    return y, s_new


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Mamba2Dims:
    d_model: int
    d_inner: int
    num_heads: int
    d_state: int
    conv_width: int = 4

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.num_heads


def mamba2_params_shape(dims: Mamba2Dims):
    d, di, hs, dk = dims.d_model, dims.d_inner, dims.num_heads, dims.d_state
    return {
        "in_proj": (d, 2 * di + 2 * dk + hs),   # z, x, B, C, dt
        "conv_w": (dims.conv_width, di + 2 * dk),
        "dt_bias": (hs,),
        "a_log": (hs,),
        "d_skip": (hs,),
        "norm_scale": (di,),
        "out_proj": (di, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, T, C], w: [W, C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out


def mamba2_block(params: Params, x: jax.Array, dims: Mamba2Dims, *,
                 chunk: int = 64) -> jax.Array:
    """Full-sequence Mamba2 mixer. x: [B, T, d] -> [B, T, d]."""
    b, t, _ = x.shape
    di, hs, dk = dims.d_inner, dims.num_heads, dims.d_state
    hd = dims.head_dim
    proj = x @ constrain(params["in_proj"], None, None)
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + dk, 2 * di + 2 * dk], axis=-1)
    xbc = _causal_conv(jnp.concatenate([xin, bmat, cmat], -1),
                       params["conv_w"])
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [di, di + dk], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,T,H]
    log_w = (-jnp.exp(params["a_log"].astype(jnp.float32)) * dt)      # [B,T,H]
    v = (xin.reshape(b, t, hs, hd).astype(jnp.float32)
         * dt[..., None]).astype(x.dtype)                # B*dt*x scaling
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, t, hs, dk))
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, t, hs, dk))
    lw = jnp.broadcast_to(log_w[..., None], (b, t, hs, dk))

    y, _ = chunked_linear_attention(q, k, v, lw, chunk=chunk)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * \
        xin.reshape(b, t, hs, hd).astype(jnp.float32)
    y = y.reshape(b, t, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rmsnorm(y, params["norm_scale"])
    return (y @ constrain(params["out_proj"], None, None)).astype(x.dtype)


def mamba2_decode(params: Params, x: jax.Array, state: Dict[str, jax.Array],
                  dims: Mamba2Dims
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: [B, 1, d]; state: {"ssm": [B,H,dk,hd],
    "conv": [B, W-1, di+2dk]}."""
    b = x.shape[0]
    di, hs, dk = dims.d_inner, dims.num_heads, dims.d_state
    hd = dims.head_dim
    proj = x[:, 0] @ params["in_proj"]
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + dk, 2 * di + 2 * dk], axis=-1)
    xbc_in = jnp.concatenate([xin, bmat, cmat], -1)          # [B, C]
    conv_buf = jnp.concatenate([state["conv"], xbc_in[:, None, :]], axis=1)
    w = params["conv_w"]
    xbc = sum(conv_buf[:, i, :] * w[i][None, :] for i in range(w.shape[0]))
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [di, di + dk], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    log_w = (-jnp.exp(params["a_log"].astype(jnp.float32)) * dt)
    v = xin.reshape(b, hs, hd).astype(jnp.float32) * dt[..., None]
    q = jnp.broadcast_to(cmat[:, None, :], (b, hs, dk)).astype(jnp.float32)
    k = jnp.broadcast_to(bmat[:, None, :], (b, hs, dk)).astype(jnp.float32)
    lw = jnp.broadcast_to(log_w[..., None], (b, hs, dk))
    y, s_new = linear_attention_step(q, k, v, lw, state["ssm"])
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * \
        xin.reshape(b, hs, hd).astype(jnp.float32)
    y = y.reshape(b, di) * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rmsnorm(y, params["norm_scale"])
    out = (y @ params["out_proj"]).astype(x.dtype)[:, None, :]
    return out, {"ssm": s_new, "conv": conv_buf[:, 1:, :]}


# ---------------------------------------------------------------------------
# RWKV-6 block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RWKV6Dims:
    d_model: int
    num_heads: int
    d_ff: int
    decay_rank: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


def rwkv6_params_shape(dims: RWKV6Dims):
    d, r = dims.d_model, dims.decay_rank
    return {
        # time-mix
        "mu_r": (d,), "mu_k": (d,), "mu_v": (d,), "mu_w": (d,), "mu_g": (d,),
        "wr": (d, d), "wk": (d, d), "wv": (d, d), "wg": (d, d),
        "w0": (d,), "w_lora_a": (d, r), "w_lora_b": (r, d),
        "bonus_u": (dims.num_heads, dims.head_dim),
        "ln_x_scale": (d,),
        "wo": (d, d),
        # channel-mix
        "mu_ck": (d,), "mu_cr": (d,),
        "ck": (d, dims.d_ff), "cv": (dims.d_ff, d), "cr": (d, d),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """x_{t-1} (zeros / supplied carry for t=0). x: [B, T, d]."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddecay(params: Params, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel log decay (low-rank, <= 0)."""
    lora = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    return -jnp.exp(params["w0"].astype(jnp.float32) + lora.astype(jnp.float32))


def rwkv6_time_mix(params: Params, x: jax.Array, dims: RWKV6Dims, *,
                   chunk: int = 64) -> jax.Array:
    b, t, d = x.shape
    h, hd = dims.num_heads, dims.head_dim
    xs = _token_shift(x)

    def mix(mu):
        return x + (xs - x) * mu[None, None, :]

    r = (mix(params["mu_r"]) @ constrain(params["wr"], None, "tp")
         ).reshape(b, t, h, hd)
    k = (mix(params["mu_k"]) @ constrain(params["wk"], None, "tp")
         ).reshape(b, t, h, hd)
    v = (mix(params["mu_v"]) @ constrain(params["wv"], None, "tp")
         ).reshape(b, t, h, hd)
    g = jax.nn.silu(mix(params["mu_g"]) @ constrain(params["wg"], None, "tp"))
    log_w = _ddecay(params, mix(params["mu_w"])).reshape(b, t, h, hd)

    y, _ = chunked_linear_attention(
        r, k, v, log_w, u=params["bonus_u"].astype(jnp.float32), chunk=chunk)
    y = y.reshape(b, t, d)
    y = layers.rmsnorm(y, params["ln_x_scale"])
    return ((y * g) @ constrain(params["wo"], "tp", None)).astype(x.dtype)


def rwkv6_channel_mix(params: Params, x: jax.Array) -> jax.Array:
    xs = _token_shift(x)
    xk = x + (xs - x) * params["mu_ck"][None, None, :]
    xr = x + (xs - x) * params["mu_cr"][None, None, :]
    kk = jnp.square(jax.nn.relu(xk @ constrain(params["ck"], None, "tp")))
    return (jax.nn.sigmoid(xr @ constrain(params["cr"], None, "tp"))
            * (kk @ constrain(params["cv"], "tp", None))).astype(x.dtype)


def rwkv6_time_mix_step(params: Params, x: jax.Array,
                        state: Dict[str, jax.Array], dims: RWKV6Dims
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode step. x: [B, d]; state: {"shift": [B, d], "wkv": [B,H,hd,hd]}."""
    b, d = x.shape
    h, hd = dims.num_heads, dims.head_dim
    xs = state["shift"]

    def mix(mu):
        return x + (xs - x) * mu[None, :]

    r = (mix(params["mu_r"]) @ params["wr"]).reshape(b, h, hd)
    k = (mix(params["mu_k"]) @ params["wk"]).reshape(b, h, hd)
    v = (mix(params["mu_v"]) @ params["wv"]).reshape(b, h, hd)
    g = jax.nn.silu(mix(params["mu_g"]) @ params["wg"])
    log_w = _ddecay(params, mix(params["mu_w"])).reshape(b, h, hd)
    y, s_new = linear_attention_step(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_w, state["wkv"], u=params["bonus_u"].astype(jnp.float32))
    y = layers.rmsnorm(y.reshape(b, d), params["ln_x_scale"])
    out = ((y * g) @ params["wo"]).astype(x.dtype)
    return out, {"shift": x, "wkv": s_new}


def rwkv6_channel_mix_step(params: Params, x: jax.Array,
                           state: Dict[str, jax.Array]
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    xs = state["shift"]
    xk = x + (xs - x) * params["mu_ck"][None, :]
    xr = x + (xs - x) * params["mu_cr"][None, :]
    kk = jnp.square(jax.nn.relu(xk @ params["ck"]))
    out = (jax.nn.sigmoid(xr @ params["cr"]) * (kk @ params["cv"])
           ).astype(x.dtype)
    return out, {"shift": x}
