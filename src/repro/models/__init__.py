"""LM substrate for the 10 assigned architectures."""
from repro.models import layers, linear_attn, model_zoo, moe, transformer

__all__ = ["layers", "linear_attn", "model_zoo", "moe", "transformer"]
