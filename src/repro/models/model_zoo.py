"""Model zoo: ArchConfig -> parameter trees, init, and the three lowered
entry points (train_step loss fwd, prefill, decode) for every assigned
family. All block params are stacked on a leading layer axis for lax.scan.

Param dtype: bf16 storage for giant MoE (kimi) per DESIGN.md §7, f32
otherwise; compute casts to bf16 inside blocks where MXU-bound.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers, linear_attn, moe as moe_lib, transformer
from repro.utils.meshctx import constrain

Params = Dict[str, Any]
PyTree = Any


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

def _norm_shape(cfg: ArchConfig):
    return None if cfg.norm == "nonparam_ln" else {"scale": (cfg.d_model,)}


def _attn_block_shapes(cfg: ArchConfig, cross: bool = False):
    d = cfg.d_model
    s: Dict[str, Any] = {}
    if _norm_shape(cfg):
        s["attn_norm"] = _norm_shape(cfg)
        s["mlp_norm"] = _norm_shape(cfg)
    s["attn"] = layers.attn_params_shape(d, transformer.attn_dims(cfg))
    if cross:
        if _norm_shape(cfg):
            s["cross_norm"] = _norm_shape(cfg)
        s["cross"] = layers.attn_params_shape(d, transformer.attn_dims(cfg))
    if cfg.num_experts:
        s["moe"] = moe_lib.moe_params_shape(d, cfg.moe_d_ff or cfg.d_ff,
                                            cfg.num_experts)
    else:
        s["mlp"] = layers.mlp_params_shape(d, cfg.d_ff, cfg.mlp)
    return s


def _rwkv_block_shapes(cfg: ArchConfig):
    dims = transformer.rwkv_dims(cfg)
    d, r = cfg.d_model, dims.decay_rank
    tm = {
        "mu_r": (d,), "mu_k": (d,), "mu_v": (d,), "mu_w": (d,), "mu_g": (d,),
        "wr": (d, d), "wk": (d, d), "wv": (d, d), "wg": (d, d),
        "w0": (d,), "w_lora_a": (d, r), "w_lora_b": (r, d),
        "bonus_u": (dims.num_heads, dims.head_dim),
        "ln_x_scale": (d,),
        "wo": (d, d),
    }
    cm = {"mu_ck": (d,), "mu_cr": (d,),
          "ck": (d, cfg.d_ff), "cv": (cfg.d_ff, d), "cr": (d, d)}
    return {"attn_norm": _norm_shape(cfg), "mlp_norm": _norm_shape(cfg),
            "time_mix": tm, "channel_mix": cm}


def _mamba_block_shapes(cfg: ArchConfig):
    dims = transformer.mamba_dims(cfg)
    return {"attn_norm": _norm_shape(cfg),
            "mamba": linear_attn.mamba2_params_shape(dims)}


def _stack(shapes: PyTree, n: int) -> PyTree:
    return jax.tree.map(lambda s: (n,) + s, shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_shapes(cfg: ArchConfig) -> PyTree:
    """Nested dict of shape tuples for the full model."""
    d, v = cfg.d_model, cfg.vocab_size
    tree: Dict[str, Any] = {"embed": (v, d)}
    if not cfg.tie_embeddings:
        tree["out_head"] = (v, d)
    if _norm_shape(cfg):
        tree["final_norm"] = _norm_shape(cfg)

    if cfg.family in ("dense", "moe", "vlm"):
        tree["blocks"] = _stack(_attn_block_shapes(cfg), cfg.num_layers)
        if cfg.family == "vlm":
            tree["connector"] = (cfg.frontend_dim, d)
    elif cfg.family == "ssm":
        tree["blocks"] = _stack(_rwkv_block_shapes(cfg), cfg.num_layers)
    elif cfg.family == "hybrid":
        g = cfg.attn_every
        n_groups = cfg.num_layers // g
        tail = cfg.num_layers - n_groups * g
        tree["groups"] = _stack(_stack(_mamba_block_shapes(cfg), g), n_groups)
        if tail:
            tree["tail"] = _stack(_mamba_block_shapes(cfg), tail)
        tree["shared_attn"] = _attn_block_shapes(cfg)
    elif cfg.family == "audio":
        tree["blocks"] = _stack(_attn_block_shapes(cfg, cross=True),
                                cfg.num_layers)
        tree["encoder"] = {
            "blocks": _stack(_attn_block_shapes(cfg), cfg.encoder_layers),
            "final_norm": _norm_shape(cfg),
            "in_proj": (cfg.frontend_dim, d),
        }
    else:
        raise ValueError(cfg.family)
    return _prune_none(tree)


def _prune_none(t):
    if isinstance(t, dict):
        return {k: _prune_none(v) for k, v in t.items() if v is not None}
    return t


def param_dtype(cfg: ArchConfig) -> jnp.dtype:
    return jnp.bfloat16 if cfg.name.startswith("kimi") else jnp.float32


def abstract_params(cfg: ArchConfig) -> PyTree:
    dt = param_dtype(cfg)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, dt),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


_SPECIAL_INIT = {
    "a_log": lambda s, k: jnp.zeros(s, jnp.float32),
    "dt_bias": lambda s, k: jnp.full(s, -2.0, jnp.float32),
    "d_skip": lambda s, k: jnp.ones(s, jnp.float32),
    "w0": lambda s, k: jnp.zeros(s, jnp.float32),
    "bonus_u": lambda s, k: jnp.full(s, 0.5, jnp.float32),
    "scale": lambda s, k: jnp.ones(s, jnp.float32),
    "ln_x_scale": lambda s, k: jnp.ones(s, jnp.float32),
    "norm_scale": lambda s, k: jnp.ones(s, jnp.float32),
}


def init_params(cfg: ArchConfig, key: jax.Array,
                init_scale: float = 0.02) -> PyTree:
    """Materialize parameters (smoke tests / examples; the dry-run never
    allocates)."""
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    dt = param_dtype(cfg)
    out = []
    for i, (path, shape) in enumerate(leaves):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in _SPECIAL_INIT:
            arr = _SPECIAL_INIT[name](shape, None).astype(dt)
        elif name.startswith("mu_"):
            arr = jnp.full(shape, 0.5, dt)
        else:
            sub = jax.random.fold_in(key, i)
            arr = (jax.random.normal(sub, shape, jnp.float32)
                   * init_scale).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Loss / forward
# ---------------------------------------------------------------------------

def _out_table(cfg: ArchConfig, params: Params) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["out_head"]


def chunked_ce_loss(x: jax.Array, table: jax.Array, labels: jax.Array,
                    weights: Optional[jax.Array] = None,
                    chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing [B, S, V]: scan over seq chunks.
    x: [B,S,d], table: [V,d], labels: i32[B,S], weights: f32[B,S] or None."""
    b, s, d = x.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        w = jnp.ones((b, s), jnp.float32) if weights is None else weights
        weights = jnp.pad(w, ((0, 0), (0, pad)))
    n = (s + pad) // c
    xc = x.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, c).transpose(1, 0, 2)
    if weights is None:
        weights = jnp.ones((b, s), jnp.float32)
    wc = weights.reshape(b, n, c).transpose(1, 0, 2)

    table_c = table.astype(x.dtype)  # one cast, hoisted out of the scan

    def body(acc, inp):
        xi, li, wi = inp
        logits = constrain(
            jnp.einsum("bcd,vd->bcv", xi, table_c,
                       preferred_element_type=jnp.float32), "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * wi
        return (acc[0] + nll.sum(), acc[1] + wi.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xc, lc, wc))
    return tot / jnp.maximum(cnt, 1.0)


def _embed_inputs(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, Optional[jax.Array],
                             Optional[jax.Array]]:
    """Returns (x [B,S,d], loss_weights or None, encoder_out or None)."""
    compute = jnp.bfloat16
    tokens = batch["tokens"]
    x = constrain(layers.embed(tokens, params["embed"]).astype(compute),
                  "dp", "sp", None)
    weights = None
    enc = None
    if cfg.family == "vlm":
        patches = batch["patches"].astype(compute)  # [B, P, Dv]
        proj = (patches @ params["connector"].astype(compute))
        p = proj.shape[1]
        x = jnp.concatenate([proj, x[:, : x.shape[1] - p]], axis=1)
        weights = jnp.concatenate(
            [jnp.zeros((x.shape[0], p), jnp.float32),
             jnp.ones((x.shape[0], x.shape[1] - p), jnp.float32)], axis=1)
    elif cfg.family == "audio":
        enc = encode_audio(cfg, params, batch["frames"])
    return x, weights, enc


def _sinusoidal(s: int, d: int) -> np.ndarray:
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def encode_audio(cfg: ArchConfig, params: Params,
                 frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings [B, F, Df]."""
    compute = jnp.bfloat16
    enc_p = params["encoder"]
    x = (frames.astype(compute) @ enc_p["in_proj"].astype(compute))
    x = x + jnp.asarray(_sinusoidal(x.shape[1], cfg.d_model)).astype(compute)

    def body(h, p):
        h, _ = transformer.attn_block(cfg, p, h, causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, enc_p["blocks"])
    return layers.apply_norm(cfg.norm, x, enc_p.get("final_norm"))


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], *,
            remat: bool = True, chunk: int = 512
            ) -> Tuple[jax.Array, Optional[jax.Array], Dict[str, jax.Array]]:
    """Full causal forward -> (hidden [B,S,d], loss weights, metrics)."""
    x, weights, enc = _embed_inputs(cfg, params, batch)
    metrics: Dict[str, jax.Array] = {}
    if cfg.family in ("dense", "moe", "vlm"):
        x, metrics = transformer.dense_stack(cfg, params["blocks"], x,
                                             causal=True, remat=remat,
                                             chunk=chunk)
    elif cfg.family == "ssm":
        if cfg.rope_theta == 0:
            x = x + jnp.asarray(_sinusoidal(x.shape[1], cfg.d_model)
                                ).astype(x.dtype)
        x = transformer.rwkv_stack(cfg, params["blocks"], x, remat=remat)
    elif cfg.family == "hybrid":
        x = transformer.zamba_stack(cfg, params, x, remat=remat,
                                    attn_chunk=chunk)
    elif cfg.family == "audio":
        x = x + jnp.asarray(_sinusoidal(x.shape[1], cfg.d_model)
                            ).astype(x.dtype)

        def body(h, p):
            h, _ = transformer.attn_block(cfg, p, h, enc=enc, causal=True,
                                          chunk=chunk)
            return h, None
        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["blocks"])
    else:
        raise ValueError(cfg.family)
    x = layers.apply_norm(cfg.norm, x, params.get("final_norm"))
    return x, weights, metrics


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], *,
            remat: bool = True, chunk: int = 512
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, weights, metrics = forward(cfg, params, batch, remat=remat,
                                  chunk=chunk)
    loss = chunked_ce_loss(x, _out_table(cfg, params), batch["labels"],
                           weights)
    if "moe_aux_loss" in metrics:
        loss = loss + 0.01 * metrics["moe_aux_loss"]
    metrics["ce_loss"] = loss
    return loss, metrics


def prefill(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], *,
            chunk: int = 512) -> jax.Array:
    """Prefill forward; returns last-position logits [B, V]."""
    x, _, _ = forward(cfg, params, batch, remat=False, chunk=chunk)
    last = x[:, -1, :]
    return jnp.einsum("bd,vd->bv", last.astype(jnp.float32),
                      _out_table(cfg, params).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def make_cache(cfg: ArchConfig, batch: int, max_seq: int,
               abstract: bool = False) -> PyTree:
    """Cache pytree (zeros or ShapeDtypeStruct)."""
    dims = transformer.attn_dims(cfg)
    dt = jnp.bfloat16

    def mk(shape, dtype=dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    l = cfg.num_layers
    if cfg.family in ("dense", "moe", "vlm"):
        kv = (l, batch, max_seq, dims.num_kv_heads, dims.head_dim)
        return {"k": mk(kv), "v": mk(kv)}
    if cfg.family == "audio":
        kv = (l, batch, max_seq, dims.num_kv_heads, dims.head_dim)
        ckv = (l, batch, cfg.frontend_len, dims.num_kv_heads, dims.head_dim)
        return {"k": mk(kv), "v": mk(kv), "ck": mk(ckv), "cv": mk(ckv)}
    if cfg.family == "ssm":
        rd = transformer.rwkv_dims(cfg)
        return {
            "att_shift": mk((l, batch, cfg.d_model), jnp.float32),
            "ffn_shift": mk((l, batch, cfg.d_model), jnp.float32),
            "wkv": mk((l, batch, rd.num_heads, rd.head_dim, rd.head_dim),
                      jnp.float32),
        }
    if cfg.family == "hybrid":
        md = transformer.mamba_dims(cfg)
        g = cfg.attn_every
        n_groups = cfg.num_layers // g
        tail = cfg.num_layers - n_groups * g
        conv_c = md.d_inner + 2 * md.d_state
        cache = {
            "groups": {
                "ssm": mk((n_groups, g, batch, md.num_heads, md.d_state,
                           md.head_dim), jnp.float32),
                "conv": mk((n_groups, g, batch, md.conv_width - 1, conv_c),
                           jnp.float32),
            },
            "shared_k": mk((n_groups, batch, max_seq, dims.num_kv_heads,
                            dims.head_dim)),
            "shared_v": mk((n_groups, batch, max_seq, dims.num_kv_heads,
                            dims.head_dim)),
        }
        if tail:
            cache["tail"] = {
                "ssm": mk((tail, batch, md.num_heads, md.d_state,
                           md.head_dim), jnp.float32),
                "conv": mk((tail, batch, md.conv_width - 1, conv_c),
                           jnp.float32),
            }
        return cache
    raise ValueError(cfg.family)


def decode_step(cfg: ArchConfig, params: Params, cache: PyTree,
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, PyTree]:
    """One-token serve step. tokens: i32[B, 1]; pos: i32[] current length.
    Returns (logits [B, V], new cache)."""
    compute = jnp.bfloat16
    x = layers.embed(tokens, params["embed"]).astype(compute)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, inp):
            p, ck, cv = inp
            h, nc = transformer.attn_block_decode(cfg, p, h,
                                                  {"k": ck, "v": cv}, pos)
            return h, (nc["k"], nc["v"])
        x, (nk, nv) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}
    elif cfg.family == "audio":
        x = x + jnp.asarray(_sinusoidal(1, cfg.d_model)).astype(x.dtype)

        def body(h, inp):
            p, ck, cv, cck, ccv = inp
            h, nc = transformer.attn_block_decode(
                cfg, p, h, {"k": ck, "v": cv}, pos, enc_kv=(cck, ccv))
            return h, (nc["k"], nc["v"])
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        new_cache = dict(cache, k=nk, v=nv)
    elif cfg.family == "ssm":
        def body(h, inp):
            p, sa, sf, wkv = inp
            h, nc = transformer.rwkv_block_decode(
                cfg, p, h, {"att_shift": sa, "ffn_shift": sf, "wkv": wkv})
            return h, (nc["att_shift"], nc["ffn_shift"], nc["wkv"])
        x, (na, nf, nw) = jax.lax.scan(
            body, x, (params["blocks"], cache["att_shift"],
                      cache["ffn_shift"], cache["wkv"]))
        new_cache = {"att_shift": na, "ffn_shift": nf, "wkv": nw}
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, inp):
            gp, ssm, conv, sk, sv = inp

            def mamba_body(hh, binp):
                p, s1, c1 = binp
                hh, st = transformer.mamba_block_decode(
                    cfg, p, hh, {"ssm": s1, "conv": c1})
                return hh, (st["ssm"], st["conv"])
            h, (ns, ncv) = jax.lax.scan(mamba_body, h, (gp, ssm, conv))
            h, nc = transformer.attn_block_decode(cfg, shared, h,
                                                  {"k": sk, "v": sv}, pos)
            return h, (ns, ncv, nc["k"], nc["v"])

        x, (ns, ncv, nsk, nsv) = jax.lax.scan(
            group_body, x, (params["groups"], cache["groups"]["ssm"],
                            cache["groups"]["conv"], cache["shared_k"],
                            cache["shared_v"]))
        new_cache = {"groups": {"ssm": ns, "conv": ncv},
                     "shared_k": nsk, "shared_v": nsv}
        if "tail" in params:
            def tail_body(h, binp):
                p, s1, c1 = binp
                h, st = transformer.mamba_block_decode(
                    cfg, p, h, {"ssm": s1, "conv": c1})
                return h, (st["ssm"], st["conv"])
            x, (ts, tc) = jax.lax.scan(
                tail_body, x, (params["tail"], cache["tail"]["ssm"],
                               cache["tail"]["conv"]))
            new_cache["tail"] = {"ssm": ts, "conv": tc}
    else:
        raise ValueError(cfg.family)

    x = layers.apply_norm(cfg.norm, x, params.get("final_norm"))
    logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(jnp.float32),
                        _out_table(cfg, params).astype(jnp.float32))
    return logits, new_cache


# ---------------------------------------------------------------------------
# input_specs (dry-run stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, seq_len: int, global_batch: int,
                kind: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    b, s = global_batch, seq_len
    i32 = jnp.int32

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    if kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": tok((b, s))}
        if kind == "train":
            batch["labels"] = tok((b, s))
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        return {"batch": batch}
    if kind == "decode":
        return {
            "tokens": tok((b, 1)),
            "cache": make_cache(cfg, b, s, abstract=True),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(kind)
