"""Core transformer layers: norms, RoPE, GQA attention (chunked flash-style
prefill + KV-cache decode), SwiGLU MLP, cross-attention.

Everything is shape-polymorphic pure functions over param dicts so the
model zoo can stack them under `lax.scan` (one compiled block body
regardless of depth — required to keep the 512-device dry-run compile
tractable, DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.meshctx import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def nonparam_layernorm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, x: jax.Array, params: Optional[Params]) -> jax.Array:
    if kind == "nonparam_ln":
        return nonparam_layernorm(x)
    return rmsnorm(x, params["scale"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x: [B, S, H, Dh], positions: [B, S] or [S]."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta), jnp.float32)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    num_heads: int
    num_kv_heads: int
    head_dim: int


def attn_params_shape(d_model: int, dims: AttnDims):
    h, kv, dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    return {
        "wq": (d_model, h * dh),
        "wk": (d_model, kv * dh),
        "wv": (d_model, kv * dh),
        "wo": (h * dh, d_model),
    }


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """[B, S, Hkv, Dh] -> [B, S, Hkv*groups, Dh]."""
    if groups == 1:
        return k
    b, s, hkv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, groups, dh)
                            ).reshape(b, s, hkv * groups, dh)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, q_offset: int = 0,
                      chunk: int = 512,
                      kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Flash-style attention in pure JAX: scan over KV chunks with running
    (max, denom, acc). Memory O(S*chunk) instead of O(S^2).

    Differentiable path: when kv_valid_len is None (train/prefill) this
    dispatches to `flash_attention`, a custom_vjp whose backward recomputes
    the probability tiles per chunk instead of saving them — without it the
    scan stores [nkv, B, H, Sq, ckv] f32 residuals (16 GB/device/layer on
    train_4k; see EXPERIMENTS.md §Perf).

    q: [B, Sq, H, Dh]; k/v: [B, Skv, H, Dh] (kv heads already repeated).
    q_offset: absolute position of q[0] (for causal masking in decode).
    kv_valid_len: optional [B] valid kv prefix length (cache decode).
    """
    if kv_valid_len is None:
        return flash_attention(q, k, v, causal, q_offset, chunk)
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = np.float32(1.0 / np.sqrt(dh))
    # Keep q/k/v in storage dtype (bf16 on the MXU); f32 accumulation via
    # preferred_element_type — no materialized f32 copies of K/V.
    qs = q * jnp.asarray(scale, q.dtype)

    ckv = min(chunk, skv)
    pad = (-skv) % ckv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    nkv = (skv + pad) // ckv

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, j):
        m, l, acc = carry
        kcj = jax.lax.dynamic_slice_in_dim(kp, j * ckv, ckv, axis=1)
        vcj = jax.lax.dynamic_slice_in_dim(vp, j * ckv, ckv, axis=1)
        kv_pos = j * ckv + jnp.arange(ckv)
        s_ij = jnp.einsum("bqhd,bkhd->bhqk", qs, kcj,
                          preferred_element_type=jnp.float32)
        mask = kv_pos[None, :] > q_pos[:, None] if causal else \
            jnp.zeros((sq, ckv), bool)
        invalid = kv_pos >= skv
        if kv_valid_len is not None:
            invalid = invalid[None, :] | (kv_pos[None, :]
                                          >= kv_valid_len[:, None])
            mask = mask[None, None] | invalid[:, None, None, :]
        else:
            mask = (mask | invalid[None, :])[None, None]
        s_ij = jnp.where(mask, -jnp.inf, s_ij)
        m_new = jnp.maximum(m, s_ij.max(axis=-1))
        # Guard fully-masked rows (m_new = -inf).
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s_ij - m_safe[..., None])
        p = jnp.where(mask, 0.0, p)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vcj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nkv))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# --- flash attention with memory-lean custom VJP -------------------------

def _flash_fwd_core(q, k, v, causal: bool, q_offset: int, chunk: int):
    """Returns (out [B,Sq,H,Dh], lse [B,H,Sq] f32)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = np.float32(1.0 / np.sqrt(dh))
    qs = q * jnp.asarray(scale, q.dtype)
    ckv = min(chunk, skv)
    pad = (-skv) % ckv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    nkv = (skv + pad) // ckv
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, j):
        m, l, acc = carry
        kcj = jax.lax.dynamic_slice_in_dim(kp, j * ckv, ckv, axis=1)
        vcj = jax.lax.dynamic_slice_in_dim(vp, j * ckv, ckv, axis=1)
        kv_pos = j * ckv + jnp.arange(ckv)
        s_ij = jnp.einsum("bqhd,bkhd->bhqk", qs, kcj,
                          preferred_element_type=jnp.float32)
        mask = (kv_pos[None, :] > q_pos[:, None]) if causal else \
            jnp.zeros((sq, ckv), bool)
        mask = (mask | (kv_pos >= skv)[None, :])[None, None]
        s_ij = jnp.where(mask, -jnp.inf, s_ij)
        m_new = jnp.maximum(m, s_ij.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, 0.0, jnp.exp(s_ij - m_safe[..., None]))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vcj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nkv))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = jnp.where(jnp.isfinite(m), m + jnp.log(l_safe), -jnp.inf)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    chunk: int = 512):
    out, _ = _flash_fwd_core(q, k, v, causal, q_offset, chunk)
    return out


def _flash_fwd(q, k, v, causal, q_offset, chunk):
    out, lse = _flash_fwd_core(q, k, v, causal, q_offset, chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, chunk, res, dout):
    q, k, v, out, lse = res
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = np.float32(1.0 / np.sqrt(dh))
    ckv = min(chunk, skv)
    pad = (-skv) % ckv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    nkv = (skv + pad) // ckv
    q_pos = q_offset + jnp.arange(sq)
    # D = rowsum(dout * out), f32 [B, H, Sq]
    d_row = jnp.einsum("bqhd,bqhd->bhq", dout.astype(jnp.float32),
                       out.astype(jnp.float32))
    lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)

    def body(dq, j):
        kcj = jax.lax.dynamic_slice_in_dim(kp, j * ckv, ckv, axis=1)
        vcj = jax.lax.dynamic_slice_in_dim(vp, j * ckv, ckv, axis=1)
        kv_pos = j * ckv + jnp.arange(ckv)
        s_ij = jnp.einsum("bqhd,bkhd->bhqk", q, kcj,
                          preferred_element_type=jnp.float32) * scale
        mask = (kv_pos[None, :] > q_pos[:, None]) if causal else \
            jnp.zeros((sq, ckv), bool)
        mask = (mask | (kv_pos >= skv)[None, :])[None, None]
        p = jnp.where(mask, 0.0, jnp.exp(s_ij - lse_safe[..., None]))
        pc = p.astype(q.dtype)
        dv_j = jnp.einsum("bhqk,bqhd->bkhd", pc, dout,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhd,bkhd->bhqk", dout, vcj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - d_row[..., None]) * scale
        dsc = ds.astype(q.dtype)
        dq = dq + jnp.einsum("bhqk,bkhd->bqhd", dsc, kcj,
                             preferred_element_type=jnp.float32)
        dk_j = jnp.einsum("bhqk,bqhd->bkhd", dsc, q,
                          preferred_element_type=jnp.float32)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, h, dh), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, jnp.arange(nkv))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, nkv * ckv, h, dh)[:, :skv]
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, nkv * ckv, h, dh)[:, :skv]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def gqa_attention(params: Params, x: jax.Array, dims: AttnDims, *,
                  positions: Optional[jax.Array] = None, causal: bool = True,
                  rope_theta: float = 1e4, chunk: int = 512,
                  use_rope: bool = True) -> jax.Array:
    """Self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    h, kv, dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    if positions is None:
        positions = jnp.arange(s)
    # ZeRO-3: storage is fsdp-sharded; gather weights (small) for compute
    # so activations never lose their batch sharding (EXPERIMENTS.md §Perf).
    wq = constrain(params["wq"], None, "tp")
    wk = constrain(params["wk"], None, "tp")
    wv = constrain(params["wv"], None, "tp")
    q = constrain((x @ wq).reshape(b, s, h, dh), "dp", None, "tp", None)
    k = constrain((x @ wk).reshape(b, s, kv, dh), "dp", None, "tp", None)
    v = constrain((x @ wv).reshape(b, s, kv, dh), "dp", None, "tp", None)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    out = chunked_attention(q, k, v, causal=causal, chunk=chunk)
    wo = constrain(params["wo"], "tp", None)
    return constrain(out.reshape(b, s, h * dh) @ wo, "dp", "sp", None)


def gqa_decode(params: Params, x: jax.Array, cache_k: jax.Array,
               cache_v: jax.Array, pos: jax.Array, dims: AttnDims, *,
               rope_theta: float = 1e4, chunk: int = 2048,
               use_rope: bool = True
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode with KV cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, Hkv, Dh]; pos: scalar current length.
    Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    h, kv, dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    wq = constrain(params["wq"], None, "tp")
    wk = constrain(params["wk"], None, "tp")
    wv = constrain(params["wv"], None, "tp")
    q = (x @ wq).reshape(b, 1, h, dh)
    k = (x @ wk).reshape(b, 1, kv, dh)
    v = (x @ wv).reshape(b, 1, kv, dh)
    posv = jnp.full((b, 1), pos, jnp.int32)
    if use_rope:
        q = apply_rope(q, posv, rope_theta)
        k = apply_rope(k, posv, rope_theta)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    kk = _repeat_kv(cache_k, h // kv)
    vv = _repeat_kv(cache_v, h // kv)
    valid = jnp.full((b,), pos + 1, jnp.int32)
    out = chunked_attention(q, kk, vv, causal=False, chunk=chunk,
                            kv_valid_len=valid)
    wo = constrain(params["wo"], "tp", None)
    return out.reshape(b, 1, h * dh) @ wo, cache_k, cache_v


def cross_attention(params: Params, x: jax.Array, enc: jax.Array,
                    dims: AttnDims, chunk: int = 512) -> jax.Array:
    """Encoder-decoder cross attention (whisper). x: [B,S,d], enc: [B,T,d]."""
    b, s, _ = x.shape
    t = enc.shape[1]
    h, kv, dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    q = (x @ constrain(params["wq"], None, "tp")).reshape(b, s, h, dh)
    k = (enc @ constrain(params["wk"], None, "tp")).reshape(b, t, kv, dh)
    v = (enc @ constrain(params["wv"], None, "tp")).reshape(b, t, kv, dh)
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    out = chunked_attention(q, k, v, causal=False, chunk=chunk)
    return out.reshape(b, s, h * dh) @ constrain(params["wo"], "tp", None)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params_shape(d_model: int, d_ff: int, kind: str = "swiglu"):
    if kind == "gelu":
        return {"wi": (d_model, d_ff), "wo": (d_ff, d_model)}
    return {"wi": (d_model, d_ff), "wg": (d_model, d_ff), "wo": (d_ff, d_model)}


def swiglu_mlp(params: Params, x: jax.Array) -> jax.Array:
    if "wg" not in params:  # 2-matrix GELU MLP (starcoder2, whisper)
        wi = constrain(params["wi"], None, "tp")
        wo = constrain(params["wo"], "tp", None)
        hidden = jax.nn.gelu(constrain(x @ wi, "dp", None, "tp"))
        return constrain(hidden @ wo, "dp", None, None)
    wi = constrain(params["wi"], None, "tp")
    wg = constrain(params["wg"], None, "tp")
    wo = constrain(params["wo"], "tp", None)
    gate = jax.nn.silu(constrain(x @ wg, "dp", None, "tp"))
    hidden = constrain(x @ wi, "dp", None, "tp") * gate
    return constrain(hidden @ wo, "dp", "sp", None)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def logits(x: jax.Array, table: jax.Array) -> jax.Array:
    """Tied/untied output projection. x: [B,S,d], table: [V,d] -> [B,S,V]."""
    return jnp.einsum("bsd,vd->bsv", x, table)


def cross_entropy(logits_: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy. logits: [B,S,V] f32, labels: i32[B,S]."""
    lz = jax.nn.log_softmax(logits_.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lz, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
