"""Mixture-of-Experts FFN with grouped, sort-based token dispatch (EP).

Dispatch is permutation-based (not GShard one-hot einsums, which are
infeasible at 128-384 experts): top-k routing -> stable sort by expert ->
capacity-rank within expert -> gather to [G, E, C, D] -> batched expert
GEMM -> weighted scatter-add back. All shapes static; overflow tokens are
dropped (capacity-factor routing) with the drop fraction exposed.

Tokens are dispatched in G groups (G = number of data-parallel shards,
from the active mesh): each group routes its own tokens to ALL experts, so
under pjit the [G@dp, E, C, D] -> [G, E@tp, C, D] resharding between the
per-group scatter and the expert GEMM lowers to exactly the EP all-to-all.
Without grouping the dispatch buffer covers the global batch on every
device (9.4 GB/device for kimi-k2 train_4k; EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import meshctx
from repro.utils.meshctx import constrain

Params = Dict[str, jax.Array]


def moe_params_shape(d_model: int, d_ff: int, num_experts: int):
    return {
        "router": (d_model, num_experts),
        "wi": (num_experts, d_model, d_ff),
        "wg": (num_experts, d_model, d_ff),
        "wo": (num_experts, d_ff, d_model),
    }


def capacity(tokens_per_group: int, num_experts: int, experts_per_token: int,
             capacity_factor: float) -> int:
    c = int(np.ceil(tokens_per_group * experts_per_token * capacity_factor
                    / num_experts))
    return max(8, -(-c // 8) * 8)


def _dp_groups(total_tokens: int) -> int:
    """Dispatch group count. Preferred: one group per DEVICE (dp x tp) so
    the dispatch boundary is a true all-to-all with tokens fully sharded
    (perf iteration 4, EXPERIMENTS.md: the dp-only grouping left tokens
    replicated across the tp row -> GSPMD lowered the boundary as tp-wide
    all-gathers, 16x the volume on kimi-k2). Falls back dp-only, then 1."""
    mesh = meshctx.current_mesh()
    if mesh is None:
        return 1
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    # NOTE (EXPERIMENTS.md perf iteration 4a, REFUTED): grouping over
    # dp x tp (tokens fully sharded) made the combine scatter replicate
    # under GSPMD (38 TB of all-gathers on kimi-k2). dp-only grouping it is;
    # the tp-wide dispatch a2a is revisited in iteration 4b.
    for g in (dp,):
        if g > 1 and total_tokens % g == 0 and total_tokens // g >= 8:
            return g
    return 1


def _dp_only_groups() -> int:
    mesh = meshctx.current_mesh()
    if mesh is None:
        return 1
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    return dp


def moe_ffn(params: Params, x: jax.Array, *, experts_per_token: int,
            capacity_factor: float = 1.25
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, D] -> (out [B, S, D], metrics)."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    k = experts_per_token
    g = _dp_groups(t)
    tg = t // g
    cap = capacity(tg, e, k, capacity_factor)

    full_shard = g > _dp_only_groups()
    tok_axis = "dpt" if full_shard else "dp"
    xg = constrain(x.reshape(g, tg, d), tok_axis, None, None)
    logits = (xg.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))        # [G, Tg, E]
    gates = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(gates, k)                     # [G, Tg, K]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Flatten (token, choice) pairs per group; rank within expert.
    flat_e = tope.reshape(g, tg * k)
    flat_w = topw.reshape(g, tg * k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, tg * k))
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st_ = jnp.take_along_axis(flat_t, order, axis=1)
    pos = jnp.broadcast_to(jnp.arange(tg * k)[None], (g, tg * k))
    expert_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)  # [G, E]
    rank = pos - jnp.take_along_axis(expert_start, se, axis=1)
    keep = rank < cap
    drop_frac = 1.0 - keep.mean()

    # Dispatch: slot (expert, rank) <- token index (+1 so 0 = empty).
    # Dropped pairs are routed to the out-of-bounds slot e*cap, which
    # mode="drop" discards (a clipped in-bounds index would race with the
    # kept occupant of the expert's last slot).
    slot_idx = jnp.where(keep, se * cap + jnp.clip(rank, 0, cap - 1),
                         e * cap)                              # [G, Tg*K]
    grow = jnp.arange(g)[:, None]
    slot_tok = jnp.zeros((g, e * cap), jnp.int32).at[
        grow, slot_idx].set(st_ + 1, mode="drop")

    xg_pad = jnp.pad(xg, ((0, 0), (1, 0), (0, 0)))
    gathered = jnp.take_along_axis(
        xg_pad, slot_tok[..., None], axis=1).reshape(g, e, cap, d)
    # [G@tok, E, C, D] -> [G@dp, E@tp, C, D]: the EP all-to-all boundary.
    gathered = constrain(gathered, "dp", "tp", None, None)

    wg_ = constrain(params["wg"], "tp", None, None)
    wi_ = constrain(params["wi"], "tp", None, None)
    wo_ = constrain(params["wo"], "tp", None, None)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", gathered, wg_))
    hidden = jnp.einsum("gecd,edf->gecf", gathered, wi_) * gate
    expert_out = jnp.einsum("gecf,efd->gecd", hidden, wo_)
    # Combine boundary: back to token-major sharding in bf16 (converting to
    # f32 before the resharding doubled its wire bytes — EXPERIMENTS 4b).
    expert_out = constrain(expert_out.astype(x.dtype),
                           tok_axis, None, None, None)

    # Combine via GATHER, not scatter-add: each (token, choice) pair reads
    # its slot and the weighted sum happens in registers. (The scatter-add
    # combine replicated across tp under GSPMD: 2 x 1.8 TB all-reduce per
    # step on kimi-k2 train_4k — EXPERIMENTS iteration 4b.)
    inv_order = jnp.argsort(order, axis=1)
    slot_pair = jnp.take_along_axis(slot_idx, inv_order, axis=1)
    eo_flat = jnp.concatenate(
        [expert_out.reshape(g, e * cap, d),
         jnp.zeros((g, 1, d), expert_out.dtype)], axis=1)
    picked = jnp.take_along_axis(eo_flat, slot_pair[..., None], axis=1)
    picked = picked.reshape(g, tg, k, d).astype(jnp.float32)
    out = (picked * topw[..., None]).sum(axis=2)         # [G, Tg, D] f32
    out = constrain(out, tok_axis, None, None).reshape(b, s, d)

    me = gates.mean(axis=(0, 1))
    ce_ = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(1.0) \
        / (t * k)
    aux_loss = e * jnp.sum(me * ce_)          # switch-style load balance
    return out.astype(x.dtype), {"moe_drop_frac": drop_frac,
                                 "moe_aux_loss": aux_loss}
