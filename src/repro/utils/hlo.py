"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

`compiled.cost_analysis()` gives FLOPs/bytes but NOT collective volume, so
we parse `compiled.as_text()`: sum result sizes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute, with
while-loop trip counts resolved from the loop-condition constants so
collectives inside the layer scan are multiplied by depth (DESIGN.md;
approximation notes in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("->")[0].split("(")[0]:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _find_entry(hlo: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    return m.group(1) if m else ""


def _while_edges(comps: Dict[str, List[str]]
                 ) -> Dict[str, List[Tuple[str, str]]]:
    """comp -> [(body, cond)] for each while instruction in it."""
    edges: Dict[str, List[Tuple[str, str]]] = {}
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb and mc:
                    edges.setdefault(name, []).append(
                        (mb.group(1), mc.group(1)))
    return edges


def _call_edges(comps: Dict[str, List[str]]) -> Dict[str, List[str]]:
    edges: Dict[str, List[str]] = {}
    for name, lines in comps.items():
        for ln in lines:
            for m in re.finditer(r"(?:calls|call|to_apply)=%?([\w\.\-]+)", ln):
                edges.setdefault(name, []).append(m.group(1))
            m = re.search(r" (?:conditional)\(", ln)
            if m:
                for b in re.finditer(
                        r"(?:branch_computations=\{([^}]*)\}|"
                        r"(?:true|false)_computation=%?([\w\.\-]+))", ln):
                    names = b.group(1) or b.group(2) or ""
                    for nm in names.split(","):
                        nm = nm.strip().lstrip("%")
                        if nm:
                            edges.setdefault(name, []).append(nm)
    return edges


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the condition (loop bound heuristic)."""
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _build_multipliers(comps, whiles, calls, entry) -> Dict[str, float]:
    mult: Dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0):
        if depth > 50 or name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for body, cond in whiles.get(name, []):
            tc = _trip_count(comps.get(cond, []))
            visit(body, m * tc, depth + 1)
            visit(cond, m * (tc + 1), depth + 1)
        for callee in calls.get(name, []):
            if callee in comps and callee != name:
                visit(callee, m, depth + 1)

    if entry:
        visit(entry, 1.0)
    else:
        for name in comps:
            mult.setdefault(name, 1.0)
    return mult


_DEF_RE = re.compile(r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^=]*?\))|"
                     r"(?:[\w]+\[[\d,]*\]\S*))\s+([\w\-]+)\(")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "iota", "after-all", "partition-id",
                   "replica-id", "while", "conditional", "call", "custom-call",
                   "broadcast", "reshape", "transpose", "copy-start",
                   "copy-done"}


def _instruction_bytes(iname: str, itype: str, op: str, ln: str,
                       types: Dict[str, str]) -> int:
    """HBM traffic model per top-level (post-fusion) instruction.

    dynamic(-update)-slice (and fusions rooted in them) touch only
    slice-sized data, not their giant loop-carried operands; everything
    else reads operands + writes result once.
    """
    res = _shape_bytes(itype)
    slicey = ("dynamic-slice" in ln or "dynamic_slice" in iname
              or "dynamic-update-slice" in ln or "dynamic_update" in iname)
    total = res
    for om in re.finditer(r"%([\w\.\-]+)", ln.split("(", 1)[-1]):
        if om.group(1) in types:
            b = _shape_bytes(types[om.group(1)])
            if slicey and b > 8 * max(res, 1):
                continue  # aliased big buffer; only the slice moves
            total += b
    return total


def analyze(hlo: str) -> Dict[str, float]:
    """Trip-count-weighted per-device analysis of post-SPMD HLO:

      flops      2*M*N*K over every dot (loop-weighted; XLA cost_analysis
                 counts loop bodies ONCE, which under-counts scan-based
                 models by ~depth x)
      hbm_bytes  sum of operand+result bytes of top-level instructions
                 (post-fusion, each top-level op ~= one kernel <-> HBM trip;
                 fusion-internal and scalar-reducer computations excluded)
      collectives  as collective_bytes()
    """
    comps = _split_computations(hlo)
    entry = _find_entry(hlo)
    whiles = _while_edges(comps)
    calls = _call_edges(comps)
    mult = _build_multipliers(comps, whiles, calls, entry)

    # fusion-internal computations: flops YES, hbm bytes NO
    fusion_callees = set()
    for lines in comps.values():
        for ln in lines:
            if " fusion(" in ln:
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                if m:
                    fusion_callees.add(m.group(1))

    flops = 0.0
    hbm = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        # symbol table: instruction name -> type string
        types: Dict[str, str] = {}
        parsed = []
        for ln in lines:
            dm = _DEF_RE.search(ln)
            if dm:
                types[dm.group(1)] = dm.group(2)
                parsed.append((dm.group(1), dm.group(2), dm.group(3), ln))
        for iname, itype, op, ln in parsed:
            if op == "dot":
                out_elems = 1
                sm = _SHAPE_RE.search(itype)
                if sm and sm.group(2):
                    for d in sm.group(2).split(","):
                        out_elems *= int(d)
                # contraction size from lhs operand shape
                om = re.search(r"\(\s*%([\w\.\-]+)", ln[ln.index("dot("):])
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                csize = 1
                if om and cdims and om.group(1) in types:
                    lshape = _SHAPE_RE.search(types[om.group(1)])
                    if lshape and lshape.group(2):
                        dims = [int(x) for x in lshape.group(2).split(",")]
                        for ci in cdims.group(1).split(","):
                            if ci != "" and int(ci) < len(dims):
                                csize *= dims[int(ci)]
                flops += 2.0 * out_elems * csize * m
            if name not in fusion_callees and op not in _SKIP_BYTES_OPS:
                hbm += _instruction_bytes(iname, itype, op, ln, types) * m

    out = collective_bytes(hlo)
    out["flops"] = flops
    out["hbm_bytes"] = hbm
    return out


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Total bytes moved by collectives, by op kind, trip-count weighted."""
    comps = _split_computations(hlo)
    entry = _find_entry(hlo)
    whiles = _while_edges(comps)
    calls = _call_edges(comps)

    mult: Dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0):
        if depth > 50 or name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for body, cond in whiles.get(name, []):
            tc = _trip_count(comps.get(cond, []))
            visit(body, m * tc, depth + 1)
            visit(cond, m * (tc + 1), depth + 1)
        for callee in calls.get(name, []):
            if callee in comps and callee != name:
                visit(callee, m, depth + 1)

    if entry:
        visit(entry, 1.0)
    else:
        for name in comps:
            mult.setdefault(name, 1.0)

    out = {k: 0.0 for k in COLLECTIVES}
    out["num_ops"] = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for ln in lines:
            for op in COLLECTIVES:
                # match '<type> op-name(' with optional leading %name =
                mm = re.search(r"=\s*([^=]*?)\s" + op + r"(?:\.\d+)?\(", ln)
                if mm and (" " + op + "(" in ln or " " + op + "." in ln
                           or ln.startswith(op)):
                    out[op] += _shape_bytes(mm.group(1)) * m
                    out["num_ops"] += m
                    break
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out
