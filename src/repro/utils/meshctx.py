"""Activation-sharding context: model code calls `constrain(x, ...logical
axes...)`; when a mesh is active (set by the launcher/dry-run) this becomes
jax.lax.with_sharding_constraint, otherwise a no-op (single-device tests).

Why this exists (EXPERIMENTS.md §Perf iteration 1): without activation
constraints GSPMD resolved the FSDP-weight vs batch-sharding conflict by
all-gathering full-batch activations (4 GB per layer per step on
smollm/train_4k). Constraints pin activations to [batch@dp, ...] and let
weights be the thing that moves.

Logical axis vocabulary: "dp" (data || pod x data), "tp" (model), None.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], sp: bool = False):
    prev = current_mesh()
    prev_sp = getattr(_state, "sp", False)
    _state.mesh = mesh
    _state.sp = sp
    try:
        yield
    finally:
        _state.mesh = prev
        _state.sp = prev_sp


def _resolve(mesh: Mesh, axis: Optional[str]):
    if axis is None:
        return None
    if axis == "dp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if axes else None
    if axis == "tp":
        return "model" if "model" in mesh.axis_names else None
    if axis == "dpt":  # every mesh axis (fully-sharded token dim)
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        return axes if axes else None
    if axis == "sp":   # sequence parallelism: model axis iff enabled
        if getattr(_state, "sp", False) and "model" in mesh.axis_names:
            return "model"
        return None
    return axis if axis in mesh.axis_names else None


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if a mesh is active and dims divide."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        return x
    import numpy as np
    spec = []
    for dim, ax in zip(x.shape, logical):
        r = _resolve(mesh, ax)
        if r is None:
            spec.append(None)
            continue
        axes = r if isinstance(r, tuple) else (r,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        spec.append(r if (size > 0 and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
