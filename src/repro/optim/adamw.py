"""Optimizers from scratch (no optax in this container): AdamW and
Adafactor (factored second moment — required for the 1T-param MoE at 512
chips, DESIGN.md §7). Pure-pytree, shardable: optimizer state inherits the
parameter sharding leaf-for-leaf."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32


def adamw_init(params: PyTree, cfg: AdamWConfig = AdamWConfig()) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads: PyTree, state: PyTree, params: PyTree, lr: jax.Array,
                 cfg: AdamWConfig = AdamWConfig()
                 ) -> Tuple[PyTree, PyTree]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return p_new, {"m": m_new, "v": v_new, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), simplified: factored v for >=2D leaves,
# bf16 first moment. State for a [.., R, C] leaf: v_row [.., R], v_col [.., C].
# ---------------------------------------------------------------------------

class AdafactorConfig(NamedTuple):
    decay: float = 0.99
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    momentum: float = 0.9
    moment_dtype: Any = jnp.bfloat16


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: PyTree,
                   cfg: AdafactorConfig = AdafactorConfig()) -> PyTree:
    def init_leaf(p):
        if _factored(p.shape):
            return {
                "v_row": jnp.zeros(p.shape[:-1], jnp.float32),
                "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                "m": jnp.zeros(p.shape, cfg.moment_dtype),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32),
                "m": jnp.zeros(p.shape, cfg.moment_dtype)}

    return {"leaves": jax.tree.map(init_leaf, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads: PyTree, state: PyTree, params: PyTree,
                     lr: jax.Array,
                     cfg: AdafactorConfig = AdafactorConfig()
                     ) -> Tuple[PyTree, PyTree]:
    step = state["step"] + 1
    beta = cfg.decay

    def upd(g, s, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + cfg.eps
        if _factored(p.shape):
            v_row = beta * s["v_row"] + (1 - beta) * g2.mean(-1)
            v_col = beta * s["v_col"] + (1 - beta) * g2.mean(-2)
            row_mean = v_row.mean(-1, keepdims=True)
            r = v_row / jnp.maximum(row_mean, cfg.eps)
            update = gf / (jnp.sqrt(r)[..., None] *
                           jnp.sqrt(v_col)[..., None, :])
            new_s = {"v_row": v_row, "v_col": v_col}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            update = gf / jnp.sqrt(v)
            new_s = {"v": v}
        rms = jnp.sqrt(jnp.mean(update * update))
        update = update / jnp.maximum(1.0, rms / cfg.clip_threshold)
        m = cfg.momentum * s["m"].astype(jnp.float32) + \
            (1 - cfg.momentum) * update
        new_s["m"] = m.astype(cfg.moment_dtype)
        p_new = (p.astype(jnp.float32) - lr * (m + cfg.weight_decay *
                                               p.astype(jnp.float32)))
        return p_new.astype(p.dtype), new_s

    g_leaves, treedef = jax.tree.flatten(grads)
    p_leaves = treedef.flatten_up_to(params)
    s_leaves = treedef.flatten_up_to(state["leaves"])
    out = [upd(g, s, p) for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
    p_new = jax.tree.unflatten(treedef, [o[0] for o in out])
    s_new = jax.tree.unflatten(treedef, [o[1] for o in out])
    return p_new, {"leaves": s_new, "step": step}
