"""LR schedules: linear warmup + cosine decay (the only two knobs a real
launcher needs; pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup_steps, warm, cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(step, peak_lr, dtype=jnp.float32)
