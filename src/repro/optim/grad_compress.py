"""Int8 gradient compression with error feedback (distributed-optimization
trick, DESIGN.md §4).

Under pure pjit, data-parallel gradient reduction is implicit in the
backward pass; to compress it we take explicit control of the DP reduction
with shard_map: per-leaf blockwise int8 quantization -> psum of int8-decoded
values (wire format int8 + per-block f32 scale = ~4x less DP traffic)
-> dequantize, with the quantization error carried in optimizer state and
added back next step (error feedback keeps convergence).

The compile-checked integration point is train.step.make_train_step(
 compress_grads=True); wall-clock validation needs real links, so tests
check exactness properties (error feedback telescopes; quantization is
unbiased-ish and bounded) and the dry-run checks lowering.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8. Returns (q int8 [..., B], scale f32)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape,
                size: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_roundtrip(x: jax.Array) -> jax.Array:
    """quantize->dequantize (what the wire carries)."""
    q, s = _quantize(x)
    return _dequantize(q, s, x.shape, x.size)


def compressed_grad_mean(grads: PyTree, error: Optional[PyTree],
                         axis_names: Tuple[str, ...]) -> Tuple[PyTree, PyTree]:
    """Inside shard_map: error-feedback compress, psum-mean over DP axes,
    return (mean grads, new error state). If `error` is None, zeros."""
    if error is None:
        error = jax.tree.map(jnp.zeros_like, grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        sent = compress_roundtrip(gf)
        new_e = gf - sent
        total = sent
        for ax in axis_names:
            total = jax.lax.psum(total, ax)
        n = 1
        for ax in axis_names:
            n = n * jax.lax.axis_size(ax)
        return (total / n).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, error)
    g_new = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    e_new = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return g_new, e_new
