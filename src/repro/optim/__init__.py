from repro.optim import grad_compress, schedule
from repro.optim.adamw import (AdafactorConfig, AdamWConfig, adafactor_init,
                               adafactor_update, adamw_init, adamw_update)

__all__ = ["AdamWConfig", "AdafactorConfig", "adamw_init", "adamw_update",
           "adafactor_init", "adafactor_update", "schedule", "grad_compress"]
