"""Array-form GBDT ensemble: a pytree of fixed-shape arrays.

Trees are complete binary trees of fixed ``depth`` stored in level order:
internal node ``i`` has children ``2i+1`` (left, x[f] <= thr) and ``2i+2``
(right). Leaves are the final level, indexed ``node - (2**depth - 1)``.

This fixed layout is what makes both jit-compiled training (level-wise
growth) and Pallas-kernel inference possible: no pointers, no ragged trees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GBDTParams:
    """Ensemble parameters.

    feat:   int32[T, 2**depth - 1]  split feature per internal node
            (-1 => degenerate node: everything goes left)
    thresh: float32[T, 2**depth - 1] raw-space threshold (left iff x <= thr)
    leaf:   float32[T, 2**depth]     leaf values (already scaled by lr)
    base:   float32[]                initial prediction (mean of targets)
    """

    feat: jax.Array
    thresh: jax.Array
    leaf: jax.Array
    base: jax.Array

    @property
    def num_trees(self) -> int:
        return self.feat.shape[0]

    @property
    def depth(self) -> int:
        return int(np.log2(self.leaf.shape[1]))

    @property
    def num_features(self) -> int:
        # Not stored explicitly; max feature index + 1 is a lower bound.
        return int(jax.device_get(self.feat).max()) + 1


def empty_params(num_trees: int, depth: int) -> GBDTParams:
    n_internal = 2**depth - 1
    n_leaf = 2**depth
    return GBDTParams(
        feat=jnp.zeros((num_trees, n_internal), jnp.int32),
        thresh=jnp.full((num_trees, n_internal), jnp.inf, jnp.float32),
        leaf=jnp.zeros((num_trees, n_leaf), jnp.float32),
        base=jnp.zeros((), jnp.float32),
    )


def to_state_dict(p: GBDTParams) -> Dict[str, Any]:
    return {
        "feat": np.asarray(p.feat),
        "thresh": np.asarray(p.thresh),
        "leaf": np.asarray(p.leaf),
        "base": np.asarray(p.base),
    }


def from_state_dict(d: Dict[str, Any]) -> GBDTParams:
    return GBDTParams(
        feat=jnp.asarray(d["feat"], jnp.int32),
        thresh=jnp.asarray(d["thresh"], jnp.float32),
        leaf=jnp.asarray(d["leaf"], jnp.float32),
        base=jnp.asarray(d["base"], jnp.float32),
    )
