from repro.gbdt.model import GBDTParams, empty_params, from_state_dict, to_state_dict
from repro.gbdt.train import (GBDTConfig, fit, fit_decision_tree, fit_linear,
                              fit_random_forest)
from repro.gbdt.infer import predict, predict_efficient, predict_jit

__all__ = [
    "GBDTParams", "GBDTConfig", "empty_params", "fit", "fit_decision_tree",
    "fit_linear", "fit_random_forest", "predict", "predict_efficient",
    "predict_jit", "to_state_dict", "from_state_dict",
]
