"""Histogram-based gradient-boosted decision trees in JAX.

LightGBM is not available in this environment (and would not run on TPU
anyway), so DARTH's recall predictor is trained with this from-scratch
implementation:

  * quantile binning (host-side, once) -> int32 bin matrix,
  * level-wise tree growth (LightGBM grows leaf-wise; level-wise has
    identical accuracy on DARTH's 11 low-cardinality features and is the
    form that vectorizes: every level is one scatter-add histogram +
    one vectorized split search over [nodes, features, bins]),
  * squared loss, shrinkage, L2 leaf regularization, min-child-weight,
  * the whole boosting loop is one ``lax.scan`` -> compiles once.

Also provides the paper's §4.1.5 comparison models: random forest (same
grower, bootstrap weights, averaged), single decision tree, ridge linear
regression.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.gbdt.model import GBDTParams


class GBDTConfig(NamedTuple):
    num_trees: int = 100
    depth: int = 6
    learning_rate: float = 0.1
    num_bins: int = 64
    l2: float = 1.0
    min_child_weight: float = 20.0


def compute_bin_edges(x: np.ndarray, num_bins: int) -> np.ndarray:
    """Per-feature quantile bin edges. Returns float32[F, num_bins - 1]."""
    qs = np.linspace(0.0, 1.0, num_bins + 1)[1:-1]
    edges = np.quantile(np.asarray(x, np.float64), qs, axis=0).T  # [F, B-1]
    # Strictly increasing edges keep searchsorted semantics clean; nudge ties.
    eps = 1e-12 + 1e-9 * np.abs(edges)
    edges = np.maximum.accumulate(edges + np.cumsum(np.zeros_like(edges), axis=1), axis=1)
    for j in range(1, edges.shape[1]):
        edges[:, j] = np.maximum(edges[:, j], edges[:, j - 1] + eps[:, j])
    return edges.astype(np.float32)


def bin_data(x: jax.Array, edges: jax.Array) -> jax.Array:
    """bin = #edges strictly below x; int32[n, F] in [0, num_bins-1]."""
    return (x[:, :, None] > edges[None, :, :]).sum(axis=2).astype(jnp.int32)


def _grow_tree(
    xb: jax.Array,           # int32[n, F] binned features
    grad: jax.Array,         # float32[n] gradients (pred - y for L2 loss)
    w: jax.Array,            # float32[n] sample weights
    depth: int,
    num_bins: int,
    l2: float,
    min_child_weight: float,
    learning_rate: float,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Grow one level-wise tree. Returns (feat, thr_bin, leaf, sample_leaf_val).

    feat: int32[2**depth - 1] (-1 = degenerate node, all left)
    thr_bin: int32[2**depth - 1] split bin (left iff bin <= thr_bin)
    leaf: float32[2**depth]
    sample_leaf_val: float32[n] this tree's contribution per training sample.
    """
    n, f_dim = xb.shape
    feat_nodes = []
    thr_nodes = []
    node_pos = jnp.zeros((n,), jnp.int32)  # position within current level
    f_range = jnp.arange(f_dim, dtype=jnp.int32)

    gw = grad * w
    for d in range(depth):
        n_nodes = 2**d
        seg = (node_pos[:, None] * (f_dim * num_bins)
               + f_range[None, :] * num_bins + xb)              # [n, F]
        nseg = n_nodes * f_dim * num_bins
        hist_g = jax.ops.segment_sum(
            jnp.broadcast_to(gw[:, None], (n, f_dim)).reshape(-1),
            seg.reshape(-1), num_segments=nseg).reshape(n_nodes, f_dim, num_bins)
        hist_w = jax.ops.segment_sum(
            jnp.broadcast_to(w[:, None], (n, f_dim)).reshape(-1),
            seg.reshape(-1), num_segments=nseg).reshape(n_nodes, f_dim, num_bins)

        gl = jnp.cumsum(hist_g, axis=2)
        wl = jnp.cumsum(hist_w, axis=2)
        g_tot = gl[:, :, -1:]
        w_tot = wl[:, :, -1:]
        gr = g_tot - gl
        wr = w_tot - wl
        parent = (g_tot**2) / (w_tot + l2)
        gain = gl**2 / (wl + l2) + gr**2 / (wr + l2) - parent    # [N, F, B]
        valid = (wl >= min_child_weight) & (wr >= min_child_weight)
        valid = valid & (jnp.arange(num_bins)[None, None, :] < num_bins - 1)
        gain = jnp.where(valid, gain, -jnp.inf)

        flat = gain.reshape(n_nodes, f_dim * num_bins)
        best = jnp.argmax(flat, axis=1)
        best_gain = jnp.take_along_axis(flat, best[:, None], axis=1)[:, 0]
        feat_d = (best // num_bins).astype(jnp.int32)
        bin_d = (best % num_bins).astype(jnp.int32)
        degenerate = ~jnp.isfinite(best_gain) | (best_gain <= 0.0)
        feat_d = jnp.where(degenerate, -1, feat_d)

        feat_nodes.append(feat_d)
        thr_nodes.append(bin_d)

        f_sel = feat_d[node_pos]                                  # [n]
        t_sel = bin_d[node_pos]
        x_sel = jnp.take_along_axis(xb, jnp.maximum(f_sel, 0)[:, None], axis=1)[:, 0]
        go_right = (x_sel > t_sel) & (f_sel >= 0)
        node_pos = 2 * node_pos + go_right.astype(jnp.int32)

    n_leaf = 2**depth
    leaf_g = jax.ops.segment_sum(gw, node_pos, num_segments=n_leaf)
    leaf_w = jax.ops.segment_sum(w, node_pos, num_segments=n_leaf)
    leaf = -learning_rate * leaf_g / (leaf_w + l2)
    sample_val = leaf[node_pos]
    feat = jnp.concatenate(feat_nodes)
    thr = jnp.concatenate(thr_nodes)
    return feat, thr, leaf, sample_val


def _bins_to_raw_thresholds(feat: jax.Array, thr_bin: jax.Array,
                            edges: jax.Array) -> jax.Array:
    """Map bin-space thresholds to raw space: left iff x <= edges[f, b]."""
    f = jnp.maximum(feat, 0)
    raw = edges[f, jnp.minimum(thr_bin, edges.shape[1] - 1)]
    return jnp.where(feat < 0, jnp.inf, raw)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _fit_binned(xb: jax.Array, y: jax.Array, edges: jax.Array,
                cfg: GBDTConfig, tree_weights: jax.Array) -> GBDTParams:
    n = xb.shape[0]
    base = jnp.mean(y)
    pred0 = jnp.full((n,), base, jnp.float32)

    def one_tree(pred, w):
        grad = pred - y
        feat, thr, leaf, sample_val = _grow_tree(
            xb, grad, w, cfg.depth, cfg.num_bins, cfg.l2,
            cfg.min_child_weight, cfg.learning_rate)
        pred = pred + sample_val
        thr_raw = _bins_to_raw_thresholds(feat, thr, edges)
        return pred, (feat, thr_raw, leaf)

    _, (feats, thrs, leaves) = jax.lax.scan(one_tree, pred0, tree_weights)
    return GBDTParams(feat=feats, thresh=thrs, leaf=leaves, base=base)


def fit(x: np.ndarray, y: np.ndarray, cfg: GBDTConfig = GBDTConfig(),
        sample_weight: Optional[np.ndarray] = None) -> GBDTParams:
    """Fit a GBDT regressor. Host-side binning + jitted boosting."""
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    edges = compute_bin_edges(x, cfg.num_bins)
    xb = bin_data(jnp.asarray(x), jnp.asarray(edges))
    w = np.ones((cfg.num_trees, x.shape[0]), np.float32)
    if sample_weight is not None:
        w = w * np.asarray(sample_weight, np.float32)[None, :]
    return _fit_binned(xb, jnp.asarray(y), jnp.asarray(edges), cfg, jnp.asarray(w))


def fit_random_forest(x: np.ndarray, y: np.ndarray, num_trees: int = 100,
                      depth: int = 6, num_bins: int = 64, l2: float = 1.0,
                      min_child_weight: float = 20.0,
                      seed: int = 0) -> GBDTParams:
    """Random forest via the same grower: each tree fits y from scratch on a
    Poisson(1) bootstrap; leaves pre-scaled by 1/T so ensemble-sum inference
    averages."""
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    edges = compute_bin_edges(x, num_bins)
    xb = bin_data(jnp.asarray(x), jnp.asarray(edges))
    base = float(np.mean(y))
    grad = jnp.asarray(-(y - base), jnp.float32)  # fit residual around mean

    feats, thrs, leaves = [], [], []
    grow = jax.jit(functools.partial(
        _grow_tree, depth=depth, num_bins=num_bins, l2=l2,
        min_child_weight=min_child_weight, learning_rate=1.0))
    for _ in range(num_trees):
        w = jnp.asarray(rng.poisson(1.0, n).astype(np.float32))
        feat, thr, leaf, _ = grow(xb, grad, w)
        feats.append(feat)
        thrs.append(_bins_to_raw_thresholds(feat, thr, jnp.asarray(edges)))
        leaves.append(leaf / num_trees)
    return GBDTParams(feat=jnp.stack(feats), thresh=jnp.stack(thrs),
                      leaf=jnp.stack(leaves), base=jnp.asarray(base, jnp.float32))


def fit_decision_tree(x: np.ndarray, y: np.ndarray, depth: int = 8,
                      num_bins: int = 64) -> GBDTParams:
    return fit(x, y, GBDTConfig(num_trees=1, depth=depth, learning_rate=1.0,
                                num_bins=num_bins, min_child_weight=5.0))


class LinearModel(NamedTuple):
    w: jax.Array
    b: jax.Array

    def predict(self, x: jax.Array) -> jax.Array:
        return x @ self.w + self.b


def fit_linear(x: np.ndarray, y: np.ndarray, ridge: float = 1e-3) -> LinearModel:
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    mu = x.mean(0)
    sd = x.std(0) + 1e-8
    xs = (x - mu) / sd
    a = xs.T @ xs + ridge * jnp.eye(x.shape[1])
    w = jnp.linalg.solve(a, xs.T @ (y - y.mean()))
    w_raw = w / sd
    b = y.mean() - mu @ w_raw
    return LinearModel(w=w_raw, b=b)
