"""Batched GBDT inference in pure JAX (the XLA path; kernels/gbdt_predict.py
is the Pallas VMEM-resident version, validated against this)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.gbdt.model import GBDTParams


def predict(params: GBDTParams, x: jax.Array) -> jax.Array:
    """Predict for a batch.

    Args:
      params: ensemble.
      x: float32[B, F] raw features.
    Returns:
      float32[B] predictions.
    """
    depth = params.depth
    num_trees = params.num_trees
    b = x.shape[0]

    # node[b, t]: current node index per (query, tree); predicated descent.
    node = jnp.zeros((b, num_trees), jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(params.feat[None, :, :].repeat(b, 0), node[:, :, None], axis=2)[..., 0]
        t = jnp.take_along_axis(params.thresh[None, :, :].repeat(b, 0), node[:, :, None], axis=2)[..., 0]
        xv = jnp.take_along_axis(x, jnp.maximum(f, 0), axis=1)  # [B, T]
        go_right = (xv > t) & (f >= 0)
        node = 2 * node + 1 + go_right.astype(jnp.int32)
    leaf_idx = node - (2**depth - 1)
    leaf_val = jnp.take_along_axis(params.leaf[None, :, :].repeat(b, 0), leaf_idx[:, :, None], axis=2)[..., 0]
    return params.base + leaf_val.sum(axis=1)


def predict_efficient(params: GBDTParams, x: jax.Array) -> jax.Array:
    """Gather-light variant: same math, but gathers through flattened tables
    (XLA lowers this to a single gather per level instead of per-tree)."""
    depth = params.depth
    num_trees, n_internal = params.feat.shape
    b = x.shape[0]
    feat_flat = params.feat.reshape(-1)
    thresh_flat = params.thresh.reshape(-1)
    tree_off = jnp.arange(num_trees, dtype=jnp.int32) * n_internal

    node = jnp.zeros((b, num_trees), jnp.int32)
    for _ in range(depth):
        idx = node + tree_off[None, :]
        f = feat_flat[idx]
        t = thresh_flat[idx]
        xv = jnp.take_along_axis(x, jnp.maximum(f, 0), axis=1)
        go_right = (xv > t) & (f >= 0)
        node = 2 * node + 1 + go_right.astype(jnp.int32)
    leaf_idx = node - (2**depth - 1)
    n_leaf = params.leaf.shape[1]
    leaf_flat = params.leaf.reshape(-1)
    leaf_val = leaf_flat[leaf_idx + (jnp.arange(num_trees, dtype=jnp.int32) * n_leaf)[None, :]]
    return params.base + leaf_val.sum(axis=1)


predict_jit = jax.jit(predict_efficient)
