"""DARTH serving engine: slot pool + batch compaction (DESIGN.md §2).

On SPMD hardware a lone early-terminated query inside a fixed batch saves
nothing — the batch keeps stepping. Compaction converts DARTH's per-query
termination into throughput: terminated queries leave their slot, queued
queries are spliced in (state surgery via tree-select), and the engine
keeps every slot busy. This is the systems contribution that makes the
paper's speedups real on TPU; `benchmarks/serving.py` measures
slot-step savings vs a no-compaction baseline.

Every query carries its own declared recall target (mixed-target batches
are native — per-slot R_t, per-slot adaptive intervals).

The server is engine-agnostic through the Engine protocol: handing it
engines.sharded_ivf_engine (cap-sharded bucket store, shard_map probe)
instead of engines.ivf_engine changes nothing here — slot compaction,
splicing and the chunked driver all operate on the replicated search
state, while the probe's bucket traffic stays on-shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import darth_search, engines as engines_lib
from repro.core.intervals import IntervalParams
from repro.core.predictor import RecallPredictor
from repro.utils import meshctx

PyTree = Any


def _select_slots(mask: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-slot tree select: where mask[b], take `new`, else `old`.
    Leaves without a leading slot dim are kept from `old`."""
    b = mask.shape[0]

    def sel(n, o):
        if hasattr(o, "ndim") and o.ndim >= 1 and o.shape[0] == b:
            m = mask.reshape((b,) + (1,) * (o.ndim - 1))
            return jnp.where(m, n, o)
        return o
    return jax.tree.map(sel, new, old)


@dataclasses.dataclass
class ServeStats:
    completed: int = 0
    slot_steps: int = 0          # engine steps x slots (cost proxy)
    engine_steps: int = 0
    refills: int = 0


class DarthServer:
    """Continuous-batching declarative-recall search server."""

    def __init__(self, engine: engines_lib.Engine,
                 predictor: RecallPredictor,
                 interval_for_target,        # fn: r_t array -> IntervalParams
                 num_slots: int = 64, steps_per_sync: int = 4,
                 mesh=None):
        self.engine = engine
        self.predictor = predictor
        self.interval_for_target = interval_for_target
        self.num_slots = num_slots
        self.steps_per_sync = steps_per_sync
        # When the engine's index was placed on a mesh (dist.place_index),
        # the slot-pool chunks run SPMD over it; use_mesh also activates
        # the activation constraints inside any model-side feature code.
        self.mesh = mesh

        eng = engine
        pred = predictor

        @jax.jit
        def run_chunk(st: darth_search.DarthState, r_t: jax.Array,
                      ipi: jax.Array, mpi: jax.Array):
            body = darth_search.make_darth_body(
                eng, pred, IntervalParams(ipi=ipi, mpi=mpi), r_t)

            def do(i, s):
                return body(s)
            return jax.lax.fori_loop(0, steps_per_sync, do, st)

        @jax.jit
        def init_chunk(q: jax.Array, ipi: jax.Array):
            return darth_search.init_darth_state(
                eng, q, IntervalParams(ipi=ipi, mpi=ipi))

        @jax.jit
        def splice(mask, new_st, old_st):
            return _select_slots(mask, new_st, old_st)

        self._run_chunk = run_chunk
        self._init_chunk = init_chunk
        self._splice = splice

    def serve(self, queries: np.ndarray, r_targets: np.ndarray,
              max_engine_steps: int = 100_000
              ) -> Tuple[List[Optional[Tuple[np.ndarray, np.ndarray]]],
                         ServeStats]:
        """Process all queries; returns per-query (dists, ids) + stats."""
        ctx = (meshctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            return self._serve(queries, r_targets, max_engine_steps)

    def _serve(self, queries: np.ndarray, r_targets: np.ndarray,
               max_engine_steps: int = 100_000
               ) -> Tuple[List[Optional[Tuple[np.ndarray, np.ndarray]]],
                          ServeStats]:
        n, d = queries.shape
        b = self.num_slots
        stats = ServeStats()
        results: List[Optional[Tuple[np.ndarray, np.ndarray]]] = [None] * n

        queue = list(range(n))
        slot_query = np.full((b,), -1, np.int64)   # which query occupies slot

        def take_batch(count):
            ids = [queue.pop(0) for _ in range(min(count, len(queue)))]
            return ids

        # initial fill
        ids = take_batch(b)
        qb = np.zeros((b, d), np.float32)
        rt = np.zeros((b,), np.float32)
        for s, qid in enumerate(ids):
            qb[s] = queries[qid]
            rt[s] = r_targets[qid]
            slot_query[s] = qid
        ip = self.interval_for_target(rt)
        ipi = np.broadcast_to(np.asarray(ip.ipi, np.float32), (b,)).copy()
        mpi = np.broadcast_to(np.asarray(ip.mpi, np.float32), (b,)).copy()
        st = self._init_chunk(jnp.asarray(qb), jnp.asarray(ipi))
        # slots with no query: deactivate
        occupied = slot_query >= 0
        st = dataclasses.replace(
            st, inner=engines_lib.set_active(
                st.inner, st.inner.active & jnp.asarray(occupied)))
        rt_dev = jnp.asarray(rt)

        while True:
            st = self._run_chunk(st, rt_dev, jnp.asarray(ipi),
                                 jnp.asarray(mpi))
            stats.engine_steps += self.steps_per_sync
            stats.slot_steps += self.steps_per_sync * int(occupied.sum())
            active = np.asarray(jax.device_get(st.inner.active))
            finished = occupied & ~active
            if finished.any():
                # harvest results
                topk_d = np.asarray(jax.device_get(
                    self.engine.topk_d(st.inner)))
                topk_i = np.asarray(jax.device_get(
                    self.engine.topk_i(st.inner)))
                for s in np.nonzero(finished)[0]:
                    qid = slot_query[s]
                    results[qid] = (topk_d[s], topk_i[s])
                    stats.completed += 1
                    slot_query[s] = -1
                occupied = slot_query >= 0
                # refill
                if queue:
                    free = np.nonzero(~occupied)[0]
                    ids = take_batch(len(free))
                    if ids:
                        stats.refills += 1
                        mask = np.zeros((b,), bool)
                        qb2 = np.zeros((b, d), np.float32)
                        rt2 = rt.copy()
                        for s, qid in zip(free, ids):
                            mask[s] = True
                            qb2[s] = queries[qid]
                            rt2[s] = r_targets[qid]
                            slot_query[s] = qid
                        ip2 = self.interval_for_target(rt2)
                        ipi2 = np.broadcast_to(
                            np.asarray(ip2.ipi, np.float32), (b,))
                        mpi2 = np.broadcast_to(
                            np.asarray(ip2.mpi, np.float32), (b,))
                        ipi = np.where(mask, ipi2, ipi)
                        mpi = np.where(mask, mpi2, mpi)
                        rt = np.where(mask, rt2, rt)
                        rt_dev = jnp.asarray(rt)
                        fresh = self._init_chunk(jnp.asarray(qb2),
                                                 jnp.asarray(ipi))
                        st = self._splice(jnp.asarray(mask), fresh, st)
                        occupied = slot_query >= 0
                # deactivate empty slots
                st = dataclasses.replace(
                    st, inner=engines_lib.set_active(
                        st.inner, st.inner.active & jnp.asarray(occupied)))
            if not occupied.any() and not queue:
                break
            if stats.engine_steps >= max_engine_steps:
                break
        return results, stats
