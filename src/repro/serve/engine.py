"""DARTH serving engine: slot pool + batch compaction (DESIGN.md §2),
split into a per-host loop and a device loop for multi-host serving.

On SPMD hardware a lone early-terminated query inside a fixed batch saves
nothing — the batch keeps stepping. Compaction converts DARTH's per-query
termination into throughput: terminated queries leave their slot, queued
queries are spliced in (state surgery via tree-select), and the engine
keeps every slot busy. This is the systems contribution that makes the
paper's speedups real on TPU; `benchmarks/serving.py` measures
slot-step savings vs a no-compaction baseline.

Every query carries its own declared recall target (mixed-target batches
are native — per-slot R_t, per-slot adaptive intervals).

Multi-host topology (hosts > 1): the slot pool is partitioned into
contiguous per-host slices, each owned by a `_HostSlots` loop that runs
admission, refill splicing and slot compaction against ONLY its slice —
no cross-host coordination, no global scheduler. The device loop is the
single SPMD program all hosts participate in: the jitted chunks
(init/run/splice) step the whole pool against the globally sharded
index, and the only global synchronization left is the collectives
already inside the engine step (the "model"-axis probe/beam merges).
On one process this is SIMULATED multi-host — N host loops over slot
slices of one device batch — exactly like the multidevice test lane
simulates shard counts; on a mesh with a "hosts" axis
(launch/mesh.make_serve_mesh) the per-chunk inputs are additionally
placed with the slot dim split over host groups
(dist.sharding.batch_shardings kind="serve"), so each host group's
devices step only the slots its host loop manages and the per-chunk
collective operands shrink to [B/hosts, ..].

Because per-slot search state never crosses slots (the engine steps,
the predictor, and the interval updates are all per-slot), a query's
(topk_d, topk_i, ndis, ninserts) is independent of which host served
it — multi-host serving matches the single-controller server exactly
(tests/test_serving.py pins host counts {1, 2, 4}).

The server stays engine-agnostic through the Engine protocol: handing
it engines.sharded_ivf_engine / engines.sharded_hnsw_engine (or either
wrapped by engines.mutable_engine) changes nothing here — slot
compaction, splicing and the chunked driver operate on the replicated
search state, while the probe/beam data traffic stays on-shard. The one
state leaf that IS sharded (HNSW's visited bitmap, split on its node
dim) still has a leading slot dim, so _select_slots splicing works on
it unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import darth_search, engines as engines_lib
from repro.core.intervals import IntervalParams
from repro.core.predictor import RecallPredictor
from repro.obs import stats as obs_stats
from repro.obs import trace as obs_trace
from repro.utils import meshctx

PyTree = Any


@dataclasses.dataclass
class _ObsArrays:
    """Per-boundary device fetches the tracer needs at harvest, sliced
    per host by harvest_host: DARTH's early-stop mask and predictor
    call counts (termination-reason attribution) plus the trajectory
    ring with the engine-step count its columns are relative to
    (traj_base — the step count when the ring's chunk state was last
    rebuilt from scratch). All fetched at the SAME sync boundary the
    server already pays for the active mask: tracing adds no device
    round-trips."""
    early: Optional[np.ndarray] = None     # bool[nloc]
    npred: Optional[np.ndarray] = None     # i32[nloc]
    traj: Optional[np.ndarray] = None      # f32[nloc, traj_cap]
    traj_base: int = 0


def _select_slots(mask: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-slot tree select: where mask[b], take `new`, else `old`.
    Leaves without a leading slot dim are kept from `old`."""
    b = mask.shape[0]

    def sel(n, o):
        if hasattr(o, "ndim") and o.ndim >= 1 and o.shape[0] == b:
            m = mask.reshape((b,) + (1,) * (o.ndim - 1))
            return jnp.where(m, n, o)
        return o
    return jax.tree.map(sel, new, old)


@dataclasses.dataclass
class HostStats:
    """One host loop's counters (ServeStats aggregates these).

    Admission accounting is exhaustive: every query striped to a host
    is admitted (then completed or truncated), explicitly shed
    (shed_ids), or abandoned (its host died, or the step budget ran out
    before it left the queue) — nothing is silently dropped
    (tests/test_properties.py pins this under overload)."""
    host: int = 0
    admitted: int = 0            # queries that ever got a slot
    completed: int = 0
    slot_steps: int = 0
    refills: int = 0
    truncated: int = 0           # admitted, harvested with a partial top-k
    ndis_harvested: int = 0      # sum of harvested slots' ndis counters
    killed: bool = False         # fault injection: host died mid-serve
    abandoned: int = 0           # queued on this host, never admitted
    # difficulty-aware admission (serve.difficulty; all zero/empty when
    # the server runs untiered)
    shed: int = 0                # refused at admission (overload="shed")
    degraded: int = 0            # served at the lowered degrade_target
    hedged: int = 0              # hedge duplicates launched
    hedge_upgrades: int = 0      # results replaced by a deeper hedge
    hedge_epoch_dropped: int = 0  # hedges dropped at harvest because a
    #                               hot-swap landed between the primary's
    #                               harvest and the hedge's (the two ran
    #                               against different index versions)
    stolen: int = 0              # queries stolen INTO this host (rebalance)
    shed_ids: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    """Aggregate serve() outcome across all host loops."""
    completed: int = 0
    slot_steps: int = 0          # engine steps x slots (cost proxy)
    engine_steps: int = 0
    refills: int = 0
    truncated: int = 0           # in-flight queries harvested with a
    #                              partial top-k when max_engine_steps hit
    #                              (or their host was killed)
    ndis_harvested: int = 0      # sum of per-query ndis at harvest
    hosts: List[HostStats] = dataclasses.field(default_factory=list)
    # difficulty-aware admission totals (sums of the HostStats fields;
    # all zero when the server runs untiered)
    shed: int = 0
    degraded: int = 0
    hedged: int = 0
    hedge_upgrades: int = 0
    hedge_epoch_dropped: int = 0
    # hot-swaps (request_swap) applied at drained chunk boundaries
    # during this serve call
    swaps: int = 0
    # per-tier SLO metrics (serve.difficulty.TierStats, keyed "easy" /
    # "hard"); empty dict when the server runs untiered
    tiers: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # wall-clock percentiles over the per-chunk device round-trips
    # (run_chunk dispatch + the sync-boundary fetch), milliseconds;
    # NaN before any chunk ran
    chunk_ms_p50: float = float("nan")
    chunk_ms_p99: float = float("nan")


class _HostSlots:
    """One host's slice [lo, hi) of the slot pool.

    Owns admission, refill and harvest bookkeeping for its slots and ITS
    OWN query queue(s): every decision reads only the host's slice of
    the device state, so N of these run with no cross-host coordination
    — the only global synchronization in multi-host serving is the
    collectives inside the engine step itself. (Rebalance work stealing
    is driven by the server between chunk boundaries and only moves
    queue entries — never in-flight slot state.)

    With a difficulty TierConfig (serve.difficulty), admission becomes
    tier-aware: the tail `hard_frac` of the host's slots is reserved
    for hard-tier queries (work-conserving — either tier spills into
    the other's free slots once its own queue drains), hard queries are
    served at a boosted effective target, overload is degraded or shed
    at construction instead of queueing unboundedly, and idle hard
    slots can run hedged duplicates. With tiers=None every tier branch
    is inert and scheduling is the original single-FIFO behavior."""

    def __init__(self, host: int, lo: int, hi: int, queue: List[int],
                 queries: np.ndarray, r_targets: np.ndarray,
                 interval_for_target, results: List, *,
                 tiers=None, is_hard: Optional[np.ndarray] = None,
                 tracer: Optional[obs_trace.Tracer] = None,
                 epoch: int = 0, collect_samples: bool = False):
        self.host = host
        self.lo, self.hi = lo, hi
        self.queries = queries
        self.r_targets = r_targets
        self.interval_for_target = interval_for_target
        self.results = results
        self.tracer = tracer
        self.collect_samples = collect_samples
        nloc = hi - lo
        self.slot_query = np.full((nloc,), -1, np.int64)
        self.rt = np.zeros((nloc,), np.float32)
        self.ipi = np.zeros((nloc,), np.float32)
        self.mpi = np.zeros((nloc,), np.float32)
        self.alive = True
        self.stats = HostStats(host=host)

        self.tiers = tiers
        self.is_hard = is_hard
        self.admit_step = np.zeros((nloc,), np.int64)
        self.slot_hedge = np.zeros((nloc,), bool)
        # engine/predictor version each slot was admitted under
        # (DarthServer.engine_epoch at fill time) and the version each
        # stored result was computed against — a hedge may only upgrade
        # a result from its own epoch (no cross-version merges)
        self.slot_epoch = np.zeros((nloc,), np.int64)
        self.result_epoch: Dict[int, int] = {}
        self.hedge_winner: set = set()   # qids whose result came from a
        #                                  hedge while the primary ran
        # harvest-time SLO samples: (hard, r_pred, latency, truncated)
        self.samples: List[Tuple[bool, float, int, bool]] = []
        self.degraded_ids: List[int] = []
        self._degraded: set = set()
        if tiers is None:
            self.queue_easy: List[int] = list(queue)
            self.queue_hard: List[int] = []
            self.easy_slots = nloc
            return

        # hard-tier slot partition: local slots [easy_slots, nloc)
        self.easy_slots = nloc - int(round(tiers.hard_slot_fraction * nloc))

        # admission control: bound the queue, degrade or shed overflow
        queue = list(queue)
        if tiers.max_queue is not None and len(queue) > tiers.max_queue:
            if tiers.overload == "shed":
                excess = len(queue) - tiers.max_queue
                # shed from the arrival tail, hard tier first (priority:
                # the expensive queries are refused before cheap ones)
                tail = ([q for q in reversed(queue) if is_hard[q]]
                        + [q for q in reversed(queue) if not is_hard[q]])
                drop = set(tail[:excess])
                self.stats.shed_ids = [q for q in queue if q in drop]
                self.stats.shed = len(self.stats.shed_ids)
                queue = [q for q in queue if q not in drop]
                if tracer is not None:
                    for qid in self.stats.shed_ids:
                        tracer.terminal(
                            qid, "shed", host=host, step=0, epoch=epoch,
                            target=float(self.r_targets[qid]),
                            tier=self._tier_of(qid))
            else:                           # degrade-to-lower-target
                for qid in queue[tiers.max_queue:]:
                    if tiers.degrade_target < self.r_targets[qid]:
                        declared = float(self.r_targets[qid])
                        self.r_targets[qid] = tiers.degrade_target
                        self.stats.degraded += 1
                        self.degraded_ids.append(qid)
                        self._degraded.add(qid)
                        if tracer is not None:
                            tracer.event(
                                "degrade", qid=qid, host=host, step=0,
                                epoch=epoch, declared=declared,
                                degraded_to=float(tiers.degrade_target))
        self.queue_easy = [q for q in queue if not is_hard[q]]
        self.queue_hard = [q for q in queue if is_hard[q]]

    @property
    def occupied(self) -> np.ndarray:
        """bool[nloc]: slots currently holding an in-flight query."""
        return self.slot_query >= 0

    @property
    def pending(self) -> int:
        """Queued-but-unadmitted query count (both tiers)."""
        return len(self.queue_easy) + len(self.queue_hard)

    def _tier_of(self, qid: int) -> Optional[str]:
        """Difficulty-tier label for trace spans (None when untiered)."""
        if self.tiers is None or self.is_hard is None:
            return None
        return "hard" if self.is_hard[qid] else "easy"

    def _target_for(self, qid: int) -> float:
        """Effective recall target: declared (possibly degraded at
        admission control), plus the hard-tier boost — clipped to 0.99
        and never below the declared target."""
        rt = float(self.r_targets[qid])
        if (self.tiers is not None and self.is_hard[qid]
                and self.tiers.boost > 0.0):
            rt = max(rt, min(rt + self.tiers.boost, 0.99))
        return rt

    def fill(self, free: np.ndarray, step: int = 0, epoch: int = 0
             ) -> Tuple[np.ndarray, np.ndarray]:
        """Admit queued queries into the local `free` slots; updates the
        host's rt/ipi/mpi slices in place and returns (mask bool[nloc],
        qb f32[nloc, D]) for the splice — mask all-False when nothing
        was admitted.

        Tiered admission fills each partition from its own queue first
        (easy slots from the easy FIFO, reserved hard slots from the
        hard FIFO), then spills the leftover free slots to the other
        tier's queue so no slot idles while any query waits. With idle
        hard slots and nothing queued, hedging (TierConfig.hedge)
        launches duplicates of the oldest in-flight hard queries at a
        hedge_boost-raised target. `step` is the current engine-step
        count, recorded per slot for the latency percentiles; `epoch`
        is the server's engine_epoch, stamped per slot so harvest can
        refuse to merge results computed against different index /
        predictor versions (hot-swap mid-flight)."""
        nloc = self.hi - self.lo
        qb = np.zeros((nloc, self.queries.shape[1]), np.float32)
        mask = np.zeros((nloc,), bool)
        free = [int(s) for s in free]
        pairs: List[Tuple[int, int]] = []       # (slot, qid)
        if self.tiers is None:
            ids = [self.queue_easy.pop(0)
                   for _ in range(min(len(free), len(self.queue_easy)))]
            pairs = list(zip(free, ids))
        else:
            free_easy = [s for s in free if s < self.easy_slots]
            free_hard = [s for s in free if s >= self.easy_slots]
            for slots, own, other in ((free_easy, self.queue_easy,
                                       self.queue_hard),
                                      (free_hard, self.queue_hard,
                                       self.queue_easy)):
                for s in list(slots):
                    q = own or other            # own tier first, then spill
                    if not q:
                        break
                    pairs.append((s, q.pop(0)))
                    slots.remove(s)
            hedges = (self._plan_hedges(free_hard, len(pairs))
                      if self.tiers.hedge else [])
        if not pairs and not (self.tiers is not None and self.tiers.hedge
                              and hedges):
            return mask, qb
        rt2 = self.rt.copy()
        for s, qid in pairs:
            mask[s] = True
            qb[s] = self.queries[qid]
            rt2[s] = self._target_for(qid)
            self.slot_query[s] = qid
            self.slot_hedge[s] = False
            self.admit_step[s] = step
            self.slot_epoch[s] = epoch
            if self.tracer is not None:
                self.tracer.event(
                    "admit", qid=qid, host=self.host, step=step,
                    epoch=epoch, slot=int(self.lo + s),
                    target=float(self.r_targets[qid]),
                    effective_target=float(rt2[s]),
                    tier=self._tier_of(qid), refill=step > 0)
        if self.tiers is not None and self.tiers.hedge:
            for s, qid in hedges:
                mask[s] = True
                qb[s] = self.queries[qid]
                rt2[s] = max(self._target_for(qid),
                             min(self._target_for(qid)
                                 + self.tiers.hedge_boost, 0.99))
                self.slot_query[s] = qid
                self.slot_hedge[s] = True
                self.admit_step[s] = step
                self.slot_epoch[s] = epoch
                self.stats.hedged += 1
                if self.tracer is not None:
                    self.tracer.event(
                        "admit", qid=qid, host=self.host, step=step,
                        epoch=epoch, slot=int(self.lo + s),
                        target=float(self.r_targets[qid]),
                        effective_target=float(rt2[s]),
                        tier=self._tier_of(qid), hedge=True)
        ip = self.interval_for_target(rt2)
        ipi2 = np.broadcast_to(np.asarray(ip.ipi, np.float32), (nloc,))
        mpi2 = np.broadcast_to(np.asarray(ip.mpi, np.float32), (nloc,))
        self.ipi = np.where(mask, ipi2, self.ipi)
        self.mpi = np.where(mask, mpi2, self.mpi)
        self.rt = np.where(mask, rt2, self.rt)
        self.stats.admitted += len(pairs)
        return mask, qb

    def _plan_hedges(self, free_hard: List[int], admitted: int
                     ) -> List[Tuple[int, int]]:
        """Hedge targets for leftover free hard slots: the oldest
        in-flight hard-tier primaries without a hedge yet. Only fires
        when the queues are fully drained (idle capacity, per the
        TierConfig.hedge contract)."""
        if admitted or self.pending or not free_hard:
            return []
        occ = self.occupied & ~self.slot_hedge
        hedged_qids = set(self.slot_query[self.slot_hedge
                                          & self.occupied].tolist())
        cands = [(int(self.admit_step[s]), int(self.slot_query[s]))
                 for s in np.nonzero(occ)[0]
                 if self.is_hard[self.slot_query[s]]
                 and int(self.slot_query[s]) not in hedged_qids]
        cands.sort()
        return list(zip(free_hard, [qid for _, qid in cands]))

    def _terminal_attrs(self, s: int, qid: int, ndis: np.ndarray,
                        r_pred: Optional[np.ndarray],
                        obs: Optional[_ObsArrays], step: int) -> Dict:
        """Terminal-span payload for local slot ``s`` holding ``qid``:
        targets, tier, counters and the drained trajectory window."""
        attrs: Dict[str, Any] = {
            "target": float(self.r_targets[qid]),
            "effective_target": float(self.rt[s]),
            "admit_step": int(self.admit_step[s]),
            "ndis": int(ndis[s]),
            "slot": int(self.lo + s),
        }
        tier = self._tier_of(qid)
        if tier is not None:
            attrs["tier"] = tier
        if qid in self._degraded:
            attrs["degraded"] = True
        if bool(self.slot_hedge[s]):
            attrs["hedge"] = True
        if r_pred is not None:
            attrs["r_pred"] = float(r_pred[s])
        if obs is not None:
            if obs.npred is not None:
                attrs["npred"] = int(obs.npred[s])
            if obs.traj is not None:
                traj, trunc = obs_trace.traj_window(
                    obs.traj[s], int(self.admit_step[s]), step,
                    obs.traj_base)
                attrs["trajectory"] = traj
                if trunc:
                    attrs["trajectory_truncated"] = True
        return attrs

    def harvest(self, mask: np.ndarray, topk_d: np.ndarray,
                topk_i: np.ndarray, ndis: np.ndarray, *,
                truncated: bool = False, step: int = 0,
                r_pred: Optional[np.ndarray] = None,
                reason: Optional[str] = None,
                obs: Optional[_ObsArrays] = None) -> int:
        """Pull the masked local slots' top-k into results; free the
        slots. The array arguments are the host's SLICE [nloc, ..] of
        the device state. Raises if a slot's query already has a result
        — every admitted query must be returned exactly once. The one
        sanctioned exception is a hedge duplicate (TierConfig.hedge):
        its primary already returned, so a naturally-completed hedge
        UPGRADES the stored result (deeper search at a raised target)
        and a truncated hedge is dropped — either way the query still
        has exactly one result. An upgrade additionally requires the
        hedge's admission epoch to match the stored result's epoch: a
        hot-swap between the primary's harvest and the hedge's means
        the pair searched two different index versions, and replacing
        one with the other would attribute a single hedge_winner to two
        versions — such a hedge is dropped (hedge_epoch_dropped)."""
        count = 0
        trunc_reason = reason or "budget_truncated"
        for s in np.nonzero(mask)[0]:
            qid = int(self.slot_query[s])
            if self.results[qid] is not None:
                # the qid already returned: only legitimate for a hedge
                # pair — the hedge arriving second upgrades (unless
                # truncated or from a different epoch), a primary whose
                # hedge won just frees
                if self.slot_hedge[s]:
                    if not truncated:
                        if (int(self.slot_epoch[s])
                                == self.result_epoch.get(qid)):
                            self.results[qid] = (topk_d[s], topk_i[s])
                            self.result_epoch[qid] = int(self.slot_epoch[s])
                            self.stats.ndis_harvested += int(ndis[s])
                            self.stats.hedge_upgrades += 1
                            if self.tracer is not None:
                                self.tracer.upgrade_terminal(
                                    qid, step=step,
                                    **self._terminal_attrs(
                                        s, qid, ndis, r_pred, obs, step))
                        else:
                            self.stats.hedge_epoch_dropped += 1
                            if self.tracer is not None:
                                self.tracer.event(
                                    "hedge_drop", qid=qid, host=self.host,
                                    step=step,
                                    epoch=int(self.slot_epoch[s]),
                                    cause="epoch")
                    elif self.tracer is not None:
                        self.tracer.event(
                            "hedge_drop", qid=qid, host=self.host,
                            step=step, epoch=int(self.slot_epoch[s]),
                            cause="truncated")
                    self.slot_query[s] = -1
                    self.slot_hedge[s] = False
                    continue
                if qid in self.hedge_winner:
                    self.hedge_winner.discard(qid)
                    self.slot_query[s] = -1
                    if self.tracer is not None:
                        self.tracer.event(
                            "hedge_primary_freed", qid=qid,
                            host=self.host, step=step,
                            epoch=int(self.slot_epoch[s]))
                    continue
                raise RuntimeError(
                    f"host {self.host}: query {qid} harvested twice")
            if self.slot_hedge[s] and truncated:
                # truncated hedge whose primary is still in flight: drop
                # it — the primary (admitted earlier, so deeper) is
                # harvested in this same truncation sweep
                self.slot_query[s] = -1
                self.slot_hedge[s] = False
                if self.tracer is not None:
                    self.tracer.event(
                        "hedge_drop", qid=qid, host=self.host, step=step,
                        epoch=int(self.slot_epoch[s]), cause="truncated")
                continue
            self.results[qid] = (topk_d[s], topk_i[s])
            self.result_epoch[qid] = int(self.slot_epoch[s])
            self.stats.ndis_harvested += int(ndis[s])
            if self.tracer is not None:
                if truncated:
                    term_reason = trunc_reason
                elif obs is not None and obs.early is not None:
                    term_reason = ("interval_met" if bool(obs.early[s])
                                   else "engine_exhausted")
                else:
                    term_reason = "interval_met"
                self.tracer.terminal(
                    qid, term_reason, host=self.host, step=step,
                    epoch=int(self.slot_epoch[s]),
                    **self._terminal_attrs(s, qid, ndis, r_pred, obs,
                                           step))
            if self.slot_hedge[s]:
                # hedge finished before (or with) its primary: its
                # deeper result wins; the primary frees via hedge_winner
                self.hedge_winner.add(qid)
                self.stats.hedge_upgrades += 1
            if self.tiers is not None or self.collect_samples:
                self.samples.append((
                    bool(self.is_hard[qid])
                    if self.is_hard is not None else False,
                    float(r_pred[s]) if r_pred is not None else float("nan"),
                    int(step - self.admit_step[s]), truncated))
            self.slot_query[s] = -1
            self.slot_hedge[s] = False
            count += 1
        if truncated:
            self.stats.truncated += count
        else:
            self.stats.completed += count
        return count

    def kill(self, *, step: int = 0, epoch: int = 0) -> None:
        """Fault injection: this host's slot slice dies. Its queue is
        abandoned (those queries stay None — they were never admitted,
        so there is no state to harvest); the caller harvests the
        in-flight slots first so every ADMITTED query still returns.
        Each abandoned queue entry gets a terminal trace span (reason
        ``abandoned``, cause ``host_killed``)."""
        self.alive = False
        self.stats.killed = True
        self.stats.abandoned = self.pending
        if self.tracer is not None:
            for qid in self.queue_easy + self.queue_hard:
                self.tracer.terminal(
                    qid, "abandoned", host=self.host, step=step,
                    epoch=epoch, cause="host_killed",
                    target=float(self.r_targets[qid]),
                    tier=self._tier_of(qid))
        self.queue_easy = []
        self.queue_hard = []


def _finalize_tiers(hostslots: List[_HostSlots], is_hard: np.ndarray
                    ) -> Dict[str, Any]:
    """Fold the host loops' SLO samples into per-tier TierStats.

    recall_p99 is the 1st percentile of harvest-time predicted recall
    (the recall the worst 1% of the tier got); latency percentiles are
    over engine steps from admission to harvest. Shed/degraded counts
    are attributed to tiers via their recorded query ids; hedges only
    ever duplicate hard-tier queries, so they land on the hard tier."""
    from repro.serve.difficulty import TierStats

    out: Dict[str, Any] = {}
    for name, hard in (("easy", False), ("hard", True)):
        ts = TierStats()
        ts.count = int(np.sum(is_hard == hard))
        rp: List[float] = []
        lat: List[int] = []
        for hl in hostslots:
            for h, r, steps, trunc in hl.samples:
                if h != hard:
                    continue
                if trunc:
                    ts.truncated += 1
                else:
                    ts.completed += 1
                if np.isfinite(r):
                    rp.append(r)
                lat.append(steps)
            ts.shed += sum(1 for q in hl.stats.shed_ids
                           if bool(is_hard[q]) == hard)
            ts.degraded += sum(1 for q in hl.degraded_ids
                               if bool(is_hard[q]) == hard)
            if hard:
                ts.hedged += hl.stats.hedged
                ts.hedge_upgrades += hl.stats.hedge_upgrades
        if rp:
            ts.recall_p50 = obs_stats.p50(rp)
            ts.recall_p99 = obs_stats.p01(rp)
        if lat:
            ts.latency_p50 = obs_stats.p50(lat)
            ts.latency_p99 = obs_stats.p99(lat)
        out[name] = ts
    return out


class DarthServer:
    """Continuous-batching declarative-recall search server.

    Queries stream through a fixed pool of device slots: each slot runs
    one query's darth_search at that query's own declared recall target,
    early-terminated slots are harvested and re-spliced at chunk (sync)
    boundaries, and the jitted chunks step all slots as one SPMD
    program. See the module docstring for the multi-host topology and
    serve.difficulty for the optional difficulty-tier scheduling layer
    (`tiers`)."""

    def __init__(self, engine: engines_lib.Engine,
                 predictor: RecallPredictor,
                 interval_for_target,        # fn: r_t array -> IntervalParams
                 num_slots: int = 64, steps_per_sync: int = 4,
                 mesh=None, hosts: int = 1, tiers=None,
                 tracer: Optional[obs_trace.Tracer] = None,
                 metrics=None, rerank=None):
        from repro.obs import metrics as obs_metrics
        self.engine = engine
        # Optional exact re-rank hook (index.residency.RerankStore.rerank
        # or compatible (q, ids) -> (d, i) callable), applied to every
        # completed result after the serve loop: the engine searches the
        # compact SQ8-resident index at an over-provisioned k and the
        # hook restores exact f32 distances/order for the final top-k.
        self.rerank = rerank
        self.predictor = predictor
        self.interval_for_target = interval_for_target
        self.num_slots = num_slots
        self.steps_per_sync = steps_per_sync
        if hosts < 1 or num_slots % hosts:
            raise ValueError(
                f"num_slots {num_slots} must split evenly over "
                f"{hosts} hosts")
        self.hosts = hosts
        # Difficulty-aware admission/scheduling policy
        # (serve.difficulty.TierConfig); None serves every query
        # identically (the original scheduling).
        self.tiers = tiers
        # When the engine's index was placed on a mesh (dist.place_index),
        # the slot-pool chunks run SPMD over it; use_mesh also activates
        # the activation constraints inside any model-side feature code.
        # A mesh with a "hosts" axis additionally splits the slot dim of
        # the chunk inputs over host groups (make_serve_mesh).
        self.mesh = mesh
        # Engine/predictor version counter: bumped by every hot-swap
        # (set_engine / set_predictor, direct or via request_swap).
        # Slots are stamped with it at admission so harvest can
        # attribute every result to exactly one version.
        self.engine_epoch = 0
        # Staged request_swap payload, applied at the next drained chunk
        # boundary (or immediately when not serving).
        self._pending_swap: Optional[Tuple] = None
        self._serving = False
        # Observability (repro.obs): a Tracer makes the chunk jits carry
        # the per-slot predicted-recall trajectory ring (fixed shape —
        # the traced chunks are a different program, built once here)
        # and the host loops emit lifecycle spans; a MetricsRegistry
        # aggregates counters/histograms per serve call. Both optional,
        # zero cost when None.
        self.tracer = tracer
        self.metrics = obs_metrics.serve_metrics(metrics)
        # engine-step count at the most recent chunk boundary of the
        # serve in progress — lets on_boundary hooks stamp the trace
        # events they emit (compaction begin/tick/swap)
        self.boundary_step = 0
        # In-flight pool search state at the most recent chunk boundary
        # (None outside serve / right after a swap): on_boundary hooks
        # that plan ahead of the engine read it — serve.cold's prefetch
        # walks each slot's remaining IVF probe order through it. Device
        # arrays; hooks fetch the small fields they need.
        self.chunk_state = None

        self._build_chunks()

    def _build_chunks(self) -> None:
        """(Re)build the jitted chunk functions around the current
        engine + predictor (called from __init__ and from the hot-swap
        paths; a rebuild recompiles, so predictor swaps pay one compile
        — the drift-recalibration cadence makes that negligible)."""
        # Capture the engine WITHOUT its index: the index is threaded
        # through the chunks as an argument anyway, and a captured copy
        # would pin the build-time index buffers in device memory for
        # the server's lifetime across contents_only engine swaps.
        eng = self.engine._replace(index=None)
        pred = self.predictor
        steps_per_sync = self.steps_per_sync
        mesh = self.mesh
        num_slots = self.num_slots

        def pin(st):
            # Pin the per-slot chunk state host-local on a "hosts" mesh
            # (dist.sharding.constrain_slots): applied at the fori_loop
            # carry boundaries so GSPMD keeps the whole carry split over
            # host groups instead of resolving it to replicated and
            # re-gathering the slot bookkeeping across hosts each step.
            if mesh is not None and "hosts" in mesh.axis_names:
                from repro.dist import sharding as sharding_lib
                return sharding_lib.constrain_slots(st, mesh, num_slots)
            return st

        # The engine's index enters these outer jits as an ARGUMENT
        # (re-bound via _replace so the protocol's init/step see the
        # traced value): a closure-captured index would be baked in as a
        # replicated constant, silently undoing dist.place_index for
        # sharded engines.
        if self.tracer is None:
            @jax.jit
            def run_chunk(index, st: darth_search.DarthState,
                          r_t: jax.Array, ipi: jax.Array, mpi: jax.Array):
                body = darth_search.make_darth_body(
                    eng._replace(index=index), pred,
                    IntervalParams(ipi=ipi, mpi=mpi), r_t)

                def do(i, s):
                    return pin(body(s))
                return jax.lax.fori_loop(0, steps_per_sync, do, pin(st))

            @jax.jit
            def init_chunk(index, q: jax.Array, ipi: jax.Array,
                           mpi: jax.Array):
                # Pass the REAL per-slot mpi through: init only reads
                # ipi today, but IntervalParams(mpi=ipi) would silently
                # lie to any future reader of params.mpi at init time.
                return darth_search.init_darth_state(
                    eng._replace(index=index), q,
                    IntervalParams(ipi=ipi, mpi=mpi))
        else:
            # Traced chunks: same programs, with the predicted-recall
            # trajectory ring riding the fori_loop carry. The ring's
            # shape is fixed ([slots, traj_cap]) and its write is a
            # dynamic-index .at[].set — no extra retraces, no host
            # syncs; the host drains it only at the boundaries where
            # serve() already fetches the active mask. Its leading slot
            # dim means pin() splits it over host groups like the rest
            # of the carry.
            traj_cap = self.tracer.traj_cap

            @jax.jit
            def run_chunk(index, st: darth_search.DarthState,
                          traj: jax.Array, r_t: jax.Array,
                          ipi: jax.Array, mpi: jax.Array):
                body = darth_search.make_darth_body(
                    eng._replace(index=index), pred,
                    IntervalParams(ipi=ipi, mpi=mpi), r_t)

                def do(i, carry):
                    s, tr = carry
                    s = body(s)
                    return pin((s, obs_trace.traj_record(
                        tr, s.steps, s.r_pred)))
                return jax.lax.fori_loop(0, steps_per_sync, do,
                                         pin((st, traj)))

            @jax.jit
            def init_chunk(index, q: jax.Array, ipi: jax.Array,
                           mpi: jax.Array):
                st = darth_search.init_darth_state(
                    eng._replace(index=index), q,
                    IntervalParams(ipi=ipi, mpi=mpi))
                return st, obs_trace.traj_init(q.shape[0], traj_cap)

        @jax.jit
        def splice(mask, new_st, old_st):
            return _select_slots(mask, new_st, old_st)

        self._run_chunk = run_chunk
        self._init_chunk = init_chunk
        self._splice = splice

    # -- hot swap (streaming mutations / drift recalibration) --------------
    def set_predictor(self, predictor: RecallPredictor) -> None:
        """Swap a refit recall predictor into the running server (the
        drift monitor's hot-swap path). Rebuilds the chunk jits and
        bumps engine_epoch — in-flight slots keep their admission
        stamp, so a hedge pair spanning the swap can never merge."""
        self.predictor = predictor
        self.engine_epoch += 1
        self._build_chunks()

    def set_engine(self, engine: engines_lib.Engine, *,
                   contents_only: bool = False) -> None:
        """Swap an updated engine in (delta writes, tombstones, or a
        compacted base).

        contents_only=True asserts that ONLY the index contents changed
        (same engine family and constructor params — k, nprobe/ef, ...):
        the existing chunk jits are kept, because the index crosses them
        as an argument and the old closures remain valid; no recompile.
        The flag is explicit because name/k/max_steps cannot distinguish
        e.g. two hnsw engines with different ef but an identical
        explicit max_steps — defaulting to reuse would silently keep
        serving with the old params. The default rebuilds.

        Safe to call mid-serve (from an on_boundary callback) for DELTA
        refreshes — ring writes/tombstones leave the base arrays
        untouched or monotonically masked, and in-flight slots carry a
        frozen delta snapshot, so they drain correctly against the old
        view. A swap that REPLACES the base object (a compacted shadow)
        must instead go through request_swap, which drains the pool
        first. Bumps engine_epoch either way."""
        if contents_only and (engine.name != self.engine.name
                              or engine.k != self.engine.k
                              or engine.max_steps != self.engine.max_steps):
            raise ValueError(
                f"contents_only swap changed the engine protocol: "
                f"{self.engine.name}/k={self.engine.k}/"
                f"max_steps={self.engine.max_steps} -> {engine.name}/"
                f"k={engine.k}/max_steps={engine.max_steps}")
        self.engine = engine
        self.engine_epoch += 1
        if not contents_only:
            self._build_chunks()

    def request_swap(self, engine: Optional[engines_lib.Engine] = None,
                     predictor: Optional[RecallPredictor] = None, *,
                     contents_only: bool = True) -> None:
        """Stage an engine and/or predictor hot-swap for the next SAFE
        chunk boundary — the atomic half of the double-buffered view
        lifecycle. While the swap is pending the server stops admitting
        new queries and lets in-flight slots drain against their
        admission-epoch view (the pool KEEPS STEPPING — this is a
        drain, not a pause); once no slot is occupied the swap applies
        atomically between two chunks and admissions resume against the
        new view, rebuilt state and all. Use this for a compacted
        shadow base (the base OBJECT is replaced, so shapes may change
        mid-serve) or a predictor refit; pure delta-contents refreshes
        don't need the drain — call set_engine(contents_only=True)
        directly. Outside serve() the swap applies immediately."""
        if engine is None and predictor is None:
            raise ValueError("request_swap needs an engine, a predictor "
                             "or both")
        if self._pending_swap is not None:
            raise RuntimeError("a hot-swap is already pending")
        self._pending_swap = (engine, predictor, contents_only)
        if not self._serving:
            self._apply_pending_swap()

    @property
    def swap_pending(self) -> bool:
        """True while a request_swap is staged but not yet applied."""
        return self._pending_swap is not None

    def _apply_pending_swap(self) -> None:
        """Apply the staged swap (only at a drained boundary, or when
        not serving)."""
        engine, predictor, contents_only = self._pending_swap
        self._pending_swap = None
        if engine is not None:
            self.set_engine(engine, contents_only=contents_only)
        if predictor is not None:
            self.set_predictor(predictor)

    # -- device placement ---------------------------------------------------
    def _put(self, arr: np.ndarray) -> jax.Array:
        """Per-chunk input onto the device(s): on a mesh with a "hosts"
        axis the leading slot dim splits over host groups
        (dist.sharding slot-dim specs); otherwise a plain transfer."""
        if self.mesh is not None and "hosts" in self.mesh.axis_names:
            from repro.dist import sharding as sharding_lib
            sh = sharding_lib.slot_sharding(self.mesh, self.num_slots,
                                            trailing=arr.ndim - 1)
            return jax.device_put(jnp.asarray(arr), sh)
        return jnp.asarray(arr)

    def serve(self, queries: np.ndarray, r_targets: np.ndarray,
              max_engine_steps: int = 100_000,
              kill_hosts: Optional[Dict[int, int]] = None,
              on_boundary=None,
              ) -> Tuple[List[Optional[Tuple[np.ndarray, np.ndarray]]],
                         ServeStats]:
        """Process all queries; returns per-query (dists, ids) + stats.

        `kill_hosts` is fault injection for the multi-host topology:
        {host_id: engine_step} kills that host's slot slice at the first
        sync boundary past the given engine step — slots that finished
        at that boundary count completed, in-flight slots are harvested
        (partial top-k, counted as truncated) so every admitted query
        still returns exactly once, and its remaining queue is
        abandoned (those results stay None).

        `on_boundary(server)` is invoked once per chunk boundary,
        between harvest and refill — the hook where streaming mutations
        push delta refreshes (set_engine contents_only), background
        compaction runs its budgeted ticks (MutableIndex.compact_tick),
        and finished shadows are staged for the drained atomic swap
        (request_swap). It runs on the host while the devices idle at
        the sync point, so its budget is one tick's worth of work."""
        from repro.core import api as api_lib

        queries = np.asarray(queries, np.float32)
        if queries.ndim != 2:
            raise ValueError(
                f"queries must be [N, D], got shape {queries.shape}")
        r_targets = np.asarray(r_targets, np.float32)
        if r_targets.shape != (queries.shape[0],):
            raise ValueError(
                f"r_targets shape {r_targets.shape} does not match the "
                f"{queries.shape[0]} queries: the server needs one "
                f"declared recall target per query")
        r_targets = api_lib.validate_targets(r_targets, queries.shape[0])
        ctx = (meshctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            self._serving = True
            try:
                return self._serve(queries, r_targets, max_engine_steps,
                                   kill_hosts or {}, on_boundary)
            finally:
                self._serving = False
                self.chunk_state = None

    def _serve(self, queries: np.ndarray, r_targets: np.ndarray,
               max_engine_steps: int, kill_hosts: Dict[int, int],
               on_boundary=None,
               ) -> Tuple[List[Optional[Tuple[np.ndarray, np.ndarray]]],
                          ServeStats]:
        import time

        tr = self.tracer
        mets = self.metrics
        if tr is not None:
            tr.begin()

        # a swap left pending by a previous serve call (budget ran out
        # mid-drain): the pool is empty now, apply before admitting
        if self._pending_swap is not None:
            self._apply_pending_swap()

        n, d = queries.shape
        b = self.num_slots
        sph = b // self.hosts
        stats = ServeStats()
        results: List[Optional[Tuple[np.ndarray, np.ndarray]]] = [None] * n

        # Difficulty classification at admission: one host-side routing
        # scan over the whole batch (serve.difficulty), before any query
        # touches a slot. r_targets is copied because admission control
        # may degrade targets in place.
        is_hard = None
        if self.tiers is not None:
            from repro.serve import difficulty as difficulty_lib
            scores = difficulty_lib.difficulty_scores(self.engine.index,
                                                      queries)
            is_hard = difficulty_lib.assign_tiers(scores, self.tiers)
            r_targets = r_targets.copy()

        # Striped query partition: host h owns queries h, h+H, h+2H, ...
        # (hosts == 1 degrades to the single-controller FIFO). Each host
        # loop owns slots [h*sph, (h+1)*sph) and only ever touches them.
        hostslots = [
            _HostSlots(h, h * sph, (h + 1) * sph,
                       list(range(h, n, self.hosts)), queries, r_targets,
                       self.interval_for_target, results,
                       tiers=self.tiers, is_hard=is_hard, tracer=tr,
                       epoch=self.engine_epoch,
                       collect_samples=mets is not None)
            for h in range(self.hosts)]
        stats.hosts = [hl.stats for hl in hostslots]
        chunk_ms: List[float] = []

        def gather_inputs():
            rt = np.concatenate([hl.rt for hl in hostslots])
            ipi = np.concatenate([hl.ipi for hl in hostslots])
            mpi = np.concatenate([hl.mpi for hl in hostslots])
            return rt, ipi, mpi

        def occupied_global():
            return np.concatenate([hl.occupied for hl in hostslots])

        def state_slices():
            """Host-side copies of the per-slot device outputs every host
            loop harvests from (one transfer, then pure local slicing).
            r_pred (the predictor's recall estimate at harvest) is only
            fetched when the tier SLO stats, metrics, or tracer need it;
            the tracer additionally drains the early mask, predictor
            counts, and the trajectory ring AT THIS SAME boundary — no
            extra sync points."""
            topk_d = np.asarray(jax.device_get(
                self.engine.topk_d(st.inner)))
            topk_i = np.asarray(jax.device_get(
                self.engine.topk_i(st.inner)))
            ndis = np.asarray(jax.device_get(st.inner.ndis))
            need_rp = (self.tiers is not None or tr is not None
                       or mets is not None)
            r_pred = (np.asarray(jax.device_get(st.r_pred))
                      if need_rp else None)
            obs = None
            if tr is not None:
                obs = _ObsArrays(
                    early=np.asarray(jax.device_get(st.early)),
                    npred=np.asarray(jax.device_get(st.npred)),
                    traj=np.asarray(jax.device_get(traj)),
                    traj_base=traj_base)
            return topk_d, topk_i, ndis, r_pred, obs

        def harvest_host(hl: _HostSlots, mask_local: np.ndarray,
                         arrays, *, truncated: bool = False,
                         reason: Optional[str] = None) -> int:
            topk_d, topk_i, ndis, r_pred, obs = arrays
            sl = slice(hl.lo, hl.hi)
            obs_loc = None
            if obs is not None:
                obs_loc = _ObsArrays(
                    early=obs.early[sl], npred=obs.npred[sl],
                    traj=obs.traj[sl], traj_base=obs.traj_base)
            return hl.harvest(mask_local, topk_d[sl], topk_i[sl], ndis[sl],
                              truncated=truncated,
                              step=stats.engine_steps,
                              r_pred=None if r_pred is None else r_pred[sl],
                              reason=reason, obs=obs_loc)

        # initial fill: every host admits into all of its slots
        fills = [hl.fill(np.arange(sph), step=0, epoch=self.engine_epoch)
                 for hl in hostslots]
        qb = np.concatenate([f[1] for f in fills])
        rt, ipi, mpi = gather_inputs()
        traj = None
        traj_base = 0          # engine_steps at the ring's last rebuild
        if tr is None:
            st = self._init_chunk(self.engine.index, self._put(qb),
                                  self._put(ipi), self._put(mpi))
        else:
            st, traj = self._init_chunk(self.engine.index, self._put(qb),
                                        self._put(ipi), self._put(mpi))
        # slots with no query: deactivate
        occupied = occupied_global()
        st = dataclasses.replace(
            st, inner=engines_lib.set_active(
                st.inner, st.inner.active & self._put(occupied)))
        rt_dev = self._put(rt)

        while True:
            t0 = time.perf_counter()
            if tr is None:
                st = self._run_chunk(self.engine.index, st, rt_dev,
                                     self._put(ipi), self._put(mpi))
            else:
                st, traj = self._run_chunk(self.engine.index, st, traj,
                                           rt_dev, self._put(ipi),
                                           self._put(mpi))
            stats.engine_steps += self.steps_per_sync
            for hl in hostslots:
                hl.stats.slot_steps += (self.steps_per_sync
                                        * int(hl.occupied.sum()))
            # fault injection: kill the named hosts at this sync boundary
            dying = [hl for hl in hostslots
                     if hl.alive and hl.host in kill_hosts
                     and stats.engine_steps >= kill_hosts[hl.host]]
            active = np.asarray(jax.device_get(st.inner.active))
            # chunk wall time: dispatch + the sync-boundary fetch that
            # forces the device round-trip
            chunk_ms.append((time.perf_counter() - t0) * 1e3)
            finished = occupied & ~active
            arrays = (state_slices()
                      if finished.any() or dying else None)
            changed = False
            for hl in dying:
                # slots that finished at this very boundary hold a full
                # top-k: they completed, only the still-running slots
                # are truncated — then harvest those too, so no
                # admitted query is dropped
                sl = slice(hl.lo, hl.hi)
                fin_local = hl.occupied & ~active[sl]
                if fin_local.any():
                    harvest_host(hl, fin_local, arrays)
                if hl.occupied.any():
                    harvest_host(hl, hl.occupied, arrays, truncated=True,
                                 reason="host_killed")
                hl.kill(step=stats.engine_steps, epoch=self.engine_epoch)
                changed = True
            if finished.any():
                for hl in hostslots:
                    if not hl.alive:
                        continue
                    sl = slice(hl.lo, hl.hi)
                    fin_local = hl.occupied & ~active[sl]
                    if fin_local.any():
                        harvest_host(hl, fin_local, arrays)
                        changed = True
            # chunk boundary: mutation / compaction hook, then the
            # drained atomic swap — the pool is retargeted only when NO
            # slot is in flight, so every admitted query runs start to
            # finish against one index version (its admission epoch)
            self.boundary_step = stats.engine_steps
            self.chunk_state = st
            if on_boundary is not None:
                swap_was_pending = self._pending_swap is not None
                on_boundary(self)
                if (tr is not None and not swap_was_pending
                        and self._pending_swap is not None):
                    tr.event("swap_staged", step=stats.engine_steps,
                             epoch=self.engine_epoch)
            if (self._pending_swap is not None
                    and not any(hl.occupied.any() for hl in hostslots)):
                self._apply_pending_swap()
                stats.swaps += 1
                if tr is not None:
                    tr.event("swap_applied", step=stats.engine_steps,
                             epoch=self.engine_epoch)
                # chunk state was built against the OLD index (shapes
                # may differ — e.g. HNSW visited rows grow at
                # compaction); force a full init rebuild at the refill
                st = None
                self.chunk_state = None
                traj = None
                changed = False
                occupied = occupied_global()
            # per-host refill — unless the step budget is already
            # exhausted: a query spliced in now would run zero steps
            # and be harvested below as init-state junk (ids -1)
            # instead of staying None in the queue. (Without tiering a
            # host only has free slots right after a harvest, so this is
            # a no-op scan on boundaries where nothing finished; with
            # rebalance/hedging enabled idle capacity can also appear
            # between harvests, so the refill runs every boundary.)
            # While a swap is pending, admissions pause: already-running
            # slots drain against their pinned epoch, new queries wait
            # for the new index.
            if (stats.engine_steps < max_engine_steps
                    and self._pending_swap is None):
                if self.tiers is not None and self.tiers.rebalance:
                    self._rebalance(hostslots, step=stats.engine_steps)
                hedging = self.tiers is not None and self.tiers.hedge
                mask = np.zeros((b,), bool)
                qb2 = np.zeros((b, d), np.float32)
                for hl in hostslots:
                    if not hl.alive or not (hl.pending or hedging):
                        continue
                    free = np.nonzero(~hl.occupied)[0]
                    if free.size == 0:
                        continue
                    m_loc, q_loc = hl.fill(free, step=stats.engine_steps,
                                           epoch=self.engine_epoch)
                    if m_loc.any():
                        hl.stats.refills += 1
                        mask[hl.lo:hl.hi] = m_loc
                        qb2[hl.lo:hl.hi] = q_loc
                if mask.any():
                    rt, ipi, mpi = gather_inputs()
                    rt_dev = self._put(rt)
                    fresh = self._init_chunk(self.engine.index,
                                             self._put(qb2),
                                             self._put(ipi),
                                             self._put(mpi))
                    # after a drained swap st is None (old chunk state
                    # discarded): the pool is empty, so the fresh init
                    # IS the chunk state — no splice needed. With a
                    # tracer, fresh is (state, ring) and the splice
                    # selects both per slot (a spliced slot's ring row
                    # resets to NO_PREDICTION, clearing the previous
                    # occupant's trajectory); on a full rebuild the
                    # ring's column origin moves to the current step
                    # (traj_base) since state.steps restarts at 0.
                    if tr is None:
                        st = (fresh if st is None
                              else self._splice(self._put(mask), fresh, st))
                    elif st is None:
                        st, traj = fresh
                        traj_base = stats.engine_steps
                    else:
                        st, traj = self._splice(self._put(mask), fresh,
                                                (st, traj))
                    changed = True
            if st is None:
                # a swap drained the pool and the refill admitted
                # nothing (budget exhausted, or the only pending
                # queries sit on dead hosts): there is no chunk state
                # left to step — exit; unadmitted queries stay None
                break
            if changed:
                # deactivate empty (and dead-host) slots
                occupied = occupied_global()
                st = dataclasses.replace(
                    st, inner=engines_lib.set_active(
                        st.inner, st.inner.active & self._put(occupied)))
            if (not occupied.any()
                    and not any(hl.pending for hl in hostslots)):
                break
            if stats.engine_steps >= max_engine_steps:
                # Step budget exhausted: the occupied slots still hold a
                # valid partial top-k — harvest it instead of silently
                # dropping those queries (their results[qid] would stay
                # None). Queries never admitted from the queue remain
                # None: they have no state to harvest.
                if occupied.any():
                    arrays = state_slices()
                    for hl in hostslots:
                        if hl.occupied.any():
                            harvest_host(hl, hl.occupied, arrays,
                                         truncated=True)
                break

        for hl in hostslots:
            if hl.alive:
                hl.stats.abandoned = hl.pending
                if tr is not None:
                    # queued to the end (step budget ran out before
                    # admission): close them out so the trace ledger
                    # stays exhaustive — served ∪ shed ∪ abandoned
                    for qid in hl.queue_easy + hl.queue_hard:
                        tr.terminal(
                            qid, "abandoned", host=hl.host,
                            step=stats.engine_steps,
                            epoch=self.engine_epoch, cause="budget",
                            target=float(hl.r_targets[qid]),
                            tier=hl._tier_of(qid))
            stats.completed += hl.stats.completed
            stats.slot_steps += hl.stats.slot_steps
            stats.refills += hl.stats.refills
            stats.truncated += hl.stats.truncated
            stats.ndis_harvested += hl.stats.ndis_harvested
            stats.shed += hl.stats.shed
            stats.degraded += hl.stats.degraded
            stats.hedged += hl.stats.hedged
            stats.hedge_upgrades += hl.stats.hedge_upgrades
            stats.hedge_epoch_dropped += hl.stats.hedge_epoch_dropped
        stats.chunk_ms_p50 = obs_stats.p50(chunk_ms)
        stats.chunk_ms_p99 = obs_stats.p99(chunk_ms)
        if self.tiers is not None:
            stats.tiers = _finalize_tiers(hostslots, is_hard)
        if mets is not None:
            self._export_metrics(mets, stats, hostslots, chunk_ms)
        if tr is not None:
            tr.finish()
        if self.rerank is not None:
            for qid, r in enumerate(results):
                if r is not None:
                    results[qid] = self.rerank(
                        np.asarray(queries[qid], np.float32), r[1])
        return results, stats

    def _export_metrics(self, mets, stats: ServeStats,
                        hostslots: List[_HostSlots],
                        chunk_ms: List[float]) -> None:
        """Fold one serve call's outcome into the metrics registry:
        query counts by terminal outcome, scheduling counters labelled
        per host, and the latency / recall / service-step histograms."""
        qt = mets.counter("darth_queries_total")
        abandoned = sum(h.abandoned for h in stats.hosts)
        for v, outcome in ((stats.completed, "completed"),
                           (stats.truncated, "truncated"),
                           (stats.shed, "shed"),
                           (abandoned, "abandoned")):
            if v:
                qt.inc(v, outcome=outcome)
        for hl in hostslots:
            host = str(hl.host)
            if hl.stats.refills:
                mets.counter("darth_refills_total").inc(
                    hl.stats.refills, host=host)
            if hl.stats.hedged:
                mets.counter("darth_hedges_total").inc(
                    hl.stats.hedged, host=host)
            if hl.stats.stolen:
                mets.counter("darth_steals_total").inc(
                    hl.stats.stolen, host=host)
        if stats.swaps:
            mets.counter("darth_swaps_total").inc(stats.swaps)
        lat_h = mets.histogram("darth_chunk_latency_ms")
        for v in chunk_ms:
            lat_h.observe(v)
        rec_h = mets.histogram("darth_harvest_recall")
        steps_h = mets.histogram("darth_service_steps")
        for hl in hostslots:
            for _, r, steps, _ in hl.samples:
                if np.isfinite(r):
                    rec_h.observe(r)
                steps_h.observe(steps)
        mets.gauge("darth_engine_epoch").set(self.engine_epoch)

    def _rebalance(self, hostslots: List[_HostSlots],
                   step: int = 0) -> None:
        """Queue-level work stealing at a refill boundary.

        Hosts with free slots and a drained queue steal queued queries
        from the most-backlogged live host's arrival tail, hard tier
        first (the expensive queries are moved toward idle capacity).
        Only queue entries move — never in-flight slot state — so a
        stolen query's RESULT is unchanged (per-slot search state is
        slot-local); only which host serves it changes. Deterministic:
        thieves iterate in host order and the donor is the max-pending
        live host, ties to the lowest host id. Stealing stops once the
        donor can admit its whole backlog into its own free slots."""
        live = [hl for hl in hostslots if hl.alive]
        for thief in live:
            if thief.pending:
                continue
            spare = int((~thief.occupied).sum())
            while spare > 0:
                donor = max(live,
                            key=lambda hl: (hl.pending, -hl.host))
                if (donor is thief
                        or donor.pending <= int((~donor.occupied).sum())):
                    break
                src = donor.queue_hard or donor.queue_easy
                qid = src.pop()
                dst = (thief.queue_hard
                       if thief.is_hard is not None and thief.is_hard[qid]
                       else thief.queue_easy)
                dst.append(qid)
                thief.stats.stolen += 1
                spare -= 1
                if self.tracer is not None:
                    self.tracer.event(
                        "steal", qid=qid, host=thief.host, step=step,
                        epoch=self.engine_epoch, donor=donor.host)
