"""DARTH serving engine: slot pool + batch compaction (DESIGN.md §2).

On SPMD hardware a lone early-terminated query inside a fixed batch saves
nothing — the batch keeps stepping. Compaction converts DARTH's per-query
termination into throughput: terminated queries leave their slot, queued
queries are spliced in (state surgery via tree-select), and the engine
keeps every slot busy. This is the systems contribution that makes the
paper's speedups real on TPU; `benchmarks/serving.py` measures
slot-step savings vs a no-compaction baseline.

Every query carries its own declared recall target (mixed-target batches
are native — per-slot R_t, per-slot adaptive intervals).

The server is engine-agnostic through the Engine protocol: handing it
engines.sharded_ivf_engine (cap-sharded bucket store, shard_map probe)
or engines.sharded_hnsw_engine (row-sharded graph, shard_map beam step)
instead of the single-device engines changes nothing here — slot
compaction, splicing and the chunked driver all operate on the
replicated search state, while the probe/beam data traffic stays
on-shard. The one state leaf that IS sharded (HNSW's visited bitmap,
split on its node dim) still has a leading slot dim, so _select_slots
splicing works on it unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import darth_search, engines as engines_lib
from repro.core.intervals import IntervalParams
from repro.core.predictor import RecallPredictor
from repro.utils import meshctx

PyTree = Any


def _select_slots(mask: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-slot tree select: where mask[b], take `new`, else `old`.
    Leaves without a leading slot dim are kept from `old`."""
    b = mask.shape[0]

    def sel(n, o):
        if hasattr(o, "ndim") and o.ndim >= 1 and o.shape[0] == b:
            m = mask.reshape((b,) + (1,) * (o.ndim - 1))
            return jnp.where(m, n, o)
        return o
    return jax.tree.map(sel, new, old)


@dataclasses.dataclass
class ServeStats:
    completed: int = 0
    slot_steps: int = 0          # engine steps x slots (cost proxy)
    engine_steps: int = 0
    refills: int = 0
    truncated: int = 0           # in-flight queries harvested with a
    #                              partial top-k when max_engine_steps hit


class DarthServer:
    """Continuous-batching declarative-recall search server."""

    def __init__(self, engine: engines_lib.Engine,
                 predictor: RecallPredictor,
                 interval_for_target,        # fn: r_t array -> IntervalParams
                 num_slots: int = 64, steps_per_sync: int = 4,
                 mesh=None):
        self.engine = engine
        self.predictor = predictor
        self.interval_for_target = interval_for_target
        self.num_slots = num_slots
        self.steps_per_sync = steps_per_sync
        # When the engine's index was placed on a mesh (dist.place_index),
        # the slot-pool chunks run SPMD over it; use_mesh also activates
        # the activation constraints inside any model-side feature code.
        self.mesh = mesh

        self._build_chunks()

    def _build_chunks(self) -> None:
        """(Re)build the jitted chunk functions around the current
        engine + predictor (called from __init__ and from the hot-swap
        paths; a rebuild recompiles, so predictor swaps pay one compile
        — the drift-recalibration cadence makes that negligible)."""
        # Capture the engine WITHOUT its index: the index is threaded
        # through the chunks as an argument anyway, and a captured copy
        # would pin the build-time index buffers in device memory for
        # the server's lifetime across contents_only engine swaps.
        eng = self.engine._replace(index=None)
        pred = self.predictor
        steps_per_sync = self.steps_per_sync

        # The engine's index enters these outer jits as an ARGUMENT
        # (re-bound via _replace so the protocol's init/step see the
        # traced value): a closure-captured index would be baked in as a
        # replicated constant, silently undoing dist.place_index for
        # sharded engines.
        @jax.jit
        def run_chunk(index, st: darth_search.DarthState, r_t: jax.Array,
                      ipi: jax.Array, mpi: jax.Array):
            body = darth_search.make_darth_body(
                eng._replace(index=index), pred,
                IntervalParams(ipi=ipi, mpi=mpi), r_t)

            def do(i, s):
                return body(s)
            return jax.lax.fori_loop(0, steps_per_sync, do, st)

        @jax.jit
        def init_chunk(index, q: jax.Array, ipi: jax.Array, mpi: jax.Array):
            # Pass the REAL per-slot mpi through: init only reads ipi
            # today, but IntervalParams(mpi=ipi) would silently lie to
            # any future reader of params.mpi at init time.
            return darth_search.init_darth_state(
                eng._replace(index=index), q,
                IntervalParams(ipi=ipi, mpi=mpi))

        @jax.jit
        def splice(mask, new_st, old_st):
            return _select_slots(mask, new_st, old_st)

        self._run_chunk = run_chunk
        self._init_chunk = init_chunk
        self._splice = splice

    # -- hot swap (streaming mutations / drift recalibration) --------------
    def set_predictor(self, predictor: RecallPredictor) -> None:
        """Swap a refit recall predictor into the running server (the
        drift monitor's hot-swap path). Rebuilds the chunk jits."""
        self.predictor = predictor
        self._build_chunks()

    def set_engine(self, engine: engines_lib.Engine, *,
                   contents_only: bool = False) -> None:
        """Swap an updated engine in (delta writes, tombstones, or a
        compacted base).

        contents_only=True asserts that ONLY the index contents changed
        (same engine family and constructor params — k, nprobe/ef, ...):
        the existing chunk jits are kept, because the index crosses them
        as an argument and the old closures remain valid; no recompile.
        The flag is explicit because name/k/max_steps cannot distinguish
        e.g. two hnsw engines with different ef but an identical
        explicit max_steps — defaulting to reuse would silently keep
        serving with the old params. The default rebuilds."""
        if contents_only and (engine.name != self.engine.name
                              or engine.k != self.engine.k
                              or engine.max_steps != self.engine.max_steps):
            raise ValueError(
                f"contents_only swap changed the engine protocol: "
                f"{self.engine.name}/k={self.engine.k}/"
                f"max_steps={self.engine.max_steps} -> {engine.name}/"
                f"k={engine.k}/max_steps={engine.max_steps}")
        self.engine = engine
        if not contents_only:
            self._build_chunks()

    def serve(self, queries: np.ndarray, r_targets: np.ndarray,
              max_engine_steps: int = 100_000
              ) -> Tuple[List[Optional[Tuple[np.ndarray, np.ndarray]]],
                         ServeStats]:
        """Process all queries; returns per-query (dists, ids) + stats."""
        from repro.core import api as api_lib

        queries = np.asarray(queries, np.float32)
        if queries.ndim != 2:
            raise ValueError(
                f"queries must be [N, D], got shape {queries.shape}")
        r_targets = np.asarray(r_targets, np.float32)
        if r_targets.shape != (queries.shape[0],):
            raise ValueError(
                f"r_targets shape {r_targets.shape} does not match the "
                f"{queries.shape[0]} queries: the server needs one "
                f"declared recall target per query")
        r_targets = api_lib.validate_targets(r_targets, queries.shape[0])
        ctx = (meshctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            return self._serve(queries, r_targets, max_engine_steps)

    def _serve(self, queries: np.ndarray, r_targets: np.ndarray,
               max_engine_steps: int = 100_000
               ) -> Tuple[List[Optional[Tuple[np.ndarray, np.ndarray]]],
                          ServeStats]:
        n, d = queries.shape
        b = self.num_slots
        stats = ServeStats()
        results: List[Optional[Tuple[np.ndarray, np.ndarray]]] = [None] * n

        queue = list(range(n))
        slot_query = np.full((b,), -1, np.int64)   # which query occupies slot

        def take_batch(count):
            ids = [queue.pop(0) for _ in range(min(count, len(queue)))]
            return ids

        def harvest(mask: np.ndarray) -> int:
            """Pull the masked slots' top-k into results; free the slots."""
            topk_d = np.asarray(jax.device_get(self.engine.topk_d(st.inner)))
            topk_i = np.asarray(jax.device_get(self.engine.topk_i(st.inner)))
            for s in np.nonzero(mask)[0]:
                results[slot_query[s]] = (topk_d[s], topk_i[s])
                slot_query[s] = -1
            return int(mask.sum())

        # initial fill
        ids = take_batch(b)
        qb = np.zeros((b, d), np.float32)
        rt = np.zeros((b,), np.float32)
        for s, qid in enumerate(ids):
            qb[s] = queries[qid]
            rt[s] = r_targets[qid]
            slot_query[s] = qid
        ip = self.interval_for_target(rt)
        ipi = np.broadcast_to(np.asarray(ip.ipi, np.float32), (b,)).copy()
        mpi = np.broadcast_to(np.asarray(ip.mpi, np.float32), (b,)).copy()
        st = self._init_chunk(self.engine.index, jnp.asarray(qb),
                              jnp.asarray(ipi), jnp.asarray(mpi))
        # slots with no query: deactivate
        occupied = slot_query >= 0
        st = dataclasses.replace(
            st, inner=engines_lib.set_active(
                st.inner, st.inner.active & jnp.asarray(occupied)))
        rt_dev = jnp.asarray(rt)

        while True:
            st = self._run_chunk(self.engine.index, st, rt_dev,
                                 jnp.asarray(ipi), jnp.asarray(mpi))
            stats.engine_steps += self.steps_per_sync
            stats.slot_steps += self.steps_per_sync * int(occupied.sum())
            active = np.asarray(jax.device_get(st.inner.active))
            finished = occupied & ~active
            if finished.any():
                stats.completed += harvest(finished)
                occupied = slot_query >= 0
                # refill — unless the step budget is already exhausted:
                # a query spliced in now would run zero steps and be
                # harvested below as init-state junk (ids -1) instead of
                # staying None in the queue.
                if queue and stats.engine_steps < max_engine_steps:
                    free = np.nonzero(~occupied)[0]
                    ids = take_batch(len(free))
                    if ids:
                        stats.refills += 1
                        mask = np.zeros((b,), bool)
                        qb2 = np.zeros((b, d), np.float32)
                        rt2 = rt.copy()
                        for s, qid in zip(free, ids):
                            mask[s] = True
                            qb2[s] = queries[qid]
                            rt2[s] = r_targets[qid]
                            slot_query[s] = qid
                        ip2 = self.interval_for_target(rt2)
                        ipi2 = np.broadcast_to(
                            np.asarray(ip2.ipi, np.float32), (b,))
                        mpi2 = np.broadcast_to(
                            np.asarray(ip2.mpi, np.float32), (b,))
                        ipi = np.where(mask, ipi2, ipi)
                        mpi = np.where(mask, mpi2, mpi)
                        rt = np.where(mask, rt2, rt)
                        rt_dev = jnp.asarray(rt)
                        fresh = self._init_chunk(self.engine.index,
                                                 jnp.asarray(qb2),
                                                 jnp.asarray(ipi),
                                                 jnp.asarray(mpi))
                        st = self._splice(jnp.asarray(mask), fresh, st)
                        occupied = slot_query >= 0
                # deactivate empty slots
                st = dataclasses.replace(
                    st, inner=engines_lib.set_active(
                        st.inner, st.inner.active & jnp.asarray(occupied)))
            if not occupied.any() and not queue:
                break
            if stats.engine_steps >= max_engine_steps:
                # Step budget exhausted: the occupied slots still hold a
                # valid partial top-k — harvest it instead of silently
                # dropping those queries (their results[qid] would stay
                # None). Queries never admitted from the queue remain
                # None: they have no state to harvest.
                if occupied.any():
                    stats.truncated += harvest(occupied)
                break
        return results, stats
