from repro.serve import difficulty, engine
from repro.serve.difficulty import (TierConfig, TierStats, assign_tiers,
                                    difficulty_scores)
from repro.serve.engine import DarthServer, HostStats, ServeStats

__all__ = [
    "engine", "difficulty", "DarthServer", "HostStats", "ServeStats",
    "TierConfig", "TierStats", "assign_tiers", "difficulty_scores",
]
