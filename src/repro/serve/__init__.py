from repro.serve import engine
from repro.serve.engine import DarthServer, HostStats, ServeStats

__all__ = ["engine", "DarthServer", "HostStats", "ServeStats"]
