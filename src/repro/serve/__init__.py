from repro.serve import engine
from repro.serve.engine import DarthServer, ServeStats

__all__ = ["engine", "DarthServer", "ServeStats"]
