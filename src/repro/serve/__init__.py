from repro.serve import cold, difficulty, engine
from repro.serve.cold import ColdTier, make_cold_tier
from repro.serve.difficulty import (TierConfig, TierStats, assign_tiers,
                                    difficulty_scores)
from repro.serve.engine import DarthServer, HostStats, ServeStats

__all__ = [
    "engine", "difficulty", "cold", "DarthServer", "HostStats",
    "ServeStats", "ColdTier", "make_cold_tier",
    "TierConfig", "TierStats", "assign_tiers", "difficulty_scores",
]
