"""Difficulty-aware admission for the slot-pool server (serve.engine).

DARTH's recall predictor estimates per-query search progress, but the
slot pool treats every query identically — so the hard tail of a query
stream drags p99 recall/latency even when MEAN recall meets the
declared target. This module classifies queries at admission time with
cheap features read off the same routing scan every engine already
performs, so the server can give the hard tier structurally different
treatment (reserved slots, boosted effective targets, hedged
duplicates, overload shedding) without touching the device programs.

Difficulty features (all from one [N, R] distance matrix against the
index's ROUTING points — IVF centroids, or the HNSW routing sample
`route_ids`; identical to what ivf.init_state / hnsw init compute on
device, so classification costs one extra host-side matmul and nothing
per step):

  * first_nn — distance to the nearest routing point. This is exactly
    the `first_nn` feature the recall predictor consumes, i.e. the
    predictor's step-0 progress signal. (The full GBDT cannot be asked
    directly at admission: features.extract zeroes a query's feature
    row while its top-k is empty, so a pre-search predictor call
    returns a constant.) Far-from-index queries are harder.
  * gap — relative margin (d2 - d1) / d1 between the two nearest
    routing points. A small gap means routing is ambiguous: the true
    neighbors plausibly live under several routing regions and early
    probes rank them poorly.
  * crowd — fraction of routing points within `crowd_margin` x d1.
    A crowded neighborhood means many regions must be visited before
    the predictor's recall estimate saturates.

The scalar score is  crowd - w_gap * gap + w_nn * (first_nn / median)
— higher is harder. Scores only ever order queries within one serve()
batch (tier assignment is by quantile or explicit threshold), so the
scale of the individual terms does not need calibration across
datasets.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """Difficulty-tier policy for DarthServer (None disables tiering).

    The identity configuration — `TierConfig()` with hard_threshold=inf
    (nothing classified hard), hard_slot_fraction=0, boost=0,
    hedge=False, max_queue=None, rebalance=False — schedules exactly
    like the untiered server: one FIFO queue per host, declared
    targets served unmodified (tests/test_serving.py pins this).

    Attributes:
      hard_quantile: score quantile above which a query is "hard"
        (per serve() batch; ignored when hard_threshold is set).
      hard_threshold: absolute score cutoff; overrides the quantile.
      hard_slot_fraction: fraction of each host's slot slice reserved
        for the hard tier (the partition is work-conserving: either
        tier spills into the other's free slots when its own queue is
        empty).
      boost: added to hard queries' effective recall target (clipped
        to 0.99, never below the declared target) — deeper search for
        the tail, which is what lifts p99 recall.
      hedge: when a host has idle hard slots and nothing queued, launch
        duplicate searches of in-flight hard queries at a further
        `hedge_boost`-raised target; a hedge that completes naturally
        upgrades the query's result, a truncated hedge is dropped.
      hedge_boost: extra target boost for hedged duplicates.
      max_queue: per-host admission bound; beyond it the overload
        policy applies instead of queueing unboundedly.
      overload: "degrade" serves overflow queries at
        min(target, degrade_target); "shed" refuses them outright
        (hard tier first — the expensive queries are dropped before
        cheap ones), recording ids in HostStats.shed_ids.
      degrade_target: the lowered target for "degrade".
      rebalance: hosts with idle slots and empty queues steal queued
        queries from the most-backlogged host at refill boundaries
        (deterministic work stealing; changes which host serves a
        query but never its result — per-slot state is slot-local).
    """
    hard_quantile: float = 0.75
    hard_threshold: Optional[float] = None
    hard_slot_fraction: float = 0.25
    boost: float = 0.0
    hedge: bool = False
    hedge_boost: float = 0.05
    max_queue: Optional[int] = None
    overload: str = "degrade"
    degrade_target: float = 0.80
    rebalance: bool = False

    def __post_init__(self):
        if not 0.0 <= self.hard_slot_fraction <= 1.0:
            raise ValueError(
                f"hard_slot_fraction must be in [0, 1], got "
                f"{self.hard_slot_fraction}")
        if not 0.0 <= self.hard_quantile <= 1.0:
            raise ValueError(
                f"hard_quantile must be in [0, 1], got "
                f"{self.hard_quantile}")
        if self.overload not in ("degrade", "shed"):
            raise ValueError(
                f"overload must be 'degrade' or 'shed', got "
                f"{self.overload!r}")
        if not 0.0 < self.degrade_target <= 1.0:
            raise ValueError(
                f"degrade_target must be in (0, 1], got "
                f"{self.degrade_target}")
        if self.boost < 0.0 or self.hedge_boost < 0.0:
            raise ValueError("boost / hedge_boost must be >= 0")
        if self.max_queue is not None and self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got "
                             f"{self.max_queue}")

    @staticmethod
    def uniform() -> "TierConfig":
        """The identity policy: tiering machinery on, behavior exactly
        the untiered server's (see class docstring)."""
        return TierConfig(hard_threshold=np.inf, hard_slot_fraction=0.0,
                          boost=0.0, hedge=False, max_queue=None,
                          rebalance=False)


@dataclasses.dataclass
class TierStats:
    """Per-tier SLO counters (ServeStats.tiers['easy'|'hard']).

    recall_* are percentiles of the PREDICTED recall at harvest
    (DarthState.r_pred — what the declarative-recall contract actually
    controls on; ground-truth recall needs the true neighbors, which
    the server never sees). recall_p99 is the 1st percentile of the
    distribution — the recall the worst 1% of the tier's queries got.
    latency_* are percentiles of engine steps from admission to
    harvest (service latency in sync units; queueing wait is visible
    as admission happening at a later engine step). NaN when the tier
    completed no queries."""
    count: int = 0              # queries assigned to the tier
    completed: int = 0
    truncated: int = 0
    shed: int = 0
    degraded: int = 0
    hedged: int = 0             # hedge duplicates launched
    hedge_upgrades: int = 0     # results replaced by a deeper hedge
    recall_p50: float = float("nan")
    recall_p99: float = float("nan")
    latency_p50: float = float("nan")
    latency_p99: float = float("nan")


def _routing_points(index) -> np.ndarray:
    """The index's routing scan targets, as host arrays.

    IVF routes over centroids; HNSW over the uniform node sample
    route_ids; a MutableIndexView routes with its base index (the delta
    ring is scanned brute-force, it has no routing structure)."""
    if hasattr(index, "base") and hasattr(index, "delta"):
        return _routing_points(index.base)
    if hasattr(index, "centroids"):
        return np.asarray(jax.device_get(index.centroids), np.float32)
    if hasattr(index, "route_ids"):
        vecs = np.asarray(jax.device_get(index.vectors), np.float32)
        ids = np.asarray(jax.device_get(index.route_ids))
        return vecs[ids]
    raise TypeError(
        f"cannot derive routing points from index type "
        f"{type(index).__name__}: expected IVF (centroids), HNSW "
        f"(route_ids) or a mutable view of either")


def difficulty_scores(index, queries: np.ndarray, *,
                      crowd_margin: float = 1.25,
                      w_gap: float = 1.0, w_nn: float = 0.5
                      ) -> np.ndarray:
    """Admission-time difficulty score per query (higher = harder).

    One [N, R] squared-distance matrix against the routing points (the
    same scan ivf.init_state / hnsw init run on device), reduced to the
    crowd / gap / first_nn features described in the module docstring.
    Deterministic in (index, queries)."""
    pts = _routing_points(index)
    q = np.asarray(queries, np.float32)
    d2 = (np.sum(q * q, axis=1)[:, None] + np.sum(pts * pts, axis=1)[None]
          - 2.0 * q @ pts.T)
    d2 = np.maximum(d2, 0.0)
    if d2.shape[1] < 2:         # a single routing point: nothing to rank
        return np.zeros((q.shape[0],), np.float32)
    part = np.partition(d2, 1, axis=1)
    d1, dsecond = part[:, 0], part[:, 1]
    eps = 1e-12
    gap = (dsecond - d1) / (d1 + eps)
    crowd = np.mean(d2 <= (crowd_margin ** 2) * d1[:, None] + eps, axis=1)
    first_nn = np.sqrt(d1)
    nn_norm = first_nn / (np.median(first_nn) + eps)
    return (crowd - w_gap * gap + w_nn * nn_norm).astype(np.float32)


def assign_tiers(scores: np.ndarray, config: TierConfig) -> np.ndarray:
    """bool[N] hard-tier mask from scores + policy (threshold wins over
    quantile; the quantile is taken within the batch being served)."""
    scores = np.asarray(scores, np.float32)
    if config.hard_threshold is not None:
        return scores >= config.hard_threshold
    cut = float(np.quantile(scores, config.hard_quantile))
    return scores >= cut


__all__ = ["TierConfig", "TierStats", "difficulty_scores", "assign_tiers"]
