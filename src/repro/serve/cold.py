"""Cold bucket tier: rarely-probed IVF buckets spill to host memory.

The third residency tier (docs/architecture.md "Index residency
tiers"): the device bucket store holds only ``hot_slots`` bucket rows
— the host keeps the canonical copy of EVERY bucket's payload, so the
device store is a cache and "eviction" is pure ``hot_map`` bookkeeping,
never a device→host copy. ``IVFIndex.hot_map`` is the indirection the
probe paths (index.ivf.probe_step and the sharded
dist.collectives.make_sharded_probe_step) resolve bucket ids through:
a probe whose bucket is not resident is SKIPPED — the probe cursor
advances, the scan contributes no candidates, ndis stays honest — so a
cold hit never stalls the SPMD chunk.

``ColdTier.on_boundary`` is the prefetcher, shaped for
``DarthServer.serve(.., on_boundary=tier.on_boundary)``: at every chunk
boundary it reads the in-flight pool state (``server.chunk_state``),
walks each active slot's REMAINING probe order ``lookahead`` probes
ahead, stages the demanded cold buckets into the least-demanded device
slots (functional ``.at[slot].set`` — the transfer is dispatched at the
boundary and overlaps the next chunk's compute), and retargets the pool
with ``set_engine(contents_only=True)``. With ``lookahead >=
steps_per_sync`` a bucket demanded by the NEXT chunk is staged one
boundary ahead of its probe turn; buckets that still slip through skip
(``darth_cold_miss_total``) rather than block.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import ivf as ivf_lib


def split_index(index: ivf_lib.IVFIndex, hot_buckets: np.ndarray
                ) -> ivf_lib.IVFIndex:
    """Device view holding only ``hot_buckets``' payload rows.

    ``hot_buckets`` (i32[nslots], unique bucket ids) occupy slots
    0..nslots-1 in build order; every other bucket maps to -1 in
    ``hot_map``. Centroids and ``bucket_sizes`` stay full [nlist] —
    probe ranking and the ndis accounting are residency-independent.
    """
    hot = np.asarray(hot_buckets, np.int32).reshape(-1)
    if hot.size != np.unique(hot).size:
        raise ValueError("hot_buckets must be unique bucket ids")
    hot_map = np.full((index.nlist,), -1, np.int32)
    hot_map[hot] = np.arange(hot.size, dtype=np.int32)
    return dataclasses.replace(
        index,
        bucket_vecs=jnp.asarray(np.asarray(
            jax.device_get(index.bucket_vecs))[hot]),
        bucket_ids=jnp.asarray(np.asarray(
            jax.device_get(index.bucket_ids))[hot]),
        bucket_sqnorm=jnp.asarray(np.asarray(
            jax.device_get(index.bucket_sqnorm))[hot]),
        hot_map=jnp.asarray(hot_map))


class ColdTier:
    """Host-canonical bucket store + device-slot cache manager.

    Build with :func:`make_cold_tier` (which picks the initial resident
    set and produces the device store), keep the returned ``tier``
    alive for the serve's duration, and pass ``tier.on_boundary`` to
    ``DarthServer.serve``. The tier owns the authoritative ``hot_map``;
    the server's engine index is refreshed in place (contents-only, no
    recompile — slot count and shapes never change).
    """

    def __init__(self, index: ivf_lib.IVFIndex, store: ivf_lib.IVFIndex,
                 *, lookahead: int = 4, staging: int = 8,
                 metrics=None) -> None:
        self.host_vecs = np.asarray(jax.device_get(index.bucket_vecs))
        self.host_ids = np.asarray(jax.device_get(index.bucket_ids))
        self.host_sqn = np.asarray(jax.device_get(index.bucket_sqnorm))
        self.store = store
        hot_map = np.asarray(jax.device_get(store.hot_map))
        self.hot_map = hot_map.copy()
        nslots = store.bucket_vecs.shape[0]
        self.slot_bucket = np.full((nslots,), -1, np.int32)
        resident = np.where(hot_map >= 0)[0]
        self.slot_bucket[hot_map[resident]] = resident
        self.lookahead = int(lookahead)
        # Only the trailing `staging` slots are evictable. The seeded
        # set stays PINNED: the boundary hook sees demand from the
        # in-flight slots only, and queries admitted at the very next
        # refill are invisible to it — evicting "undemanded" pinned
        # buckets would strip exactly what the next admission wave's
        # first probes need (the window the plan()/popularity seed
        # exists to cover).
        self.pinned = np.zeros((nslots,), bool)
        self.pinned[:max(nslots - int(staging), 0)] = True
        self.metrics = metrics
        self.prefetches = 0
        self.evictions = 0
        self.misses = 0

    # -- demand planning ----------------------------------------------

    def plan(self, queries: np.ndarray, *, nprobe: int,
             first: int = 4) -> ivf_lib.IVFIndex:
        """Re-seed the resident set from a known query workload.

        The boundary prefetcher covers every probe a query makes AFTER
        its first chunk (by then the slot's probe order is visible and
        lookahead stages ahead of the cursor), but a query's FIRST
        ``steps_per_sync`` probes run before any boundary has seen it —
        a cold bucket there is skipped for good. When the workload is
        known up front (the batch serve API), ranking every query's
        centroids and seeding residency by early-probe demand closes
        exactly that window: buckets scored by how many queries want
        them within their first ``first`` probes (earlier probes weigh
        more). Returns the new device store; build the serving engine
        from it."""
        q = jnp.asarray(np.asarray(queries, np.float32))
        qsq = jnp.sum(q * q, axis=1, keepdims=True)
        order, _ = ivf_lib.rank_centroids(self.store.centroids, q, qsq,
                                          min(nprobe, self.store.nlist))
        order = np.asarray(jax.device_get(order))
        score = np.zeros((self.store.nlist,), np.float64)
        depth = min(first, order.shape[1])
        for j in range(depth):
            np.add.at(score, order[:, j], float(depth - j))
        # Tail tie-break: keep the populated-bucket prior for slots the
        # workload's early probes leave unclaimed.
        sizes = np.asarray(jax.device_get(self.store.bucket_sizes))
        score += sizes / max(float(sizes.sum()), 1.0)
        nslots = self.slot_bucket.size
        hot = np.argsort(-score, kind="stable")[:nslots].astype(np.int32)
        hot_map = np.full((self.store.nlist,), -1, np.int32)
        hot_map[hot] = np.arange(nslots, dtype=np.int32)
        self.hot_map = hot_map
        self.slot_bucket = hot.copy()
        self.store = dataclasses.replace(
            self.store,
            bucket_vecs=jnp.asarray(self.host_vecs[hot]),
            bucket_ids=jnp.asarray(self.host_ids[hot]),
            bucket_sqnorm=jnp.asarray(self.host_sqn[hot]),
            hot_map=jnp.asarray(hot_map))
        return self.store

    def _demand(self, server) -> Optional[Dict[int, int]]:
        """bucket id -> probes-until-needed (min over active slots),
        from the server's boundary-exposed pool state; None when no
        probe bookkeeping is in flight (between serves / right after a
        swap / non-IVF engine)."""
        s = server.chunk_state
        while s is not None and not hasattr(s, "probe_order"):
            s = getattr(s, "inner", None)
        if s is None:
            return None
        order = np.asarray(jax.device_get(s.probe_order))
        pos = np.asarray(jax.device_get(s.probe_pos))
        active = np.asarray(jax.device_get(s.active))
        nprobe = order.shape[1]
        want: Dict[int, int] = {}
        for row in np.where(active)[0]:
            lo = int(pos[row])
            ahead = order[row, lo:min(lo + self.lookahead, nprobe)]
            for j, bk in enumerate(np.asarray(ahead, np.int64)):
                bk = int(bk)
                if bk >= 0 and want.get(bk, self.lookahead + 1) > j:
                    want[bk] = j
        return want

    # -- the boundary hook --------------------------------------------

    def on_boundary(self, server) -> None:
        """Stage upcoming cold buckets; evict slots nothing will probe."""
        want = self._demand(server)
        if not want:
            return
        missing = sorted(
            (bk for bk in want if self.hot_map[bk] < 0),
            key=want.get)
        if not missing:
            return
        # A demanded-but-cold bucket closer than the chunk length will
        # be probed before the staged copy can matter: an honest miss.
        near = sum(1 for bk in missing
                   if want[bk] < getattr(server, "steps_per_sync", 1))
        # Victims: unpinned (staging-ring) slots whose bucket no active
        # slot will probe inside the lookahead window.
        victims = [sl for sl in range(self.slot_bucket.size)
                   if not self.pinned[sl]
                   and int(self.slot_bucket[sl]) not in want]
        loads = list(zip(missing, victims))
        if not loads:
            self._count(near, 0, 0)
            return
        bv, bi, bs = (self.store.bucket_vecs, self.store.bucket_ids,
                      self.store.bucket_sqnorm)
        evicted = 0
        for bk, sl in loads:
            old = int(self.slot_bucket[sl])
            if old >= 0:
                self.hot_map[old] = -1
                evicted += 1
            # Host payload is canonical — staging is device-write only.
            bv = bv.at[sl].set(self.host_vecs[bk])
            bi = bi.at[sl].set(self.host_ids[bk])
            bs = bs.at[sl].set(self.host_sqn[bk])
            self.hot_map[bk] = sl
            self.slot_bucket[sl] = bk
        self.store = dataclasses.replace(
            self.store, bucket_vecs=bv, bucket_ids=bi, bucket_sqnorm=bs,
            hot_map=jnp.asarray(self.hot_map))
        self._retarget(server)
        self._count(near, len(loads), evicted)

    def _retarget(self, server) -> None:
        """Contents-only engine refresh around the new store view."""
        engine = server.engine
        idx = engine.index
        if hasattr(idx, "base"):      # MutableIndexView: swap the base
            idx = dataclasses.replace(idx, base=self.store)
        else:
            idx = self.store
        server.set_engine(engine._replace(index=idx), contents_only=True)

    def _count(self, near: int, staged: int, evicted: int) -> None:
        self.misses += near
        self.prefetches += staged
        self.evictions += evicted
        if self.metrics is None:
            return
        if near:
            self.metrics.counter("darth_cold_miss_total").inc(near)
        if staged:
            self.metrics.counter("darth_cold_prefetch_total").inc(staged)
        if evicted:
            self.metrics.counter("darth_cold_evictions_total").inc(evicted)


def make_cold_tier(index: ivf_lib.IVFIndex, *, hot_slots: int,
                   lookahead: int = 4, staging: int = 8,
                   metrics=None) -> ColdTier:
    """Split ``index`` into a ``hot_slots``-bucket device store plus a
    host cold tier, initially keeping the most populated buckets
    resident (population is the best probe-popularity prior available
    at split time; ``plan`` sharpens the seed from a known workload and
    the boundary prefetcher's ``staging``-slot ring tracks live demand).
    """
    if not 0 < hot_slots <= index.nlist:
        raise ValueError(
            f"hot_slots must be in (0, nlist={index.nlist}], "
            f"got {hot_slots}")
    sizes = np.asarray(jax.device_get(index.bucket_sizes))
    hot = np.argsort(-sizes, kind="stable")[:hot_slots].astype(np.int32)
    store = split_index(index, hot)
    return ColdTier(index, store, lookahead=lookahead,
                    staging=min(staging, hot_slots), metrics=metrics)


__all__ = ["ColdTier", "make_cold_tier", "split_index"]
